"""Table 1 — MAPE of the GBDT predictors per device/backend/op-kind.

Paper values: GPU 3.7-9.0%, CPU 2.4-11.5% depending on device and kind.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEVICES, csv_row, get_predictor
from repro.core.predictor import (mape, measure_ops, sample_conv_ops,
                                  sample_linear_ops)

_PAPER = {  # (device, kind, backend) -> paper MAPE %
    ("pixel4", "linear", "gpu"): 4.4, ("pixel4", "conv", "gpu"): 8.5,
    ("pixel5", "linear", "gpu"): 3.7, ("pixel5", "conv", "gpu"): 7.7,
    ("moto2022", "linear", "gpu"): 4.0, ("moto2022", "conv", "gpu"): 9.0,
    ("oneplus11", "linear", "gpu"): 3.7, ("oneplus11", "conv", "gpu"): 7.4,
}


def run() -> list:
    rows = []
    test_l = sample_linear_ops(400, seed=77)
    test_c = sample_conv_ops(400, seed=77)
    for dev in DEVICES:
        for kind, test in (("linear", test_l), ("conv", test_c)):
            for backend in ("gpu", "cpu1", "cpu2", "cpu3"):
                p = get_predictor(dev, backend, kind,
                                  whitebox=(backend == "gpu"))
                y = measure_ops(test, dev, backend, seed=99)
                m = mape(p.predict(test), y) * 100
                paper = _PAPER.get((dev, kind, backend), "")
                rows.append(csv_row(f"tab1_{dev}_{kind}_{backend}", m,
                                    f"mape_pct(paper={paper})"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("tab1", run)
