"""Calibration microbench — pre/post-calibration fidelity on a fixed-seed
executed plan.

Executes a compiled resnet18 plan twice (one untimed warmup before the
first run), records every per-op `MeasurementRecord` into the shared
store (`reports/measurements/`), fits a `Calibrator`, and reports

  * the executed-vs-predicted fidelity error (Σ |log wall/pred|) before
    and after calibration, and their ratio — the headline number this
    suite tracks across PRs;
  * the calibrated replan's predicted gain and decision-change count.

Everything is fixed-seed (compile seed, executor params/input seeds), so
the only nondeterminism is host wall-clock jitter — which is exactly what
calibration absorbs.  The JSON report embeds the raw records
(`benchmarks.common.load_bench_measurements("calibration")` reads them
back).

    PYTHONPATH=src python -m benchmarks.calibration_bench
"""
from __future__ import annotations

import repro
from benchmarks.common import (PRED_CACHE, csv_row, measurement_store,
                               plan_cache)
from repro.measure import Calibrator, fidelity_error

NETWORK = "resnet18"
DEVICE = "moto2022"
THREADS = 3
RUNS = 2

#: records collected by the last `run()` (embedded in the JSON report)
_collected: list = []


def measurements() -> list:
    """The records the last `run()` collected (what the report embeds)."""
    return list(_collected)


def run() -> list:
    target = repro.Target(device=DEVICE, threads=THREADS)
    compiled = repro.compile(NETWORK, target, samples=200, estimators=40,
                             cache=plan_cache(),
                             predictor_cache=str(PRED_CACHE))
    store = measurement_store()
    # the memoized executor warms up once; later records are steady-state
    reports = [compiled.record(store=store) for _ in range(RUNS)]
    records = [t for rep in reports for t in rep.timings]
    _collected[:] = records

    cal = Calibrator.fit(records)
    pre = fidelity_error(records)
    post = cal.fidelity_error(records)
    ratio = pre / max(post, 1e-9)
    recompiled, diff = compiled.replan(cal, store=store, cache=plan_cache())

    print(f"# plan {compiled.key} -> replanned {recompiled.key} "
          f"under calibration {cal.version}")
    return [
        csv_row("calibration_pre_error", pre,
                f"records={len(records)},runs={RUNS},"
                f"net={NETWORK},dev={DEVICE}"),
        csv_row("calibration_post_error", post,
                f"corrections={len(cal.corrections)},"
                f"calibration={cal.version}"),
        csv_row("calibration_fidelity_ratio", ratio,
                "pre/post,higher=better"),
        csv_row("calibration_replan_gain", diff.predicted_gain_us,
                f"changed={len(diff.changes)}/{diff.n_ops},"
                f"new_key={diff.new_key}"),
    ]


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main("calibration", run,
               extra={"network": NETWORK, "exec_device": DEVICE,
                      "runs": RUNS},
               measurements_fn=measurements)
