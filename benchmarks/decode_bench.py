"""Decode-block co-execution benchmark — head/state-split planning on the
tiny decode models (PR 7 headline suite).

Headline rows are the *model-predicted* decode-block latency of the
planned (axis, split, mode) schedule against the exclusive-GPU baseline on
the modeled phone — the same convention as tab3 (predictions model the
phone, execution runs on this host).  One row per attention/ssm node shows
the chosen partition axis, boundary, and kernel mode with its predicted
speedup over the best exclusive-GPU mode.

Executed rows are the fidelity signal, not a speedup claim: XLA's virtual
host devices time-share this machine's cores, so a co-executed split runs
its two sides serially here.  What execution *can* establish is that the
split schedule lowers, runs, and reproduces the unsplit oracle
bit-identically in fp32 — reported per model as `identical`/`maxdiff`
alongside fused/unfused host wall time.
"""
from __future__ import annotations

import numpy as np

import repro
from benchmarks.common import PRED_CACHE, csv_row, plan_cache
from repro.core.simulator.measure import true_latency_us
from repro.core.types import AttnOp, SSMOp
from repro.graph.frontends import from_model
from repro.kernels import registry

DEVICE = "moto2022"
THREADS = 3

#: model -> from_model knobs sized so the decode node dominates the block
#: (long KV cache / long token block) and co-execution wins in the model
CONFIGS = (
    ("tiny_decoder", dict(cache_len=4096)),
    ("tiny_ssm", dict(tokens=4096)),
    ("tiny_hybrid", dict(blocks=2, cache_len=4096)),
)


def _gpu_only_us(op) -> float:
    """Exclusive-GPU device-model baseline: best kernel mode for the op."""
    modes = registry.get(registry.op_kind(op)).modes or (op.mode,)
    return min(true_latency_us(op.with_mode(m), DEVICE, "gpu")
               for m in modes)


def _decision_rows(name: str, compiled) -> list:
    """One row per attention/ssm node: planned (axis, split, mode) and its
    predicted speedup over the exclusive-GPU baseline."""
    rows = []
    for nid, dec in sorted(compiled.decisions_by_node.items()):
        if not isinstance(dec.op, (AttnOp, SSMOp)):
            continue
        gpu_us = _gpu_only_us(dec.op)
        speedup = gpu_us / dec.pred_total_us if dec.pred_total_us > 0 \
            else float("inf")
        rows.append(csv_row(
            f"decode_{name}_{nid}", dec.pred_total_us,
            f"axis={dec.axis},split={dec.c_gpu}/{dec.c_gpu + dec.c_cpu},"
            f"mode={dec.op.mode},gpu_us={gpu_us:.1f},"
            f"speedup={speedup:.2f}x"))
    return rows


def _exec_rows(name: str, compiled) -> list:
    """Host execution: fused/unfused wall (best of 2, warmed) plus
    bit-fidelity of the split schedule against the unsplit oracle."""
    best = {}
    for fused in (False, True):
        reps = [compiled.profile(fused=fused, warmup=True)
                for _ in range(2)]
        best[fused] = min(reps, key=lambda r: r.wall_us)
    y = np.asarray(compiled.run(fused=True, warmup=True))
    ref = np.asarray(compiled.executor().run_oracle())
    identical = bool(np.array_equal(y, ref))
    maxdiff = float(np.max(np.abs(y - ref))) if y.size else 0.0
    print(f"# {name}: fused {best[True].wall_us / 1e3:.1f} ms vs unfused "
          f"{best[False].wall_us / 1e3:.1f} ms, oracle "
          f"{'bit-identical' if identical else f'maxdiff={maxdiff:.1e}'}")
    return [csv_row(
        f"decode_{name}_exec", best[True].wall_us,
        f"unfused_us={best[False].wall_us:.1f},"
        f"pred_us={best[True].predicted_us:.1f},"
        f"identical={int(identical)},maxdiff={maxdiff:.1e}")]


def run(execute: bool = True) -> list:
    rows = []
    cache = plan_cache()
    target = repro.Target(device=DEVICE, threads=THREADS)
    for name, kw in CONFIGS:
        graph = from_model(name, **kw)
        compiled = repro.compile(graph, target, cache=cache,
                                 predictor_cache=PRED_CACHE)
        r = compiled.report()
        rows.append(csv_row(
            f"decode_{name}", r.end_to_end_us,
            f"base_us={r.baseline_us:.1f},"
            f"e2e={r.end_to_end_speedup:.2f}x,"
            f"ind={r.individual_speedup:.2f}x,"
            f"warm={int(compiled.from_cache)}"))
        rows += _decision_rows(name, compiled)
        if execute:
            rows += _exec_rows(name, compiled)
    print(f"# plan cache: {cache.hits} hits / {cache.misses} misses "
          f"({cache.root})")
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks.common import bench_main

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-execute", action="store_true",
                    help="skip host execution (planning rows only)")
    args = ap.parse_args()
    bench_main("decode_bench", lambda: run(execute=not args.no_execute))
