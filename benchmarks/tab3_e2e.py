"""Table 3 — end-to-end speedups (VGG16, ResNet-18/34, Inception-v3) with
GPU + 3 CPU threads co-execution.

Paper headline: up to 1.67x / 1.79x / 1.27x / 1.27x average e2e speedups on
Pixel 4 / Pixel 5 / Moto 2022 / OnePlus 11.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import DEVICES, csv_row, get_predictor, plan_cache
from repro.core.networks import NETWORKS
from repro.core.predictor.train import MuxPredictor
from repro.runtime import plan_network_cached

_PAPER_E2E = {
    ("pixel4", "vgg16"): 1.14, ("pixel4", "resnet18"): 1.54,
    ("pixel4", "resnet34"): 1.67, ("pixel4", "inception_v3"): 1.62,
    ("pixel5", "vgg16"): 1.56, ("pixel5", "resnet18"): 1.78,
    ("pixel5", "resnet34"): 1.76, ("pixel5", "inception_v3"): 1.79,
    ("moto2022", "vgg16"): 1.08, ("moto2022", "resnet18"): 1.11,
    ("moto2022", "resnet34"): 1.14, ("moto2022", "inception_v3"): 1.27,
    ("oneplus11", "vgg16"): 1.05, ("oneplus11", "resnet18"): 1.25,
    ("oneplus11", "resnet34"): 1.27, ("oneplus11", "inception_v3"): 1.17,
}


def run() -> list:
    rows = []
    threads = 3
    cache = plan_cache()
    for dev in DEVICES:
        gp = MuxPredictor(get_predictor(dev, "gpu", "linear", whitebox=True),
                          get_predictor(dev, "gpu", "conv", whitebox=True))
        cp = MuxPredictor(
            get_predictor(dev, f"cpu{threads}", "linear", whitebox=False),
            get_predictor(dev, f"cpu{threads}", "conv", whitebox=False))
        for name, fn in NETWORKS.items():
            plan = plan_network_cached(fn(), cp, gp, threads=threads,
                                       cache=cache)
            r = plan.report()
            rows.append(csv_row(
                f"tab3_{dev}_{name}", r.end_to_end_us,
                f"base_ms={r.baseline_us/1e3:.1f},"
                f"ind={r.individual_speedup:.2f}x,"
                f"e2e={r.end_to_end_speedup:.2f}x,"
                f"paper_e2e={_PAPER_E2E[(dev, name)]}"))
    print(f"# plan cache: {cache.hits} hits / {cache.misses} misses "
          f"({cache.root})")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
