"""Table 3 — end-to-end speedups (VGG16, ResNet-18/34, Inception-v3) with
GPU + 3 CPU threads co-execution.

Paper headline: up to 1.67x / 1.79x / 1.27x / 1.27x average e2e speedups on
Pixel 4 / Pixel 5 / Moto 2022 / OnePlus 11.

`--execute` additionally lowers one compiled network through the
`repro.compile` facade and reports executed-vs-predicted latency per op
(predictions model the phone, execution runs on this host — the per-op
ratio's spread is the fidelity signal), then runs EVERY network both ways
through the executor — per-node walk vs fused segment walk — and reports
the wall-time comparison (fused should never lose: same computation,
strictly fewer dispatches and device syncs).
"""
from __future__ import annotations

import repro
from benchmarks.common import DEVICES, csv_row, get_predictor, plan_cache
from repro.core.networks import NETWORKS
from repro.core.predictor.train import MuxPredictor

_PAPER_E2E = {
    ("pixel4", "vgg16"): 1.14, ("pixel4", "resnet18"): 1.54,
    ("pixel4", "resnet34"): 1.67, ("pixel4", "inception_v3"): 1.62,
    ("pixel5", "vgg16"): 1.56, ("pixel5", "resnet18"): 1.78,
    ("pixel5", "resnet34"): 1.76, ("pixel5", "inception_v3"): 1.79,
    ("moto2022", "vgg16"): 1.08, ("moto2022", "resnet18"): 1.11,
    ("moto2022", "resnet34"): 1.14, ("moto2022", "inception_v3"): 1.27,
    ("oneplus11", "vgg16"): 1.05, ("oneplus11", "resnet18"): 1.25,
    ("oneplus11", "resnet34"): 1.27, ("oneplus11", "inception_v3"): 1.17,
}


def run(execute: bool = False, exec_device: str = "moto2022",
        exec_network: str = "resnet18", chain: bool = True) -> list:
    rows = []
    threads = 3
    cache = plan_cache()
    compiled_networks = {}
    for dev in DEVICES:
        gp = MuxPredictor(get_predictor(dev, "gpu", "linear", whitebox=True),
                          get_predictor(dev, "gpu", "conv", whitebox=True))
        cp = MuxPredictor(
            get_predictor(dev, f"cpu{threads}", "linear", whitebox=False),
            get_predictor(dev, f"cpu{threads}", "conv", whitebox=False))
        target = repro.Target(device=dev, threads=threads)
        for name in NETWORKS:
            compiled = repro.compile(name, target, predictors=(cp, gp),
                                     cache=cache)
            compiled_networks[(dev, name)] = compiled
            r = compiled.report()
            rows.append(csv_row(
                f"tab3_{dev}_{name}", r.end_to_end_us,
                f"base_ms={r.baseline_us/1e3:.1f},"
                f"ind={r.individual_speedup:.2f}x,"
                f"e2e={r.end_to_end_speedup:.2f}x,"
                f"paper_e2e={_PAPER_E2E[(dev, name)]}"))
    print(f"# plan cache: {cache.hits} hits / {cache.misses} misses "
          f"({cache.root})")
    if execute:
        rows += _execute_rows(compiled_networks[(exec_device, exec_network)],
                              exec_device, exec_network, chain)
        for name in NETWORKS:
            rows += _fused_rows(compiled_networks[(exec_device, name)],
                                exec_device, name)
    return rows


def _execute_rows(compiled, dev: str, name: str, chain: bool) -> list:
    """Lower one compiled network into actual split execution; one row per
    op (executed wall us vs the plan's predicted us) plus a summary row."""
    rep = compiled.profile(chain=chain, warmup=True)
    rows = []
    for t in rep.timings:
        ratio = (f"{t.wall_us / t.pred_us:.1f}" if t.pred_us > 0
                 else "na")                    # pool units carry no pred
        rows.append(csv_row(
            f"tab3_exec_{dev}_{name}_{t.index:02d}_{t.unit}", t.wall_us,
            f"pred_us={t.pred_us:.1f},ratio={ratio},mode={t.mode},"
            f"split={t.c_fast}/{t.c_slow},"
            f"chained={int(t.chained_input)}"))
    rows.append(csv_row(
        f"tab3_exec_{dev}_{name}_total", rep.wall_us,
        f"pred_us={rep.predicted_us:.1f},"
        f"reshard={rep.reshard_points},elided={rep.elided},"
        f"split_capable={int(rep.split_capable)}"))
    print("# " + rep.fidelity_summary())
    return rows


def _fused_rows(compiled, dev: str, name: str) -> list:
    """Fused (segment walk) vs unfused (per-node walk) wall time for one
    network — best of 2 timed runs each, after the shared warmup."""
    best = {}
    for fused in (False, True):
        reps = [compiled.profile(fused=fused, warmup=True)
                for _ in range(2)]
        best[fused] = min(reps, key=lambda r: r.wall_us)
    ru, rf = best[False], best[True]
    speedup = ru.wall_us / rf.wall_us if rf.wall_us > 0 else float("inf")
    print(f"# {name}: fused {rf.wall_us / 1e3:.1f} ms "
          f"({len(rf.segment_wall_us)} segments, {rf.sync_points} syncs) "
          f"vs unfused {ru.wall_us / 1e3:.1f} ms ({ru.sync_points} syncs) "
          f"-> {speedup:.2f}x")
    return [
        csv_row(f"tab3_exec_{dev}_{name}_unfused", ru.wall_us,
                f"sync={ru.sync_points},reshard={ru.reshard_points},"
                f"elided={ru.elided}"),
        csv_row(f"tab3_exec_{dev}_{name}_fused", rf.wall_us,
                f"segments={len(rf.segment_wall_us)},"
                f"sync={rf.sync_points},reshard={rf.reshard_points},"
                f"elided={rf.elided},speedup={speedup:.2f}x"),
    ]


if __name__ == "__main__":
    import argparse

    from benchmarks.common import bench_main

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--execute", action="store_true",
                    help="execute one cached plan and report per-op "
                         "executed-vs-predicted latency")
    ap.add_argument("--exec-device", default="moto2022", choices=DEVICES)
    ap.add_argument("--exec-network", default="resnet18",
                    choices=sorted(NETWORKS))
    ap.add_argument("--no-chain", action="store_true",
                    help="gather after every co-executed op")
    args = ap.parse_args()
    # --execute writes to a separate suite so plain tab3.json stays a
    # stable row set for cross-PR tracking
    suite = "tab3_e2e" if args.execute else "tab3"
    extra = ({"execute": True, "exec_device": args.exec_device,
              "exec_network": args.exec_network,
              "chain": not args.no_chain} if args.execute else None)
    bench_main(suite, lambda: run(execute=args.execute,
                                  exec_device=args.exec_device,
                                  exec_network=args.exec_network,
                                  chain=not args.no_chain), extra=extra)
