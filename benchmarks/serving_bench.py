"""Serving traffic benchmark — continuous scheduler + plan portfolio vs
the fixed-batch engine (PR 8 headline suite).

Synthetic Poisson traffic (mixed prompt lengths, generation budgets, and
temperatures) is served twice under the same virtual clock:

  * `ContinuousScheduler` with a bucketed `PlanPortfolio` — per-step
    admission/eviction, chunked prefill interleaved with decode, each
    step charged to the smallest covering bucket's plan;
  * `FixedBatchReference` — the fixed-batch engine's semantics (arrival-
    order batches, padded bulk prefill, decode to the longest member,
    head-of-line blocking between batches) priced with one single plan.

Headline rows are p50/p99 request latency, TTFT, and tokens/s for both,
plus the scheduler-vs-fixed ratios the acceptance tracks (the scheduler
must win p99 latency AND throughput at the same arrival rate).  A second,
smaller run simulates a mid-run thermal throttle and reports the
drift-triggered in-place replan with its pre/post bucket fidelity error.

Request latencies are virtual-clock quantities (plan-predicted step
costs on the modeled phone); the scheduler really decodes every token on
this host — the tokens themselves are the correctness witness, not a
host-speed claim.
"""
from __future__ import annotations

import repro
from benchmarks.common import (FULL, MEASUREMENTS_DIR, PLAN_CACHE_DIR,
                               PRED_CACHE, csv_row)
from repro.models import build_model, get_config

ARCH = "codeqwen15_7b"
DEVICE = "moto2022"
MAX_BATCH = 4
MAX_LEN = 48
BUCKETS = ((1, MAX_LEN), (2, MAX_LEN), (MAX_BATCH, MAX_LEN))

N_REQUESTS = 4000 if FULL else 600
RATE = 1500.0                    # req/s on the virtual clock
#: heavy-tailed prompt mix: the fixed-batch engine bulk-prefills every
#: batch to its longest member, so one 12-token prompt makes three short
#: ones pay 12 padded positions each — the scheduler only pays real ones
PROMPT_LENS = (2, 4, 12)
MAX_NEW = (2, 4)
TEMPERATURES = (0.0, 0.0, 0.7)

N_THROTTLE = 120                 # smaller drift-replan run
THROTTLE_RATE = 300.0
THROTTLE_AT_S = 0.08             # ~1/3 in: enough pre-throttle baseline


def _traffic(n: int, seed: int):
    from repro.serving import poisson_requests
    cfg = get_config(ARCH).reduced()
    return poisson_requests(n, rate=RATE if n == N_REQUESTS
                            else THROTTLE_RATE,
                            vocab_size=cfg.vocab_size,
                            prompt_lens=PROMPT_LENS, max_new=MAX_NEW,
                            temperatures=TEMPERATURES, seed=seed)


def _latency_rows(tag: str, rep, derived_extra: str = "") -> list:
    return [
        csv_row(f"serving_{tag}_p99", rep.latency_p(99) * 1e6,
                f"p50_us={rep.latency_p(50) * 1e6:.1f},"
                f"ttft_p50_us={rep.ttft_p(50) * 1e6:.1f},"
                f"ttft_p99_us={rep.ttft_p(99) * 1e6:.1f},"
                f"requests={len(rep.stats)}{derived_extra}"),
        csv_row(f"serving_{tag}_tput", 1e6 / rep.tokens_per_s,
                f"tokens_per_s={rep.tokens_per_s:.1f},"
                f"tokens={rep.total_tokens},steps={rep.steps},"
                f"duration_s={rep.duration_s:.4f}"),
    ]


def run() -> list:
    from repro.serving import (ContinuousScheduler, FixedBatchReference,
                               SchedulerConfig, ThrottleSim)
    import jax

    cfg = get_config(ARCH).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    portfolio = repro.compile_portfolio(
        cfg, repro.Target(device=DEVICE), buckets=BUCKETS,
        cache=PLAN_CACHE_DIR, predictor_cache=PRED_CACHE)
    print(f"# {portfolio}")

    # ---- traffic run: portfolio scheduler vs fixed-batch single plan
    reqs = _traffic(N_REQUESTS, seed=11)
    sched = ContinuousScheduler(
        cfg, model, params, portfolio=portfolio,
        config=SchedulerConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                               fidelity_every=200))
    srep = sched.run(reqs)
    _, largest = portfolio.select(MAX_BATCH, MAX_LEN)
    fixed = FixedBatchReference(largest, max_batch=MAX_BATCH)
    frep = fixed.run(reqs)

    p99_speedup = frep.latency_p(99) / max(srep.latency_p(99), 1e-12)
    tput_speedup = srep.tokens_per_s / max(frep.tokens_per_s, 1e-12)
    wins = int(p99_speedup > 1.0 and tput_speedup > 1.0)
    rows = []
    rows += _latency_rows("sched", srep, f",rate={RATE:.0f}")
    rows += _latency_rows("fixed", frep, f",rate={RATE:.0f}")
    rows.append(csv_row(
        "serving_sched_vs_fixed", srep.latency_p(99) * 1e6,
        f"p99_speedup={p99_speedup:.2f}x,"
        f"tput_speedup={tput_speedup:.2f}x,sched_wins={wins}"))
    rows.append(csv_row(
        "serving_bucket_switches", float(srep.bucket_switches),
        "bucket_steps=" + "|".join(
            f"{t}:{n}" for t, n in sorted(srep.bucket_steps.items()))))
    print(f"# sched p99 {srep.latency_p(99)*1e3:.2f} ms vs fixed "
          f"{frep.latency_p(99)*1e3:.2f} ms ({p99_speedup:.2f}x); tput "
          f"{srep.tokens_per_s:.0f} vs {frep.tokens_per_s:.0f} tok/s "
          f"({tput_speedup:.2f}x)")

    # ---- throttle run: drift-triggered in-place replan
    treqs = _traffic(N_THROTTLE, seed=23)
    tsched = ContinuousScheduler(
        cfg, model, params, portfolio=portfolio,
        measurement_store=MEASUREMENTS_DIR, plan_cache=PLAN_CACHE_DIR,
        config=SchedulerConfig(max_batch=MAX_BATCH, max_len=MAX_LEN,
                               fidelity_every=8, drift_cooldown=3),
        throttle=ThrottleSim(at_s=THROTTLE_AT_S, scale=2.2))
    trep = tsched.run(treqs)
    if trep.replan_events:
        ev = trep.replan_events[0]
        improved = int(ev.post_fidelity is not None
                       and ev.post_fidelity < ev.pre_fidelity)
        rows.append(csv_row(
            "serving_replan", float(len(trep.replan_events)),
            f"bucket={ev.bucket},pre_fid={ev.pre_fidelity:.3f},"
            f"post_fid={ev.post_fidelity if ev.post_fidelity is None else round(ev.post_fidelity, 3)},"
            f"gain_us={ev.predicted_gain_us:.1f},improved={improved}"))
    else:
        rows.append(csv_row("serving_replan", 0.0, "no_replan_triggered"))
    print("# " + trep.summary().replace("\n", "\n# "))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("serving_bench", run)
