"""Tuned-vs-default tile-config benchmark (PR 9 headline suite).

Kernel rows measure each op kind's Pallas lowering wall under the default
blocking and under the autotuned winner from the numerics-preserving grid
(`runtime.autotune.autotune`), on shapes where the default grid is visibly
sub-optimal in interpret mode (grid-step count dominates the wall).  Raw
walls are host-dependent and carry the `_wallclock` suffix so
`bench --compare` skips them; the comparable metric per kind is the
`*_speedup` row (default wall / tuned wall — dimensionless, stable across
hosts of different speeds).

The e2e rows compile the same op chain untuned and with
`repro.compile(..., tune=True)` and execute both: the tuned plan must
reproduce the untuned output **bit-identically** (the preserving grid pins
every reduction-axis block), reported as `identical=1` alongside the wall
delta.  When the planner splits an op across CPU+GPU its co-execution
lowering is tile-independent, so the e2e delta only reflects tiles on the
decisions that stayed dense — the kernel rows are the headline speedup.
"""
from __future__ import annotations

import numpy as np

import repro
from benchmarks.common import csv_row, plan_cache
from repro.core.types import ConvOp, LinearOp
from repro.kernels import registry
from repro.runtime.autotune import (DEFAULT_TUNE_DIR, TuneCache, autotune,
                                    measure_device, measure_tile_us)

DEVICE = "moto2022"
THREADS = 3

#: op shapes where the numerics-preserving grid holds a known win: the
#: default square-ish blocking leaves many grid steps on the table
KERNEL_OPS = (
    ("linear_196x512x512", LinearOp(L=196, C_in=512, C_out=512)),
    ("conv_32x32x64to128", ConvOp(H_in=32, W_in=32, C_in=64, C_out=128)),
)

#: e2e chain: three of the linear shapes above (tuned once, applied thrice)
E2E_OPS = [LinearOp(L=196, C_in=512, C_out=512)] * 3


def _kernel_rows(cache: TuneCache) -> list:
    rows = []
    device, backend = measure_device()
    for name, op in KERNEL_OPS:
        spec = registry.tile_spec(registry.op_kind(op))
        default = spec.default_config(op)
        hits0 = cache.hits
        best = autotune(op, cache=cache, device=device, backend=backend)
        src = "cache" if cache.hits > hits0 else "measured"
        default_us = measure_tile_us(op, None, reps=3)
        tuned_us = measure_tile_us(op, best, reps=3)
        speedup = default_us / tuned_us if tuned_us > 0 else float("inf")
        print(f"# {name}: default {default_us / 1e3:.1f} ms "
              f"[{default.label()}] vs tuned {tuned_us / 1e3:.1f} ms "
              f"[{best.label()}] ({speedup:.2f}x, {src})")
        rows.append(csv_row(f"tune_{name}_default_wallclock", default_us,
                            f"tile={default.label()}"))
        rows.append(csv_row(f"tune_{name}_tuned_wallclock", tuned_us,
                            f"tile={best.label()},src={src}"))
        rows.append(csv_row(f"tune_{name}_speedup", speedup,
                            f"default={default.label()},"
                            f"tuned={best.label()}"))
    return rows


def _e2e_rows(cache: TuneCache) -> list:
    target = repro.Target(device=DEVICE, threads=THREADS)
    pcache = plan_cache()
    base = repro.compile(E2E_OPS, target, cache=pcache)
    tuned = repro.compile(E2E_OPS, target, cache=pcache, tune=True,
                          tune_cache=cache)
    walls = {}
    for label, compiled in (("default", base), ("tuned", tuned)):
        reps = [compiled.profile(fused=True, warmup=True) for _ in range(2)]
        walls[label] = min(r.wall_us for r in reps)
    y = np.asarray(tuned.run(fused=True, warmup=True))
    ref = np.asarray(base.run(fused=True, warmup=True))
    identical = bool(np.array_equal(y, ref))
    tiles = sorted({s.tile.label() for s in tuned.plan.exec_specs()
                    if getattr(s, "tile", None) is not None})
    print(f"# e2e: default {walls['default'] / 1e3:.1f} ms vs tuned "
          f"{walls['tuned'] / 1e3:.1f} ms, tiles={tiles or ['(all default)']}"
          f", {'bit-identical' if identical else 'OUTPUT MISMATCH'}")
    return [
        csv_row("tune_e2e_default_wallclock", walls["default"],
                f"key={base.key}"),
        csv_row("tune_e2e_tuned_wallclock", walls["tuned"],
                f"key={tuned.key},tune={tuned.provenance.tune},"
                f"tiles={'|'.join(tiles) or 'none'},"
                f"identical={int(identical)}"),
    ]


def run() -> list:
    cache = TuneCache(DEFAULT_TUNE_DIR)
    rows = _kernel_rows(cache)
    rows += _e2e_rows(cache)
    print(f"# tune cache: {cache.hits} hits / {cache.misses} misses "
          f"({cache.root})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main

    bench_main("tune_bench", run)
