"""Table 4 — ablation on Moto 2022: (a) white-box feature augmentation,
(b) SVM-polling sync vs the original event-notification overhead.

Paper: linear 3-thread speedup 1.44x (ours) -> 1.37x (w/o augmentation) ->
0.88x (original overhead); augmentation cuts linear MAPE 9.3% -> 4.4%.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import FULL, csv_row, get_predictor
from repro.core.partitioner import optimal_partition, speedup_vs_gpu
from repro.core.predictor import mape, measure_ops, sample_linear_ops
from repro.core.predictor.dataset import eval_linear_ops
from repro.core.sync import SyncMechanism

N_OPS = 150 if FULL else 40


def run() -> list:
    dev = "moto2022"
    threads = 3
    rows = []

    # (a) prediction ablation
    test = sample_linear_ops(300, seed=55)
    y = measure_ops(test, dev, "gpu", seed=66)
    wb = get_predictor(dev, "gpu", "linear", whitebox=True)
    bb = get_predictor(dev, "gpu", "linear", whitebox=False)
    rows.append(csv_row("tab4_mape_whitebox", mape(wb.predict(test), y) * 100,
                        "paper=4.4pct"))
    rows.append(csv_row("tab4_mape_blackbox", mape(bb.predict(test), y) * 100,
                        "paper=9.3pct"))

    # (b) speedup ablation
    rng = np.random.default_rng(4)
    pool = eval_linear_ops()
    ops = [pool[i] for i in rng.choice(len(pool), N_OPS, replace=False)]
    cp = get_predictor(dev, f"cpu{threads}", "linear", whitebox=False)

    def avg_speedup(pred_gpu, decide_mech, pay_mech):
        """Decisions are made under `decide_mech`; the system pays
        `pay_mech`.  The paper's "Original Overhead" row partitions as if
        synchronization were cheap but executes with event notification —
        that mismatch is what drives its speedups below 1.0x."""
        return float(np.mean([
            speedup_vs_gpu(optimal_partition(o, cp, pred_gpu,
                                             mechanism=decide_mech),
                           dev, threads, mechanism=pay_mech)
            for o in ops]))

    s_ours = avg_speedup(wb, SyncMechanism.SVM_POLL, SyncMechanism.SVM_POLL)
    s_noaug = avg_speedup(bb, SyncMechanism.SVM_POLL,
                          SyncMechanism.SVM_POLL)
    s_event = avg_speedup(wb, SyncMechanism.SVM_POLL, SyncMechanism.EVENT)
    rows.append(csv_row("tab4_speedup_ours", s_ours * 1000,
                        f"{s_ours:.2f}x(paper=1.44)"))
    rows.append(csv_row("tab4_speedup_no_augment", s_noaug * 1000,
                        f"{s_noaug:.2f}x(paper=1.37)"))
    rows.append(csv_row("tab4_speedup_event_overhead", s_event * 1000,
                        f"{s_event:.2f}x(paper=0.88)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("tab4", run)
