"""Table 2 — co-execution speedups: GBDT-predicted partitioning vs grid
search, per device and CPU thread count.

Paper headline: Pixel 5 linear 3 threads GBDT 1.89x vs Search 2.01x.
Grid search is evaluated on a subsample (as in the paper, 10% of cases).
"""
from __future__ import annotations

import numpy as np

import repro
from benchmarks.common import DEVICES, FULL, csv_row, get_predictor, plan_cache
from repro.core.partitioner import speedup_vs_gpu_batch
from repro.core.predictor.dataset import eval_conv_ops, eval_linear_ops

_PAPER = {  # (device, kind, threads) -> (gbdt, search)
    ("pixel4", "linear", 3): (1.84, 1.92),
    ("pixel5", "linear", 3): (1.89, 2.01),
    ("moto2022", "linear", 3): (1.44, 1.49),
    ("oneplus11", "linear", 3): (1.26, 1.35),
    ("pixel4", "conv", 3): (1.69, 1.79),
    ("pixel5", "conv", 3): (1.75, 1.87),
    ("moto2022", "conv", 3): (1.39, 1.46),
    ("oneplus11", "conv", 3): (1.35, 1.40),
}

N_PRED = 200 if FULL else 40
N_GRID = 40 if FULL else 12


def _subsample(ops, n, seed):
    rng = np.random.default_rng(seed)
    idx = rng.choice(len(ops), size=min(n, len(ops)), replace=False)
    return [ops[i] for i in idx]


def run() -> list:
    rows = []
    cache = plan_cache()
    # paper-scale eval sets: 2,039 linear / 2,051-class conv constructions
    pool = {"linear": _subsample(eval_linear_ops(), 2039, seed=0),
            "conv": eval_conv_ops()}
    for dev in DEVICES:
        for kind in ("linear", "conv"):
            gp = get_predictor(dev, "gpu", kind, whitebox=True)
            for threads in (1, 2, 3):
                cp = get_predictor(dev, f"cpu{threads}", kind,
                                   whitebox=False)
                # seed=0 keeps the grid provenance identical to the
                # pre-facade grid_partition_ops_cached default
                target = repro.Target(device=dev, threads=threads, seed=0)
                ops_p = _subsample(pool[kind], N_PRED, seed=threads)
                decs = repro.compile(ops_p, target, predictors=(cp, gp),
                                     cache=cache).decisions
                sp = np.mean(speedup_vs_gpu_batch(decs, dev, threads))
                # score grid search on a subset of the SAME ops so the
                # comparison is apples-to-apples
                ops_g = ops_p[:N_GRID]
                gdecs = repro.compile(ops_g, target, mode="grid",
                                      cache=cache).decisions
                sg = np.mean(speedup_vs_gpu_batch(gdecs, dev, threads))
                paper = _PAPER.get((dev, kind, threads), ("", ""))
                rows.append(csv_row(
                    f"tab2_{dev}_{kind}_{threads}t", sp * 1000,
                    f"gbdt={sp:.2f}x,search={sg:.2f}x,"
                    f"paper={paper[0]}/{paper[1]}"))
    print(f"# plan cache: {cache.hits} hits / {cache.misses} misses "
          f"({cache.root})")
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("tab2", run)
