"""Fig. 6 — the two causes of discontinuity on mobile GPUs.

(a) workgroup-count/latency correlation for linear ops (50, 768, C);
(b) the conv kernel switch to Winograd at C_out = 128 for 3x3 conv on
    (64, 64, 128) input.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.simulator import DEVICES, dispatch_for, true_latency_us
from repro.core.types import ConvOp, LinearOp


def run() -> list:
    dev = "oneplus11"
    spec = DEVICES[dev]
    wgs, lats = [], []
    for c in range(256, 2049, 8):
        op = LinearOp(50, 768, c)
        wgs.append(dispatch_for(op, spec).wg_count)
        lats.append(true_latency_us(op, dev, "gpu"))
    corr = float(np.corrcoef(wgs, lats)[0, 1])

    below = ConvOp(64, 64, 128, 120, 3, 1)
    above = ConvOp(64, 64, 128, 136, 3, 1)
    k_below = dispatch_for(below, spec).kernel
    k_above = dispatch_for(above, spec).kernel
    return [
        csv_row("fig6a_wg_latency_corr", corr * 100,
                "corr_pct(workgroups,latency)"),
        csv_row("fig6b_conv120", true_latency_us(below, dev, "gpu"),
                f"kernel={k_below}"),
        csv_row("fig6b_conv136", true_latency_us(above, dev, "gpu"),
                f"kernel={k_above}(switch_at_128)"),
    ]


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("fig6", run)
