"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes one JSON report
per suite under reports/bench/ (see benchmarks.common.write_bench_report).
Set BENCH_FULL=1 for paper-scale datasets (slower); default is a reduced
but representative run.

    PYTHONPATH=src python -m benchmarks.run [--only tab2] [--list]

``--list`` prints the registered suite names (one per line) and exits 0 —
CI enumerates suites from here instead of hard-coding them.  Suites
resolve lazily: listing never imports jax or the suite modules.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

#: suite name -> module exposing `run()` (and optionally `measurements()`)
SUITES = {
    "fig2": "benchmarks.fig2_crossover",
    "fig5": "benchmarks.fig5_prediction",
    "fig6": "benchmarks.fig6_discontinuity",
    "fig7": "benchmarks.fig7_importance",
    "tab1": "benchmarks.tab1_mape",
    "tab2": "benchmarks.tab2_speedup",
    "tab3": "benchmarks.tab3_e2e",
    "tab4": "benchmarks.tab4_ablation",
    "roofline": "benchmarks.roofline_report",
    "calibration": "benchmarks.calibration_bench",
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro bench")
    ap.add_argument("--only", choices=list(SUITES), default=None)
    ap.add_argument("--list", action="store_true",
                    help="print registered suite names and exit")
    args = ap.parse_args(argv)
    if args.list:
        for name in SUITES:
            print(name)
        return 0
    names = [args.only] if args.only else list(SUITES)

    from benchmarks.common import write_bench_report

    print("name,us_per_call,derived")
    for name in names:
        mod = importlib.import_module(SUITES[name])
        t0 = time.time()
        try:
            rows = [str(r) for r in mod.run()]
            for row in rows:
                print(row)
        except Exception as e:                       # noqa: BLE001
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
            raise
        wall = time.time() - t0
        print(f"{name}_wallclock,{wall*1e6:.0f},seconds={wall:.1f}")
        # a suite that collects unified-schema records exposes a module-
        # level `measurements()` next to its `run` — one registration
        # point shared with the standalone bench_main entry
        measurements_fn = getattr(mod, "measurements", None)
        path = write_bench_report(
            name, rows, extra={"wallclock_s": round(wall, 2)},
            measurements=measurements_fn() if measurements_fn else None)
        print(f"# wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
