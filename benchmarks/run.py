"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes one JSON report
per suite under reports/bench/ (see benchmarks.common.write_bench_report).
Set BENCH_FULL=1 for paper-scale datasets (slower); default is a reduced
but representative run.

    PYTHONPATH=src python -m benchmarks.run [--only tab2]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import (calibration_bench, fig2_crossover, fig5_prediction,
                        fig6_discontinuity, fig7_importance, roofline_report,
                        tab1_mape, tab2_speedup, tab3_e2e, tab4_ablation)

SUITES = {
    "fig2": fig2_crossover.run,
    "fig5": fig5_prediction.run,
    "fig6": fig6_discontinuity.run,
    "fig7": fig7_importance.run,
    "tab1": tab1_mape.run,
    "tab2": tab2_speedup.run,
    "tab3": tab3_e2e.run,
    "tab4": tab4_ablation.run,
    "roofline": roofline_report.run,
    "calibration": calibration_bench.run,
}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro bench")
    ap.add_argument("--only", choices=list(SUITES), default=None)
    args = ap.parse_args(argv)
    names = [args.only] if args.only else list(SUITES)

    from benchmarks.common import write_bench_report

    print("name,us_per_call,derived")
    for name in names:
        t0 = time.time()
        try:
            rows = [str(r) for r in SUITES[name]()]
            for row in rows:
                print(row)
        except Exception as e:                       # noqa: BLE001
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
            raise
        wall = time.time() - t0
        print(f"{name}_wallclock,{wall*1e6:.0f},seconds={wall:.1f}")
        # a suite that collects unified-schema records exposes a module-
        # level `measurements()` next to its `run` — one registration
        # point shared with the standalone bench_main entry
        mod = sys.modules[SUITES[name].__module__]
        measurements_fn = getattr(mod, "measurements", None)
        path = write_bench_report(
            name, rows, extra={"wallclock_s": round(wall, 2)},
            measurements=measurements_fn() if measurements_fn else None)
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
