"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes one JSON report
per suite under reports/bench/ (see benchmarks.common.write_bench_report).
Set BENCH_FULL=1 for paper-scale datasets (slower); default is a reduced
but representative run.

    PYTHONPATH=src python -m benchmarks.run [--only tab2] [--list]

``--list`` prints the registered suite names (one per line) and exits 0 —
CI enumerates suites from here instead of hard-coding them.  Suites
resolve lazily: listing never imports jax or the suite modules.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

#: suite name -> module exposing `run()` (and optionally `measurements()`)
SUITES = {
    "fig2": "benchmarks.fig2_crossover",
    "fig5": "benchmarks.fig5_prediction",
    "fig6": "benchmarks.fig6_discontinuity",
    "fig7": "benchmarks.fig7_importance",
    "tab1": "benchmarks.tab1_mape",
    "tab2": "benchmarks.tab2_speedup",
    "tab3": "benchmarks.tab3_e2e",
    "tab4": "benchmarks.tab4_ablation",
    "roofline": "benchmarks.roofline_report",
    "calibration": "benchmarks.calibration_bench",
    "decode_bench": "benchmarks.decode_bench",
    "serving_bench": "benchmarks.serving_bench",
    "tune_bench": "benchmarks.tune_bench",
}


def _committed_metrics(suite: str):
    """Metric rows of the last *committed* reports/bench/<suite>.json
    (via `git show HEAD:`), or None when the suite has no committed
    baseline yet."""
    import json
    import subprocess
    rel = f"reports/bench/{suite}.json"
    try:
        out = subprocess.run(["git", "show", f"HEAD:{rel}"],
                             cwd=Path(__file__).resolve().parents[1],
                             capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    if out.returncode != 0 or not out.stdout.strip():
        return None
    try:
        return json.loads(out.stdout).get("metrics")
    except json.JSONDecodeError:
        return None


def compare_suite(suite: str, rows, tolerance=None) -> list:
    """Diff this run's metrics against the committed baseline report.

    Returns the regressed metric names: shared rows whose us_per_call grew
    by more than `tolerance` (fraction).  With tolerance None every drift
    is printed as a warning and nothing counts as a regression (CI's
    default is warn-only; gate by passing --tolerance).
    """
    from benchmarks.common import parse_rows
    base = _committed_metrics(suite)
    if base is None:
        print(f"# compare {suite}: no committed baseline at HEAD "
              f"(reports/bench/{suite}.json) — skipping")
        return []
    old = {m["name"]: float(m["us_per_call"]) for m in base}
    regressed = []
    for m in parse_rows([str(r) for r in rows]):
        name, cur = m["name"], float(m["us_per_call"])
        if name.endswith("_wallclock") or name not in old or old[name] <= 0:
            continue
        rel = (cur - old[name]) / old[name]
        if tolerance is not None and rel > tolerance:
            regressed.append(name)
            print(f"# compare {suite} REGRESSION {name}: "
                  f"{old[name]:.2f} -> {cur:.2f} us ({rel:+.1%}, "
                  f"tolerance {tolerance:.0%})")
        elif abs(rel) > 0.05:
            print(f"# compare {suite} {name}: "
                  f"{old[name]:.2f} -> {cur:.2f} us ({rel:+.1%})")
    if not regressed:
        print(f"# compare {suite}: ok vs {len(old)} committed metrics")
    return regressed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro bench")
    ap.add_argument("--only", choices=list(SUITES), default=None)
    ap.add_argument("--list", action="store_true",
                    help="print registered suite names and exit")
    ap.add_argument("--compare", action="store_true",
                    help="diff each suite's metrics against the last "
                         "committed reports/bench/<suite>.json")
    ap.add_argument("--tolerance", type=float, default=None,
                    help="with --compare: exit non-zero when any shared "
                         "metric's us_per_call grows by more than this "
                         "fraction (e.g. 0.25); default is warn-only")
    args = ap.parse_args(argv)
    if args.list:
        for name in SUITES:
            print(name)
        return 0
    names = [args.only] if args.only else list(SUITES)

    from benchmarks.common import write_bench_report

    print("name,us_per_call,derived")
    regressions = []
    for name in names:
        mod = importlib.import_module(SUITES[name])
        t0 = time.time()
        try:
            rows = [str(r) for r in mod.run()]
            for row in rows:
                print(row)
        except Exception as e:                       # noqa: BLE001
            print(f"{name}_ERROR,0.0,{type(e).__name__}:{e}")
            raise
        if args.compare:
            regressions += compare_suite(name, rows,
                                         tolerance=args.tolerance)
        wall = time.time() - t0
        print(f"{name}_wallclock,{wall*1e6:.0f},seconds={wall:.1f}")
        # a suite that collects unified-schema records exposes a module-
        # level `measurements()` next to its `run` — one registration
        # point shared with the standalone bench_main entry
        measurements_fn = getattr(mod, "measurements", None)
        path = write_bench_report(
            name, rows, extra={"wallclock_s": round(wall, 2)},
            measurements=measurements_fn() if measurements_fn else None)
        print(f"# wrote {path}")
    from repro.analysis import rejections
    if rejections.total():
        # stale/corrupt cache entries the suites hit (each was recompiled)
        print(f"# {rejections.summary()}")
    if regressions:
        print(f"# {len(regressions)} metric(s) regressed beyond "
              f"--tolerance: {', '.join(regressions)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
