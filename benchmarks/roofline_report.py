"""Roofline summary table from the dry-run artifacts (deliverable g).

Reads reports/dryrun/*.json (produced by `python -m repro.launch.dryrun`)
and emits one row per (arch x shape x mesh) with the three roofline terms,
the dominant bottleneck, and the useful-FLOPs ratio.  This benchmark does
not lower anything itself — run the dry-run first.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import REPORTS, csv_row

DRYRUN = REPORTS / "dryrun"


def run() -> list:
    rows = []
    if not DRYRUN.exists():
        return [csv_row("roofline_missing", 0.0,
                        "run: python -m repro.launch.dryrun --all first")]
    for path in sorted(DRYRUN.glob("*.json")):
        rec = json.loads(path.read_text())
        if rec.get("status") == "skipped":
            rows.append(csv_row(f"roofline_{path.stem}", 0.0,
                                f"SKIP:{rec['reason'][:60]}"))
            continue
        dom = rec["bottleneck"]
        t_dom = rec[f"t_{dom}_s"] * 1e6
        rows.append(csv_row(
            f"roofline_{path.stem}", t_dom,
            f"bottleneck={dom},compute_ms={rec['t_compute_s']*1e3:.1f},"
            f"memory_ms={rec['t_memory_s']*1e3:.1f},"
            f"collective_ms={rec['t_collective_s']*1e3:.1f},"
            f"useful={rec['useful_flops_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("roofline", run)
