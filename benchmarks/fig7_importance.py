"""Fig. 7 — GBDT gain importance of input features (conv, Moto 2022).

Paper claim: workgroup size / workgroup count rank among the top features,
motivating dispatch-feature augmentation.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, get_predictor
from repro.core.predictor.features import feature_names


def run() -> list:
    p = get_predictor("moto2022", "gpu", "conv", whitebox=True)
    names = feature_names("conv", whitebox=True)
    gains = np.zeros(len(names))
    for model in p.models.values():
        if model.feature_gain_ is not None \
                and len(model.feature_gain_) == len(names):
            gains += model.feature_gain_
    order = np.argsort(gains)[::-1][:8]
    rows = []
    dispatch_in_top8 = 0
    for rank, idx in enumerate(order):
        name = names[idx]
        if name in ("wg_size", "wg_count", "grid_x", "grid_y", "waves",
                    "wave_quant", "occupancy", "wg_x", "wg_y",
                    "log_padded_flops"):
            dispatch_in_top8 += 1
        rows.append(csv_row(f"fig7_rank{rank + 1}", float(gains[idx]),
                            name))
    rows.append(csv_row("fig7_dispatch_features_in_top8",
                        float(dispatch_in_top8), "paper:wg_features_rank_high"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("fig7", run)
