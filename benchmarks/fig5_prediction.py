"""Fig. 3/5 — latency-prediction quality around the spike region.

Paper claim: config-only GBDT misses the spikes in C_out in [2048, 2560]
(input (50, 768), OnePlus 11); dispatch-feature augmentation captures them,
improving the ViT-Base-32 partitioning from ~1.02x to ~1.29x-class speedup.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, get_predictor
from repro.core.predictor import measure_ops, mape
from repro.core.types import LinearOp


def run() -> list:
    dev = "oneplus11"
    ops = [LinearOp(50, 768, c) for c in range(2048, 2561, 4)]
    y = measure_ops(ops, dev, "gpu")
    bb = get_predictor(dev, "gpu", "linear", whitebox=False)
    wb = get_predictor(dev, "gpu", "linear", whitebox=True)
    m_bb = mape(bb.predict(ops), y)
    m_wb = mape(wb.predict(ops), y)
    spike = float(np.max(y) / np.min(y))
    return [
        csv_row("fig5_spike_ratio", float(np.max(y)),
                f"max/min={spike:.2f}(paper~1.85)"),
        csv_row("fig5_blackbox_mape", m_bb * 100, "percent"),
        csv_row("fig5_whitebox_mape", m_wb * 100,
                f"improvement={m_bb/max(m_wb,1e-9):.2f}x"),
    ]


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("fig5", run)
