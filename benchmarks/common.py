"""Shared benchmark infrastructure: predictor training with disk cache.

All paper benchmarks share one pool of trained GBDT predictors per
(device, backend, op kind, whitebox) tuple, cached under reports/predictors
so repeated benchmark runs are fast.  Scale knobs (--full) switch between
a CI-sized run and the paper-scale dataset (12,500 configs per op kind).
"""
from __future__ import annotations

import os
import sys
import time
from pathlib import Path
from typing import Dict, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.predictor import (LatencyPredictor, sample_conv_ops,   # noqa: E402
                                  sample_linear_ops, train_predictor)
from repro.core.predictor.gbdt import GBDTParams                      # noqa: E402
from repro.runtime import PlanCache                                   # noqa: E402

REPORTS = Path(__file__).resolve().parents[1] / "reports"
PRED_CACHE = REPORTS / "predictors"
PLAN_CACHE_DIR = REPORTS / "plans"


def plan_cache() -> PlanCache:
    """Fresh handle on the shared on-disk plan cache (counters start at 0)."""
    return PlanCache(PLAN_CACHE_DIR)

FULL = os.environ.get("BENCH_FULL", "0") == "1"
N_TRAIN = 10_000 if FULL else 2_500
N_ESTIMATORS = 300 if FULL else 120

DEVICES = ("pixel4", "pixel5", "moto2022", "oneplus11")

_memo: Dict[Tuple, LatencyPredictor] = {}


def train_ops(kind: str, seed: int = 1):
    if kind == "linear":
        return sample_linear_ops(N_TRAIN, seed=seed)
    return sample_conv_ops(N_TRAIN, seed=seed)


def get_predictor(device: str, backend: str, kind: str,
                  whitebox: bool = True) -> LatencyPredictor:
    key = (device, backend, kind, whitebox, N_TRAIN, N_ESTIMATORS)
    if key in _memo:
        return _memo[key]
    tag = f"{device}_{backend}_{kind}_{'wb' if whitebox else 'bb'}" \
          f"_{N_TRAIN}_{N_ESTIMATORS}.pkl"
    path = PRED_CACHE / tag
    if path.exists():
        p = LatencyPredictor.load(path)
    else:
        t0 = time.time()
        p = train_predictor(train_ops(kind), device, backend,
                            whitebox=whitebox,
                            params=GBDTParams(n_estimators=N_ESTIMATORS))
        print(f"  [train] {tag} ({time.time()-t0:.0f}s)")
        p.save(path)
    _memo[key] = p
    return p


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"
