"""Shared benchmark infrastructure: predictor training with disk cache,
plus machine-readable result reports.

All paper benchmarks share one pool of trained GBDT predictors per
(device, backend, op kind, whitebox) tuple, cached under reports/predictors
so repeated benchmark runs are fast.  Scale knobs (--full) switch between
a CI-sized run and the paper-scale dataset (12,500 configs per op kind).

Every suite also writes a JSON report under reports/bench/<suite>.json
(suite name, host device, git sha, parsed metric rows) so the perf
trajectory is trackable across PRs: `bench_main` is the standalone-script
entry point, and `benchmarks.run` calls `write_bench_report` per suite.
"""
from __future__ import annotations

import datetime
import json
import os
import platform
import subprocess
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.predictor import (LatencyPredictor, sample_conv_ops,   # noqa: E402
                                  sample_linear_ops, train_predictor)
from repro.core.predictor.gbdt import GBDTParams                      # noqa: E402
from repro.measure import MeasurementRecord, MeasurementStore         # noqa: E402
from repro.runtime import PlanCache                                   # noqa: E402

ROOT = Path(__file__).resolve().parents[1]
REPORTS = ROOT / "reports"
PRED_CACHE = REPORTS / "predictors"
PLAN_CACHE_DIR = REPORTS / "plans"
BENCH_REPORTS = REPORTS / "bench"
MEASUREMENTS_DIR = REPORTS / "measurements"


def plan_cache() -> PlanCache:
    """Fresh handle on the shared on-disk plan cache (counters start at 0)."""
    return PlanCache(PLAN_CACHE_DIR)


def measurement_store() -> MeasurementStore:
    """Handle on the shared on-disk measurement store (JSONL per plan)."""
    return MeasurementStore(MEASUREMENTS_DIR)

FULL = os.environ.get("BENCH_FULL", "0") == "1"
N_TRAIN = 10_000 if FULL else 2_500
N_ESTIMATORS = 300 if FULL else 120

DEVICES = ("pixel4", "pixel5", "moto2022", "oneplus11")

_memo: Dict[Tuple, LatencyPredictor] = {}


def train_ops(kind: str, seed: int = 1):
    if kind == "linear":
        return sample_linear_ops(N_TRAIN, seed=seed)
    return sample_conv_ops(N_TRAIN, seed=seed)


def get_predictor(device: str, backend: str, kind: str,
                  whitebox: bool = True) -> LatencyPredictor:
    key = (device, backend, kind, whitebox, N_TRAIN, N_ESTIMATORS)
    if key in _memo:
        return _memo[key]
    tag = f"{device}_{backend}_{kind}_{'wb' if whitebox else 'bb'}" \
          f"_{N_TRAIN}_{N_ESTIMATORS}.pkl"
    path = PRED_CACHE / tag
    if path.exists():
        p = LatencyPredictor.load(path)
    else:
        t0 = time.time()
        p = train_predictor(train_ops(kind), device, backend,
                            whitebox=whitebox,
                            params=GBDTParams(n_estimators=N_ESTIMATORS))
        print(f"  [train] {tag} ({time.time()-t0:.0f}s)")
        p.save(path)
    _memo[key] = p
    return p


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"


# ------------------------------------------------------- JSON reporting

def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=ROOT,
                             capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        return None


def parse_rows(rows: List[str]) -> List[Dict[str, object]]:
    """`name,us_per_call,derived` CSV rows -> metric dicts (the derived
    field may itself contain commas, hence maxsplit)."""
    out = []
    for row in rows:
        name, us, derived = str(row).split(",", 2)
        out.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    return out


def write_bench_report(suite: str, rows: List[str], *,
                       extra: Optional[Dict[str, object]] = None,
                       measurements: Optional[List[MeasurementRecord]] = None
                       ) -> Path:
    """Persist one suite's results as reports/bench/<suite>.json.

    `measurements` embeds unified-schema records in the report (the
    executor/calibration suites carry their raw per-op measurements
    alongside the derived CSV rows); `load_bench_measurements` reads them
    back as `MeasurementRecord`s.
    """
    doc = {
        "suite": suite,
        "device": platform.processor() or platform.machine(),
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "git_sha": _git_sha(),
        "generated_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "full": FULL,
        "metrics": parse_rows(rows),
    }
    if measurements:
        doc["measurements"] = [r.to_json() for r in measurements]
    if extra:
        doc.update(extra)
    BENCH_REPORTS.mkdir(parents=True, exist_ok=True)
    path = BENCH_REPORTS / f"{suite}.json"
    path.write_text(json.dumps(doc, indent=1))
    return path


def load_bench_measurements(suite: str) -> List[MeasurementRecord]:
    """The unified-schema records a suite's JSON report embedded (empty
    for suites that only wrote CSV rows)."""
    path = BENCH_REPORTS / f"{suite}.json"
    if not path.exists():
        return []
    doc = json.loads(path.read_text())
    return [MeasurementRecord.from_json(d)
            for d in doc.get("measurements", [])]


def bench_main(suite: str, run_fn, *,
               extra: Optional[Dict[str, object]] = None,
               measurements_fn=None) -> List[str]:
    """Standalone-script entry point: print CSV rows AND write the JSON
    report (used by every tab*/fig* script's __main__).  A suite that
    collects unified-schema measurements passes `measurements_fn` (called
    after `run_fn`, returns the records to embed)."""
    rows = [str(r) for r in run_fn()]
    print("\n".join(rows))
    measurements = measurements_fn() if measurements_fn else None
    path = write_bench_report(suite, rows, extra=extra,
                              measurements=measurements)
    print(f"# wrote {path.relative_to(ROOT)}")
    return rows
