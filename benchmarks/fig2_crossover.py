"""Fig. 2 — CPU vs GPU latency for linear ops (50, 3072) x (3072, C_out).

Paper claim (OnePlus 11): the 3-thread CPU beats the GPU for C_out < ~425.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row
from repro.core.simulator import true_latency_us
from repro.core.types import LinearOp


def run() -> list:
    rows = []
    # the GPU curve is spiky, so the curves cross more than once; report
    # the last C_out where the CPU still wins (the paper's ~425 figure)
    wins = [c for c in range(64, 1537, 16)
            if true_latency_us(LinearOp(50, 3072, c), "oneplus11", "cpu3")
            < true_latency_us(LinearOp(50, 3072, c), "oneplus11", "gpu")]
    crossover = max(wins) if wins else 0
    op = LinearOp(50, 3072, 425)
    rows.append(csv_row("fig2_gpu_at_425",
                        true_latency_us(op, "oneplus11", "gpu"),
                        f"crossover_cout={crossover}"))
    rows.append(csv_row("fig2_cpu3_at_425",
                        true_latency_us(op, "oneplus11", "cpu3"),
                        "paper_crossover~425"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import bench_main
    bench_main("fig2", run)
