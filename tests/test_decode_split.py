"""Differential tests for typed-axis co-execution (head / kv-block /
ssm-state splits) and the registry's split validation.

Kernel- and executor-level split lowerings need >1 device, so they run in
a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(same idiom as test_executor.py); validation, codec round-trip, and
explain() labels run in-process.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.partitioner import PartitionDecision
from repro.core.types import AttnOp, SSMOp
from repro.graph.frontends import from_model
from repro.kernels import registry
from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                build_graph_schedule, segments_json)

ATTN = AttnOp(H=8, S=512, KV=4, hd=16)
SSM = SSMOp(T=64, H=8, hd=8, N=16)


# ------------------------------------------------ registry-level rejection

def test_head_split_must_respect_gqa_grouping():
    # H=8 / KV=4 -> GQA groups of 2 query heads; odd splits are illegal
    for bad in (1, 3, 5, 7):
        with pytest.raises(ValueError, match="granularity"):
            registry.validate_axis_split(ATTN, "head", bad)
    for ok in (0, 2, 4, 6, 8):
        registry.validate_axis_split(ATTN, "head", ok)


def test_head_split_needs_multiple_gqa_groups():
    mha = AttnOp(H=4, S=512, KV=1, hd=16)     # one KV head = one group
    with pytest.raises(ValueError, match="unavailable"):
        registry.validate_axis_split(mha, "head", 2)


def test_ssm_state_split_lane_alignment():
    misaligned = SSMOp(T=64, H=8, hd=12, N=16)      # 12 % 8 != 0
    with pytest.raises(ValueError, match="unavailable|hd"):
        registry.validate_axis_split(misaligned, "ssm-state", 4)
    registry.validate_axis_split(SSM, "ssm-state", 4)


def test_kv_block_split_gates_short_and_windowed_caches():
    short = AttnOp(H=8, S=128, KV=4, hd=16)         # S < KV_BLOCK_MIN_S
    with pytest.raises(ValueError, match="unavailable"):
        registry.validate_axis_split(short, "kv-block", 64)
    windowed = AttnOp(H=8, S=512, KV=4, hd=16, window=256)
    with pytest.raises(ValueError, match="unavailable"):
        registry.validate_axis_split(windowed, "kv-block", 256)
    registry.validate_axis_split(ATTN, "kv-block", 256)


def test_axis_split_out_of_range_rejected():
    with pytest.raises(ValueError, match="out of range"):
        registry.validate_axis_split(ATTN, "head", 9)
    with pytest.raises(ValueError, match="out of range"):
        registry.validate_axis_split(SSM, "ssm-state", -1)


def test_illegal_split_cannot_enter_a_schedule():
    g = from_model("tiny_decoder", cache_len=512)
    decisions, opaque = _typed_decisions(g)
    attn = next(n for n in g if n.kind == "attention")
    decisions[attn.id] = PartitionDecision(
        op=attn.op, c_cpu=attn.op.H - 1, c_gpu=1,   # breaks GQA grouping
        pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0, axis="head")
    with pytest.raises(ValueError, match="granularity"):
        build_graph_schedule(g, decisions, opaque)


# --------------------------------------------- codec round-trip + explain

def _forced_plan(g, decisions, opaque=None):
    prov = PlanProvenance(
        device="moto2022", threads=3, mechanism="svm_poll", step=8, seed=1,
        network_fingerprint=g.fingerprint(), predictor_checksum="")
    return CoexecPlan(
        provenance=prov,
        schedule=build_graph_schedule(g, decisions, opaque or {}),
        graph_json=None if g.is_unit_chain() else g.to_json(),
        segments=segments_json(g, decisions))


def _typed_decisions(g):
    decisions, opaque = {}, {}
    for n in g:
        if n.kind in ("linear", "conv"):
            c = n.op.C_out
            decisions[n.id] = PartitionDecision(
                op=n.op, c_cpu=c // 4, c_gpu=c - c // 4,
                pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0)
        elif n.kind == "attention":
            decisions[n.id] = PartitionDecision(
                op=n.op.with_mode("streaming"), c_cpu=n.op.H // 2,
                c_gpu=n.op.H // 2, pred_cpu_us=1.0, pred_gpu_us=1.0,
                pred_total_us=2.0, axis="head")
        elif n.kind == "ssm":
            decisions[n.id] = PartitionDecision(
                op=n.op.with_mode("recurrent"), c_cpu=n.op.H // 2,
                c_gpu=n.op.H // 2, pred_cpu_us=1.0, pred_gpu_us=1.0,
                pred_total_us=2.0, axis="ssm-state")
    return decisions, opaque


def test_axis_and_mode_roundtrip_through_plan_json():
    g = from_model("tiny_hybrid", blocks=2, cache_len=512)
    decisions, opaque = _typed_decisions(g)
    plan = _forced_plan(g, decisions, opaque)
    blob = plan.dumps()
    back = CoexecPlan.loads(blob)
    assert back.dumps() == blob                      # codec is bit-stable
    for nid, dec in decisions.items():
        got = back.decisions_by_node[nid]
        assert got.axis == dec.axis, nid
        assert getattr(got.op, "mode", None) == getattr(dec.op, "mode",
                                                        None), nid
        assert (got.c_cpu, got.c_gpu) == (dec.c_cpu, dec.c_gpu), nid


def test_channel_only_plans_serialize_without_axis_or_mode_keys():
    """Pre-axis byte compatibility: a pure conv/linear plan must not leak
    the new keys into its JSON (cached plans stay byte-identical)."""
    from repro.core.networks import NETWORKS
    from repro.graph.ir import from_units
    g = from_units(NETWORKS["resnet18"]())
    decisions, _ = _typed_decisions(g)
    blob = _forced_plan(g, decisions).dumps()
    assert '"axis"' not in blob
    assert '"mode"' not in blob


def test_explain_prints_axis_split_and_mode():
    import repro
    g = from_model("tiny_hybrid", blocks=2, cache_len=512)
    decisions, opaque = _typed_decisions(g)
    compiled = repro.CompiledNetwork(
        plan=_forced_plan(g, decisions, opaque),
        target=repro.Target(device="moto2022", threads=3))
    text = compiled.explain()
    assert "coexec head-split 2/4, mode=streaming" in text
    assert "coexec ssm-state-split 2/4, mode=recurrent" in text
    assert "unsplit kind" not in text


# --------------------------------- split vs oracle (8-device subprocess)

_SPLIT_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core.coexec import coexec_mesh, gather_stacked
    from repro.core.partitioner import PartitionDecision
    from repro.core.types import AttnOp, SSMOp
    from repro.graph.frontends import from_model
    from repro.kernels import registry
    from repro.runtime.executor import PlanExecutor
    from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                    build_graph_schedule, segments_json)

    mesh = coexec_mesh(jax.devices())
    rng = np.random.default_rng(7)

    def unit_io(op, dtype):
        ent = registry.entry_for(op)
        x = jnp.asarray(rng.standard_normal(ent.input_shape(op)), dtype)
        w = jnp.asarray(ent.init_weight(op, rng), dtype)
        return ent, x, w

    # ---- head-split decode attention: bit-identical fp32, close bf16
    attn = AttnOp(H=8, S=512, KV=4, hd=16)
    for dtype, check in ((jnp.float32, "exact"), (jnp.bfloat16, "close")):
        ent, x, w = unit_io(attn, dtype)
        ref = np.asarray(ent.lowering.oracle(x, w, attn))
        for n_fast in (2, 4, 6):
            low = registry.get_split_lowering("attention", "head")
            split, packed = low.pack(w, attn, n_fast, mesh)
            y = np.asarray(low.run(x, packed, split, mesh, attn, n_fast))
            if check == "exact":
                assert y.tobytes() == ref.tobytes(), ("head", n_fast)
            else:
                np.testing.assert_allclose(
                    y.astype(np.float32), ref.astype(np.float32),
                    rtol=3e-2, atol=3e-2)
    print("HEAD_SPLIT_OK")

    # ---- ssm-state split: bit-identical fp32, close bf16
    ssm = SSMOp(T=64, H=8, hd=8, N=16)
    for dtype, check in ((jnp.float32, "exact"), (jnp.bfloat16, "close")):
        ent, x, w = unit_io(ssm, dtype)
        ref = np.asarray(ent.lowering.oracle(x, w, ssm))
        for n_fast in (2, 4, 6):
            low = registry.get_split_lowering("ssm", "ssm-state")
            split, packed = low.pack(w, ssm, n_fast, mesh)
            y = np.asarray(low.run(x, packed, split, mesh, ssm, n_fast))
            if check == "exact":
                assert y.tobytes() == ref.tobytes(), ("ssm-state", n_fast)
            else:
                np.testing.assert_allclose(
                    y.astype(np.float32), ref.astype(np.float32),
                    rtol=3e-2, atol=3e-2)
    print("SSM_SPLIT_OK")

    # ---- kv-block split: tolerance-exact (log-sum-exp merge reassociates)
    ent, x, w = unit_io(attn, jnp.float32)
    ref = np.asarray(ent.lowering.oracle(x, w, attn))
    for n_fast in (128, 256, 384):
        low = registry.get_split_lowering("attention", "kv-block")
        split, packed = low.pack(w, attn, n_fast, mesh)
        y = np.asarray(low.run(x, packed, split, mesh, attn, n_fast))
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-6)
    print("KV_BLOCK_OK")

    # ---- executor level: planned typed-axis schedule, fused AND unfused,
    # bit-identical to the unsplit per-node oracle walk
    def forced(g):
        decisions, opaque = {}, {}
        for n in g:
            if n.kind in ("linear", "conv"):
                c = n.op.C_out
                decisions[n.id] = PartitionDecision(
                    op=n.op, c_cpu=c // 4, c_gpu=c - c // 4,
                    pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0)
            elif n.kind == "attention":
                decisions[n.id] = PartitionDecision(
                    op=n.op, c_cpu=n.op.H // 2, c_gpu=n.op.H // 2,
                    pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0,
                    axis="head")
            elif n.kind == "ssm":
                decisions[n.id] = PartitionDecision(
                    op=n.op, c_cpu=n.op.H // 2, c_gpu=n.op.H // 2,
                    pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0,
                    axis="ssm-state")
        prov = PlanProvenance(
            device="moto2022", threads=3, mechanism="svm_poll", step=8,
            seed=1, network_fingerprint=g.fingerprint(),
            predictor_checksum="")
        return CoexecPlan(
            provenance=prov,
            schedule=build_graph_schedule(g, decisions, opaque),
            graph_json=None if g.is_unit_chain() else g.to_json(),
            segments=segments_json(g, decisions))

    for name, g in [("tiny_decoder", from_model("tiny_decoder",
                                                cache_len=512)),
                    ("tiny_ssm", from_model("tiny_ssm", tokens=64)),
                    ("tiny_hybrid", from_model("tiny_hybrid", blocks=2,
                                               cache_len=512))]:
        plan = forced(g)
        # typed-axis nodes are never inside fused segments (compilation-
        # unit discipline: one jitted shard_map program per split node)
        typed = {nid for nid, d in plan.decisions_by_node.items()
                 if d.axis not in ("channel", "none")}
        assert typed, name
        fused_nodes = {nid for seg in plan.segment_partition()
                       if seg.kind == "fused" for nid in seg.node_ids}
        assert not (typed & fused_nodes), (name, typed & fused_nodes)
        exe = PlanExecutor(plan, mesh=mesh)
        y_u, rep_u = exe.run(chain=True)
        y_f, rep_f = exe.run(fused=True)
        y_o = exe.run_oracle()
        assert np.asarray(y_u).tobytes() == np.asarray(y_o).tobytes(), name
        assert np.asarray(y_f).tobytes() == np.asarray(y_o).tobytes(), name
        assert rep_u.count("coexec") > 0, name
        print(name, "exec ok:", len(typed), "typed-axis node(s)")
    print("DECODE_EXEC_OK")
""")


def test_typed_axis_splits_match_oracle_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SPLIT_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for marker in ("HEAD_SPLIT_OK", "SSM_SPLIT_OK", "KV_BLOCK_OK",
                   "DECODE_EXEC_OK"):
        assert marker in out.stdout, out.stdout[-2000:]
