"""Minimal stand-in for `hypothesis` so property tests still run without it.

The real library is preferred (install via `pip install -e .[dev]`); when it
is absent, test modules fall back to this shim:

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from hypothesis_fallback import given, settings, st

The shim covers exactly the strategy surface this repo uses — `integers`,
`floats`, `sampled_from` — and replays a fixed number of deterministically
drawn examples per test (no shrinking, no database).  It is a graceful
degradation, not a replacement: coverage is random-but-fixed rather than
adversarial.
"""
from __future__ import annotations

import functools
import inspect
import types

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, sample):
        self.sample = sample


def _integers(min_value=None, max_value=None):
    lo = 0 if min_value is None else int(min_value)
    hi = lo + 1000 if max_value is None else int(max_value)
    return _Strategy(lambda rng: int(rng.integers(lo, hi + 1)))


def _floats(min_value=0.0, max_value=1.0):
    lo, hi = float(min_value), float(max_value)
    return _Strategy(lambda rng: float(rng.uniform(lo, hi)))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


st = types.SimpleNamespace(integers=_integers, floats=_floats,
                           sampled_from=_sampled_from)


def given(**strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            rng = np.random.default_rng(0x5EED)
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            for _ in range(n):
                drawn = {name: s.sample(rng)
                         for name, s in strategies.items()}
                fn(*args, **{**kwargs, **drawn})

        # hide the drawn parameters from pytest's fixture resolution
        sig = inspect.signature(fn)
        params = [p for name, p in sig.parameters.items()
                  if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=params)
        wrapper._is_fallback_property_test = True
        return wrapper

    return decorate


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn

    return decorate
