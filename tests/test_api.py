"""Tests for the repro.api compile→run facade.

Covers: Target validation, facade/pre-facade planning equivalence (bit-
identical plans and shared cache entries), CompiledNetwork save/load
round-trips (fp32 and bf16, with run-output equality), artifact integrity
checksums, the once-per-entry-point deprecation shims, the
fidelity-summary guards for empty/all-exclusive schedules, ServingEngine
`compiled=`, and the unified CLI warm-hitting the legacy CLI's cache.
"""
import json
import subprocess
import sys
import warnings

import numpy as np
import pytest

import repro
from repro import api
from repro.core.networks import NETWORKS
from repro.core.partitioner import (grid_search_partition_batch,
                                    optimal_partition_batch)
from repro.core.predictor import sample_conv_ops, sample_linear_ops, \
    train_predictor
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.core.sync import SyncMechanism
from repro.core.types import ConvOp, LinearOp
from repro.runtime import (PlanCache, grid_partition_ops_cached,
                           partition_ops_cached, plan_network_cached)

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)


def _small_units():
    return [("conv", ConvOp(28, 28, 32, 64, 3, 1)),
            ("pool", 4 * 14 * 14 * 64),
            ("conv", ConvOp(14, 14, 64, 96, 3, 1)),
            ("linear", LinearOp(1, 96, 128))]


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


@pytest.fixture()
def target():
    return api.Target(device="moto2022", threads=3)


# ---------------------------------------------------------------- target

def test_target_validates_eagerly():
    with pytest.raises(ValueError, match="unknown device"):
        api.Target(device="iphone99")
    with pytest.raises(ValueError, match="unknown sync mechanism"):
        api.Target(device="pixel5", mechanism="telepathy")
    with pytest.raises(ValueError, match="threads"):
        api.Target(device="pixel5", threads=0)
    with pytest.raises(ValueError, match="step"):
        api.Target(device="pixel5", step=0)
    with pytest.raises(ValueError, match="mesh policy"):
        api.Target(device="pixel5", mesh="hexagonal")
    # bool is an int subclass but would serialize as JSON `true` and split
    # the cache key from the equivalent int target
    with pytest.raises(ValueError, match="threads"):
        api.Target(device="pixel5", threads=True)
    with pytest.raises(ValueError, match="step"):
        api.Target(device="pixel5", step=True)


def test_target_normalizes_mechanism_and_roundtrips():
    t = api.Target(device="pixel5", mechanism=SyncMechanism.EVENT)
    assert t.mechanism == "event"
    assert t.sync_mechanism is SyncMechanism.EVENT
    assert api.Target.from_json(t.to_json()) == t


def test_compile_rejects_bad_inputs(target):
    with pytest.raises(ValueError, match="unknown network"):
        api.compile("not_a_net", target)
    with pytest.raises(ValueError, match="unknown mode"):
        api.compile("resnet18", target, mode="psychic")
    with pytest.raises(TypeError, match="repro.Target"):
        api.compile("resnet18", {"device": "moto2022"})
    with pytest.raises(ValueError, match="empty"):
        api.compile([], target)
    with pytest.raises(ValueError, match="no predictors"):
        api.compile(_small_units(), target, mode="grid",
                    predictors=("cp", "gp"))


# ---------------------------------------- facade / pre-facade equivalence

def test_compile_network_is_bit_identical_to_cached_planner(
        mux_predictors, target, tmp_path):
    """Acceptance: facade plans == direct plan_network_cached plans, and
    the two share on-disk cache entries (facade warm-hits a plan written
    by the pre-facade entry point)."""
    cp, gp = mux_predictors
    legacy_cache = PlanCache(tmp_path)
    legacy = plan_network_cached(_small_units(), cp, gp, threads=3,
                                 cache=legacy_cache)

    compiled = api.compile(_small_units(), target, predictors=(cp, gp),
                           cache=tmp_path)
    assert compiled.from_cache          # warm-hit the legacy entry
    assert compiled.key == legacy.key
    assert compiled.plan.provenance == legacy.provenance
    assert compiled.plan.schedule == legacy.schedule
    assert compiled.decisions == legacy.decisions
    assert compiled.plan.end_to_end_us == legacy.end_to_end_us


def test_compile_network_name_matches_unit_list(mux_predictors, target,
                                                tmp_path):
    cp, gp = mux_predictors
    by_name = api.compile("resnet18", target, predictors=(cp, gp),
                          cache=tmp_path)
    by_units = api.compile(NETWORKS["resnet18"](), target,
                           predictors=(cp, gp), cache=tmp_path)
    assert by_units.from_cache
    assert by_name.key == by_units.key


def test_compile_bare_ops_matches_partition_ops_cached(mux_predictors,
                                                       target, tmp_path):
    cp, gp = mux_predictors
    ops = [LinearOp(50, 768, 640), ConvOp(28, 28, 64, 96, 3, 1),
           LinearOp(8, 256, 1000)]
    legacy = partition_ops_cached(ops, cp, gp, cache=PlanCache(tmp_path))
    compiled = api.compile(ops, target, predictors=(cp, gp),
                           cache=tmp_path)
    assert compiled.from_cache
    assert compiled.decisions == legacy
    assert compiled.decisions == optimal_partition_batch(ops, cp, gp)
    # bare-op provenance stays threads/seed-free (the Table 2 contract)
    assert compiled.provenance.threads == 0
    assert compiled.provenance.seed == 0
    assert compiled.report() is None


def test_compile_grid_matches_grid_search(target, tmp_path):
    ops = [LinearOp(50, 768, 640), ConvOp(14, 14, 128, 130, 1, 1)]
    t0 = api.Target(device="moto2022", threads=3, seed=0)
    legacy = grid_partition_ops_cached(ops, "moto2022", 3,
                                       cache=PlanCache(tmp_path))
    compiled = api.compile(ops, t0, mode="grid", cache=tmp_path)
    assert compiled.from_cache
    assert compiled.decisions == legacy
    assert compiled.decisions == grid_search_partition_batch(
        ops, "moto2022", 3)
    assert compiled.provenance.planner == "grid"
    assert compiled.provenance.predictor_checksum == ""


def test_compile_grid_network_includes_pools(target, tmp_path):
    compiled = api.compile(_small_units(), target, mode="grid",
                           cache=tmp_path)
    assert compiled.units == _small_units()
    assert len(compiled.decisions) == 3
    # grid plans execute like any other plan
    y = compiled.run()
    assert y.shape == (1, 128)


# -------------------------------------------------------- artifact codecs

@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_save_load_roundtrip_with_run_equality(mux_predictors, target,
                                               tmp_path, dtype):
    """Satellite: provenance digest, target fields, and .run() output all
    survive a save/load cycle, in fp32 and bf16."""
    cp, gp = mux_predictors
    compiled = api.compile(_small_units(), target, predictors=(cp, gp),
                           cache=tmp_path)
    path = tmp_path / "artifact" / "net.coexec.json"
    compiled.save(path)

    back = api.CompiledNetwork.load(path)
    assert back.key == compiled.key                      # provenance digest
    assert back.provenance == compiled.provenance
    assert back.target == compiled.target                # every field
    assert back.mode == compiled.mode
    assert back.plan.schedule == compiled.plan.schedule

    y0 = np.asarray(compiled.run(dtype=dtype))
    y1 = np.asarray(back.run(dtype=dtype))
    np.testing.assert_array_equal(y0, y1)


def test_artifact_checksum_rejects_tampering(mux_predictors, target,
                                             tmp_path):
    cp, gp = mux_predictors
    compiled = api.compile(_small_units(), target, predictors=(cp, gp),
                           cache=tmp_path)
    path = tmp_path / "net.coexec.json"
    compiled.save(path)

    doc = json.loads(path.read_text())
    doc["plan"]["schedule"][0]["decision"]["c_cpu"] += 8
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="checksum"):
        api.CompiledNetwork.load(path)

    with pytest.raises(ValueError, match="artifact"):
        api.CompiledNetwork.from_json({"format": "something_else"})
    # truncated artifact (valid format/version, missing body keys) must
    # surface as the checksum ValueError, not a KeyError
    with pytest.raises(ValueError, match="checksum"):
        api.CompiledNetwork.from_json(
            {"format": "repro.compiled_network", "version": 1})


def test_explain_lists_every_unit(mux_predictors, target, tmp_path):
    cp, gp = mux_predictors
    compiled = api.compile(_small_units(), target, predictors=(cp, gp),
                           cache=tmp_path)
    text = compiled.explain()
    assert "co-executed" in text or "gpu-only" in text or "cpu-only" in text
    assert "pool" in text
    assert compiled.key in text
    # one row per schedule unit plus header/summary/verification lines
    assert len(text.splitlines()) == len(compiled.plan.schedule) + 5
    assert "verify: clean" in text


# ------------------------------------------------- fidelity summary guards

def _report(timings):
    from repro.runtime.executor import ExecutionReport
    return ExecutionReport(device="moto2022", network_fingerprint="x",
                           chain=True, split_capable=False,
                           timings=timings, reshard_points=0, elided=0)


def test_fidelity_summary_empty_schedule_has_no_nan():
    rep = _report([])
    text = rep.fidelity_summary()
    assert "0 units" in text
    for bad in ("nan", "inf", "x0.00"):
        assert bad not in text.lower()


def test_fidelity_summary_all_exclusive_zero_prediction():
    """Satellite regression: no co-executed ops and zero predicted latency
    must not divide by (near-)zero into a garbage ratio."""
    from repro.runtime.executor import OpTiming
    rep = _report([OpTiming(index=0, unit="pool", label="pool 64B",
                            mode="pool", c_fast=0, c_slow=0,
                            chained_input=False, gathered_output=True,
                            wall_us=12.5, pred_us=0.0)])
    text = rep.fidelity_summary()
    assert "n/a" in text
    assert "nan" not in text.lower()
    # the old formula produced wall/1e-9 ~ 1e10 ratios; nothing like that
    assert "e+" not in text and "x125" not in text


# ------------------------------------------------------ deprecation shims

def test_api_single_op_wrappers_warn_exactly_once(mux_predictors):
    cp, gp = mux_predictors
    op = LinearOp(50, 768, 640)

    api._DEPRECATED_SEEN.clear()
    with pytest.warns(DeprecationWarning, match="optimal_partition"):
        dec = api.optimal_partition(op, cp, gp)
    from repro.core.partitioner import optimal_partition as core_impl
    assert dec == core_impl(op, cp, gp)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        api.optimal_partition(op, cp, gp)          # second call: silent
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]

    with pytest.warns(DeprecationWarning, match="grid_search_partition"):
        api.grid_search_partition(op, "moto2022", 3, step=640)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        api.grid_search_partition(op, "moto2022", 3, step=640)
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


@pytest.mark.parametrize("module_name, match", [
    ("repro.runtime.plan", "repro plan"),
    ("repro.runtime.executor", "repro execute"),
])
def test_cli_shims_warn_exactly_once(module_name, match):
    import importlib
    mod = importlib.import_module(module_name)

    api._DEPRECATED_SEEN.clear()
    with pytest.warns(DeprecationWarning, match=match), \
            pytest.raises(SystemExit):
        mod.main(["--help"])                       # forwards to the new CLI
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        with pytest.raises(SystemExit):
            mod.main(["--help"])
    assert not [w for w in caught
                if issubclass(w.category, DeprecationWarning)]


# ------------------------------------------------------------- integrations

class _Model:                          # never traced: jit is lazy
    @staticmethod
    def prefill(params, toks, cache):
        raise NotImplementedError

    @staticmethod
    def decode_step(params, tok, cache, pos):
        raise NotImplementedError


def test_serving_engine_accepts_compiled(mux_predictors, target, tmp_path):
    from repro.serving.engine import ServingEngine

    cp, gp = mux_predictors
    compiled = api.compile(_small_units(), target, predictors=(cp, gp),
                           cache=tmp_path)
    eng = ServingEngine(cfg=None, model=_Model, params={},
                        compiled=compiled)
    assert eng.compiled is compiled
    assert eng.coexec_plan is compiled.plan
    # the engine shares the compiled network's memoized executor
    assert eng.plan_executor is compiled.executor()

    with pytest.raises(ValueError, match="not both"):
        ServingEngine(cfg=None, model=_Model, params={},
                      compiled=compiled, coexec_plan=compiled.plan)
    with pytest.raises(TypeError, match="CompiledNetwork"):
        ServingEngine(cfg=None, model=_Model, params={},
                      compiled={"not": "compiled"})


# --------------------------------------------------------------------- CLI

def test_unified_cli_warm_hits_legacy_cli_cache(tmp_path, capsys):
    """Acceptance: `python -m repro plan` warm-hits the same on-disk cache
    entry the deprecated `python -m repro.runtime.plan` CLI wrote."""
    from repro import cli
    from repro.runtime import plan as legacy_plan

    args = ["--network", "resnet18", "--device", "moto2022",
            "--threads", "3", "--samples", "60", "--estimators", "10",
            "--cache-dir", str(tmp_path)]

    api._DEPRECATED_SEEN.clear()
    with pytest.warns(DeprecationWarning):
        assert legacy_plan.main(args) == 0         # cold compile via shim
    cold = capsys.readouterr().out
    assert "cache MISS" in cold

    assert cli.main(["plan", *args]) == 0          # warm via the facade CLI
    warm = capsys.readouterr().out
    assert "cache HIT" in warm
    # same provenance key on both paths
    key = [ln for ln in cold.splitlines() if "key " in ln][0].split()[1]
    assert key in warm
    assert len(list(tmp_path.glob("*.json"))) == 1


def test_import_repro_and_target_stay_jax_free():
    """The facade's import-light contract: importing repro, validating a
    Target, and compiling (planning is numpy-only) never import jax."""
    import os
    env = dict(os.environ)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = ("import sys, repro; repro.Target(device='pixel5'); "
            "import repro.api; "
            "assert 'jax' not in sys.modules, 'jax was imported'; "
            "print('jax-free')")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "jax-free" in out.stdout


def test_python_dash_m_repro_help():
    import os
    env = dict(os.environ)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run([sys.executable, "-m", "repro", "--help"],
                         env=env, capture_output=True, text=True,
                         timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    for sub in ("plan", "execute", "bench", "serve"):
        assert sub in out.stdout


def test_cli_plan_writes_artifact_and_execute_loads_it(tmp_path, capsys):
    from repro import cli

    art = tmp_path / "net.coexec.json"
    args = ["--network", "resnet18", "--device", "moto2022",
            "--threads", "3", "--samples", "60", "--estimators", "10",
            "--cache-dir", str(tmp_path)]
    assert cli.main(["plan", *args, "--save", str(art)]) == 0
    capsys.readouterr()
    assert art.exists()

    assert cli.main(["execute", "--artifact", str(art),
                     "--no-warmup"]) == 0
    out = capsys.readouterr().out
    assert "fidelity:" in out
