"""Sanity tests for the end-to-end network graphs and the planner."""
import numpy as np
import pytest

from repro.core.networks import NETWORKS
from repro.core.types import ConvOp, LinearOp


@pytest.mark.parametrize("name,lo,hi", [
    ("vgg16", 29e9, 33e9),            # ~30.9 GFLOPs @224
    ("resnet18", 3.2e9, 4.1e9),       # ~3.6
    ("resnet34", 6.8e9, 7.9e9),       # ~7.3
    ("inception_v3", 10e9, 14e9),     # ~11.4 @299
])
def test_network_flops_match_literature(name, lo, hi):
    units = NETWORKS[name]()
    fl = sum(u[1].flops for u in units if u[0] in ("conv", "linear"))
    assert lo <= fl <= hi, f"{name}: {fl/1e9:.2f} GFLOPs"


def test_networks_are_connected():
    """Channel counts must chain: each conv/linear input channels match a
    plausible producer (spot check: resnet34 strictly alternates)."""
    for name, fn in NETWORKS.items():
        units = [u for u in fn() if u[0] in ("conv", "linear")]
        assert len(units) >= 10 or name == "vgg16"
        for kind, op in units:
            if kind == "conv":
                assert op.C_in >= 1 and op.C_out >= 1
                assert op.H_out >= 1 and op.W_out >= 1


def test_planner_pool_stays_on_gpu(pixel5_linear_predictors):
    """Pooling units contribute no CPU work and no sync overhead."""
    from repro.core.planner import plan_network
    cp, gp = pixel5_linear_predictors
    units = [("linear", LinearOp(64, 512, 1024)), ("pool", 4 * 1024),
             ("linear", LinearOp(64, 1024, 512))]
    r = plan_network(units, cp, gp, threads=3)
    assert len(r.decisions) == 2            # pools make no decisions
    assert r.baseline_us > 0
    assert r.end_to_end_speedup > 0.5
