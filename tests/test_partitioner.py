"""Tests for the output-channel partitioner (paper Section 2)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # graceful fallback, see hypothesis_fallback
    from hypothesis_fallback import given, settings, st

from repro.core.partitioner import (grid_search_partition, optimal_partition,
                                    realized_latency_us, speedup_vs_gpu)
from repro.core.sync import SyncMechanism
from repro.core.types import LinearOp


def test_split_covers_all_channels(pixel5_linear_predictors):
    cp, gp = pixel5_linear_predictors
    op = LinearOp(50, 768, 3072)
    d = optimal_partition(op, cp, gp)
    assert d.c_cpu + d.c_gpu == op.C_out
    assert d.c_cpu >= 0 and d.c_gpu >= 0


def test_partition_never_worse_than_exclusive_in_prediction(
        pixel5_linear_predictors):
    """The argmin includes both exclusive strategies, so the predicted total
    can never exceed the predicted exclusive latencies."""
    cp, gp = pixel5_linear_predictors
    for c_out in (64, 640, 1000, 2048, 3072):
        op = LinearOp(50, 768, c_out)
        d = optimal_partition(op, cp, gp)
        t_gpu = gp.predict([op])[0]
        t_cpu = cp.predict([op])[0]
        assert d.pred_total_us <= min(t_gpu, t_cpu) + 1e-6


def test_grid_search_finds_good_splits():
    op = LinearOp(50, 768, 3072)
    g = grid_search_partition(op, "pixel5", 3)
    s = speedup_vs_gpu(g, "pixel5", 3)
    assert s > 1.5      # paper: ~1.9x-2.0x class on Pixel 5


def test_predictor_close_to_grid_search(pixel5_linear_predictors):
    cp, gp = pixel5_linear_predictors
    rng = np.random.default_rng(2)
    ops = [LinearOp(int(L), int(ci), int(co))
           for L, ci, co in zip(rng.integers(16, 512, 6),
                                rng.integers(256, 2048, 6),
                                rng.integers(512, 3072, 6))]
    sp = np.mean([speedup_vs_gpu(optimal_partition(o, cp, gp), "pixel5", 3)
                  for o in ops])
    sg = np.mean([speedup_vs_gpu(grid_search_partition(o, "pixel5", 3),
                                 "pixel5", 3) for o in ops])
    assert sp > 0.85 * sg, (sp, sg)   # Tab. 2: GBDT within ~6% of search


def test_sync_mechanism_affects_decision_and_latency(
        pixel5_linear_predictors):
    """Tab. 4: with the 155 us event overhead co-execution loses its margin
    on small ops; with SVM polling it wins."""
    cp, gp = pixel5_linear_predictors
    op = LinearOp(50, 768, 640)
    t_svm = realized_latency_us(
        optimal_partition(op, cp, gp, mechanism=SyncMechanism.SVM_POLL),
        "pixel5", 3, mechanism=SyncMechanism.SVM_POLL)
    t_evt = realized_latency_us(
        optimal_partition(op, cp, gp, mechanism=SyncMechanism.EVENT),
        "pixel5", 3, mechanism=SyncMechanism.EVENT)
    assert t_svm <= t_evt


@settings(max_examples=15, deadline=None)
@given(c_out=st.integers(32, 4096))
def test_candidate_grid_includes_exclusive_endpoints(c_out):
    from repro.core.partitioner import _candidate_splits
    cands = _candidate_splits(c_out, 8)
    assert cands[0] == 0 and cands[-1] == c_out
    assert np.all(np.diff(cands) > 0)
