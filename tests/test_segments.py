"""Differential test harness for the segment compiler.

The fused segment walk (`PlanExecutor.run(fused=True)` lowering each
same-mesh segment into one jitted program, see `repro.runtime.segments`)
is locked against two references: the per-node walk (`fused=False`) and
the unsplit oracle (`run_oracle`) — outputs must agree bit-for-bit, and
the partition (`Graph.segments`) must cut exactly where the unfused walk
materializes.

Layers:
  * pure graph properties of `Graph.segments` / `elided` /
    `materialization_points` (no jax execution);
  * a property-based random-DAG differential (hypothesis, falling back to
    the deterministic `hypothesis_fallback` shim): random residual-block
    graphs with exclusive boundaries, fused == unfused == oracle across
    fp32/bf16;
  * a true-split 8-virtual-device subprocess (the PR-5 pattern) asserting
    one gather per fused segment and strictly fewer device syncs;
  * the `_fit_axis` strictness regression;
  * a fidelity round-trip: fused `source="fused"` records through
    `MeasurementStore` -> `Calibrator.fit` -> `replan()`.
"""
import os
import subprocess
import sys
import textwrap
from collections import defaultdict

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from hypothesis_fallback import given, settings, st

import jax.numpy as jnp

from repro.core.networks import NETWORKS
from repro.core.partitioner import PartitionDecision
from repro.core.predictor import (sample_conv_ops, sample_linear_ops,
                                  train_predictor)
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.core.types import ConvOp, LinearOp
from repro.graph.frontends import from_model
from repro.graph.ir import (SEGMENT_EXCLUSIVE, SEGMENT_FUSED, SEGMENT_POOL,
                            Graph, Node, Segment, from_units)
from repro.measure import MeasurementStore
from repro.runtime import PlanCache
from repro.runtime.executor import PlanExecutor, _fit_axis
from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                build_graph_schedule, segments_json)


def _forced_plan(g: Graph, decisions, opaque=None) -> CoexecPlan:
    """A hand-built plan over `g` with explicit split decisions — segment
    structure must be deterministic for these tests, so no predictors."""
    prov = PlanProvenance(
        device="moto2022", threads=3, mechanism="svm_poll", step=8, seed=1,
        network_fingerprint=g.fingerprint(), predictor_checksum="")
    return CoexecPlan(
        provenance=prov,
        schedule=build_graph_schedule(g, decisions, opaque or {}),
        graph_json=None if g.is_unit_chain() else g.to_json(),
        segments=segments_json(g, decisions))


def _all_coexec(g: Graph):
    """Every splittable node co-executed (uneven ~3/4-1/4 split), opaque
    kinds priced at a token latency."""
    decisions, opaque = {}, {}
    for n in g:
        if n.kind in ("linear", "conv"):
            c = n.op.C_out
            c_cpu = max(1, c // 4)
            decisions[n.id] = PartitionDecision(
                op=n.op, c_cpu=c_cpu, c_gpu=c - c_cpu,
                pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0)
        elif n.kind in ("attention", "ssm"):
            opaque[n.id] = 1.0
    return decisions, opaque


# ------------------------------------------------- pure graph properties

def test_segment_dataclass_validates():
    s = Segment(kind=SEGMENT_FUSED, node_ids=["a", "b"])
    assert s.node_ids == ("a", "b") and len(s) == 2
    with pytest.raises(ValueError):
        Segment(kind="bogus", node_ids=("a",))
    with pytest.raises(ValueError):
        Segment(kind=SEGMENT_POOL, node_ids=())


def test_tiny_decoder_partition_structure():
    """The decoder block partitions exactly as designed: the attention
    node is an exclusive singleton; the o_proj+residual and the whole MLP
    (up, down, residual join) fuse."""
    g = from_model("tiny_decoder")
    decisions, _ = _all_coexec(g)
    coexec = set(decisions)
    segs = g.segments(coexec)
    got = [(s.kind, s.node_ids) for s in segs]
    assert got == [
        (SEGMENT_FUSED, ("embed",)),
        (SEGMENT_FUSED, ("b0.q_proj",)),
        (SEGMENT_EXCLUSIVE, ("b0.attn",)),
        (SEGMENT_FUSED, ("b0.o_proj", "b0.attn_res")),
        (SEGMENT_FUSED, ("b0.mlp_up", "b0.mlp_down", "b0.mlp_res")),
    ]


@pytest.mark.parametrize("network", ["resnet18", "vgg16"])
def test_conv_network_partitions_to_single_digit_segments(network):
    g = from_units(NETWORKS[network]())
    decisions, _ = _all_coexec(g)
    coexec = set(decisions)
    segs = g.segments(coexec)
    # covering partition, in topological order
    assert [nid for s in segs for nid in s.node_ids] == [n.id for n in g]
    n_fused = sum(1 for s in segs if s.kind == SEGMENT_FUSED)
    # a handful of jitted programs instead of ~20 Python-dispatched ops
    assert 0 < n_fused < 10, [s.node_ids for s in segs]
    assert len(segs) < len(g.nodes)
    # boundary kinds: pools are pool singletons, fused members are
    # coexec ops or adds
    for s in segs:
        if s.kind == SEGMENT_POOL:
            assert len(s) == 1 and g.node(s.node_ids[0]).kind == "pool"
        elif s.kind == SEGMENT_FUSED:
            for nid in s.node_ids:
                assert nid in coexec or g.node(nid).kind == "add"
        # convexity: only the last node of a fused run is consumed outside
        if s.kind == SEGMENT_FUSED:
            ids = set(s.node_ids)
            for nid in s.node_ids[:-1]:
                assert set(g.consumers(nid)) <= ids, (s.node_ids, nid)


def test_unsplit_kinds_and_exclusive_ops_are_boundaries():
    g = from_model("tiny_decoder")
    decisions, _ = _all_coexec(g)
    # demote one mid-block linear to exclusive: it must become a singleton
    decisions["b0.mlp_up"] = PartitionDecision(
        op=g.node("b0.mlp_up").op, c_cpu=0,
        c_gpu=g.node("b0.mlp_up").op.C_out,
        pred_cpu_us=0.0, pred_gpu_us=1.0, pred_total_us=1.0)
    coexec = {nid for nid, d in decisions.items()
              if d.c_cpu > 0 and d.c_gpu > 0}
    segs = {s.node_ids: s.kind for s in g.segments(coexec)}
    assert segs[("b0.attn",)] == SEGMENT_EXCLUSIVE      # unsplit kind
    assert segs[("b0.mlp_up",)] == SEGMENT_EXCLUSIVE    # demoted op
    assert segs[("b0.mlp_down", "b0.mlp_res")] == SEGMENT_FUSED


def test_materialization_points_are_coexec_minus_elided():
    for build in (lambda: from_model("tiny_decoder"),
                  lambda: from_units(NETWORKS["resnet18"]())):
        g = build()
        decisions, _ = _all_coexec(g)
        coexec = frozenset(decisions)
        el = g.elided(coexec)
        assert el <= coexec
        assert g.materialization_points(coexec) == coexec - el
        # an elided producer and its sole consumer share a fused segment
        seg_of = {}
        for k, s in enumerate(g.segments(coexec)):
            for nid in s.node_ids:
                seg_of[nid] = (k, s.kind)
        for nid in el:
            (k, kind) = seg_of[nid]
            cons = g.consumers(nid)[0]
            assert kind == SEGMENT_FUSED
            assert seg_of[cons] == (k, SEGMENT_FUSED), (nid, cons)


def test_plan_embeds_and_reloads_segment_partition():
    g = from_model("tiny_decoder")
    decisions, opaque = _all_coexec(g)
    plan = _forced_plan(g, decisions, opaque)
    doc = plan.to_json()
    assert doc["segments"] == segments_json(g, decisions)
    back = CoexecPlan.from_json(doc)
    assert back.segment_partition() == plan.segment_partition()
    # omitted-when-absent: a plan without the field re-derives identically
    bare = CoexecPlan(provenance=plan.provenance, schedule=plan.schedule,
                      graph_json=plan.graph_json)
    assert "segments" not in bare.to_json()
    assert bare.segment_partition() == plan.segment_partition()
    # the ExecSpec view carries the partition index
    seg_of = plan.segment_of()
    for spec in plan.exec_specs():
        assert spec.segment == seg_of[spec.node_id]


# ------------------------------------- random-DAG differential (property)

def _residual_graph(rng, n_blocks: int, exclusive_mid: bool
                    ) -> Graph:
    """embed -> n_blocks x (u = linear, v = linear, r = add(prev, v))."""
    c = int(rng.choice([16, 24, 32]))
    L = int(rng.integers(2, 5))
    nodes = [Node(id="embed", kind="linear", op=LinearOp(L, c, c))]
    prev = "embed"
    for b in range(n_blocks):
        nodes.append(Node(id=f"b{b}.u", kind="linear",
                          op=LinearOp(L, c, c), inputs=(prev,)))
        nodes.append(Node(id=f"b{b}.v", kind="linear",
                          op=LinearOp(L, c, c), inputs=(f"b{b}.u",)))
        nodes.append(Node(id=f"b{b}.r", kind="add",
                          inputs=(prev, f"b{b}.v")))
        prev = f"b{b}.r"
    return Graph(nodes)


@settings(max_examples=8)
@given(seed=st.integers(0, 10 ** 6), n_blocks=st.integers(1, 3),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       exclusive_mid=st.sampled_from([False, True]))
def test_random_residual_dag_fused_equals_unfused_and_oracle(
        seed, n_blocks, dtype, exclusive_mid):
    rng = np.random.default_rng(seed)
    g = _residual_graph(rng, n_blocks, exclusive_mid)
    decisions, _ = _all_coexec(g)
    if exclusive_mid:
        op = g.node("b0.v").op
        decisions["b0.v"] = PartitionDecision(
            op=op, c_cpu=0, c_gpu=op.C_out, pred_cpu_us=0.0,
            pred_gpu_us=1.0, pred_total_us=1.0)
    exe = PlanExecutor(_forced_plan(g, decisions), dtype=jnp.dtype(dtype))
    x = exe.input_template()
    y_u, rep_u = exe.run(x, chain=True)
    y_f, rep_f = exe.run(x, fused=True)
    y_o = exe.run_oracle(x)
    assert np.asarray(y_f).tobytes() == np.asarray(y_u).tobytes()
    assert np.asarray(y_f).tobytes() == np.asarray(y_o).tobytes()
    assert rep_f.fused and not rep_u.fused
    assert rep_f.sync_points == len(rep_f.segment_wall_us)
    assert rep_f.sync_points <= rep_u.sync_points
    assert len(rep_f.timings) == len(rep_u.timings) == len(g.nodes)
    # the partition indices on the records cover the partition in order
    segs = exe.plan.segment_partition()
    assert [t.segment for t in rep_f.timings] == \
        [k for k, s in enumerate(segs) for _ in s.node_ids]


def test_pool_boundaries_differential():
    """Conv graph with pools: pools are singleton boundaries; fused ==
    unfused == oracle bit-for-bit."""
    units = [("conv", ConvOp(8, 8, 8, 16, 3, 1)),
             ("conv", ConvOp(8, 8, 16, 16, 3, 1)),
             ("pool", 4 * 4 * 4 * 16),
             ("conv", ConvOp(4, 4, 16, 24, 3, 1)),
             ("linear", LinearOp(1, 4 * 4 * 24, 32))]
    g = from_units(units)
    decisions, _ = _all_coexec(g)
    exe = PlanExecutor(_forced_plan(g, decisions))
    y_u, rep_u = exe.run(chain=True)
    y_f, rep_f = exe.run(fused=True)
    y_o = exe.run_oracle()
    assert np.asarray(y_f).tobytes() == np.asarray(y_u).tobytes()
    assert np.asarray(y_f).tobytes() == np.asarray(y_o).tobytes()
    kinds = [p.kind for p in exe.segment_programs()]
    assert SEGMENT_POOL in kinds
    assert rep_f.count("pool") == rep_u.count("pool") == 1


def test_fused_requires_chaining():
    g = from_units([("linear", LinearOp(1, 8, 8))])
    decisions, _ = _all_coexec(g)
    exe = PlanExecutor(_forced_plan(g, decisions))
    with pytest.raises(ValueError, match="fused"):
        exe.run(chain=False, fused=True)


# ---------------------------------------------- _fit_axis strictness fix

def test_fit_axis_strict_raises_on_non_alignment_mismatch():
    x = jnp.ones((4, 10))
    # growing an axis is never alignment padding
    with pytest.raises(ValueError, match="axis 1"):
        _fit_axis(x, 1, 32)
    # shrinking past the alignment envelope is a real mismatch too:
    # 10 > roundup(4, 8) = 8
    with pytest.raises(ValueError, match="axis 1"):
        _fit_axis(x, 1, 4)
    # exact size is the identity
    assert _fit_axis(x, 1, 10) is x
    # cropping alignment padding is the legitimate case: 37 -> padded 40
    y = _fit_axis(jnp.ones((4, 40)), 1, 37)
    assert y.shape == (4, 37)
    # lcm-of-8-and-lanes granularity via align=
    assert _fit_axis(jnp.ones((4, 48)), 1, 33, align=24).shape == (4, 33)
    with pytest.raises(ValueError):           # 48 > roundup(33, 8) = 40
        _fit_axis(jnp.ones((4, 48)), 1, 33, align=8)


def test_fit_axis_adapt_keeps_tile_and_crop():
    x = jnp.arange(6, dtype=jnp.float32).reshape(1, 6)
    y = _fit_axis(x, 1, 15, adapt=True)       # tile x3 (18) then crop
    assert y.shape == (1, 15)
    np.testing.assert_array_equal(
        np.asarray(y)[0], np.tile(np.arange(6), 3)[:15])
    assert _fit_axis(x, 1, 4, adapt=True).shape == (1, 4)


# --------------------------------- true split execution (8-device subproc)

_SPLIT_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.coexec import coexec_mesh
    from repro.core.networks import NETWORKS
    from repro.core.partitioner import PartitionDecision
    from repro.graph.frontends import from_model
    from repro.graph.ir import from_units
    from repro.runtime.executor import PlanExecutor
    from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                    build_graph_schedule, segments_json)

    def forced(g):
        decisions, opaque = {}, {}
        for n in g:
            if n.kind in ("linear", "conv"):
                c = n.op.C_out
                c_cpu = max(1, c // 4)
                decisions[n.id] = PartitionDecision(
                    op=n.op, c_cpu=c_cpu, c_gpu=c - c_cpu,
                    pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0)
            elif n.kind in ("attention", "ssm"):
                opaque[n.id] = 1.0
        prov = PlanProvenance(
            device="moto2022", threads=3, mechanism="svm_poll", step=8,
            seed=1, network_fingerprint=g.fingerprint(),
            predictor_checksum="")
        return CoexecPlan(
            provenance=prov,
            schedule=build_graph_schedule(g, decisions, opaque),
            graph_json=None if g.is_unit_chain() else g.to_json(),
            segments=segments_json(g, decisions)), decisions

    mesh = coexec_mesh(jax.devices())
    for name, g in [("resnet18", from_units(NETWORKS["resnet18"]())),
                    ("tiny_decoder", from_model("tiny_decoder"))]:
        plan, decisions = forced(g)
        exe = PlanExecutor(plan, mesh=mesh)
        assert exe.split_capable
        progs = exe.segment_programs()
        fused = [p for p in progs if p.kind == "fused"]
        assert 0 < len(progs) < 10 and fused, name
        # acceptance: a fused segment issues EXACTLY ONE gather — at its
        # boundary; every interior edge stays group-local or is merged
        # inside the program
        for p in fused:
            assert p.gathers == 1, (name, p.node_ids, p.gathers)
        y_u, rep_u = exe.run(chain=True)
        y_f, rep_f = exe.run(fused=True)
        y_o = exe.run_oracle()
        assert np.asarray(y_f).tobytes() == np.asarray(y_u).tobytes(), name
        np.testing.assert_allclose(np.asarray(y_f), np.asarray(y_o),
                                   rtol=2e-5, atol=2e-5)
        # strictly fewer device syncs than the per-node walk
        assert rep_f.sync_points < rep_u.sync_points, name
        assert rep_f.sync_points == len(progs)
        # both walks reshard at the same points and elide the same edges
        assert rep_f.reshard_points == rep_u.reshard_points, name
        assert rep_f.elided == rep_u.elided, name
        # the partition's boundaries ARE the unfused materialization
        # points: producers of chained records == graph.elided
        coexec = frozenset(exe.plan.coexec_node_ids())
        want = g.elided(coexec)
        from_unfused = {g.node(t.node_id).inputs[0]
                        for t in rep_u.timings if t.chained_input}
        from_fused = {nid for p in progs
                      for nid, gf in p.gathered.items() if not gf}
        assert from_unfused == want, name
        assert from_fused == want, name
        print(name, "segments", len(progs), "fused", len(fused),
              "sync", rep_f.sync_points, "vs", rep_u.sync_points)
    print("FUSED_SPLIT_OK")
""")


def test_fused_split_execution_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SPLIT_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FUSED_SPLIT_OK" in out.stdout


# ------------------------------------------------- fidelity round-trip

_FAST = GBDTParams(n_estimators=30, max_depth=5, learning_rate=0.25)


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(150, seed=1)
    ct = sample_conv_ops(150, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


def test_fused_records_roundtrip_store_calibrate_replan(mux_predictors,
                                                        tmp_path):
    """Fused plan -> MeasurementStore -> Calibrator.fit -> replan(), end
    to end on source="fused" records; per-segment attribution sums back
    to the segment wall."""
    import repro

    cache = PlanCache(tmp_path / "plans")
    target = repro.Target(device="moto2022", threads=3)
    units = [("conv", ConvOp(14, 14, 16, 32, 3, 1)),
             ("conv", ConvOp(14, 14, 32, 32, 3, 2)),
             ("pool", 4 * 7 * 7 * 32),
             ("linear", LinearOp(1, 7 * 7 * 32, 64)),
             ("linear", LinearOp(1, 64, 32))]
    compiled = repro.compile(units, target, predictors=mux_predictors,
                             cache=cache)
    store = MeasurementStore(tmp_path / "meas")
    for _ in range(2):
        rep = compiled.record(store=store, warmup=False, fused=True)
        assert rep.fused
        by_seg = defaultdict(float)
        for t in rep.timings:
            assert t.source == "fused" and t.segment >= 0
            by_seg[t.segment] += t.wall_us
        for k, wall in enumerate(rep.segment_wall_us):
            assert by_seg[k] == pytest.approx(wall, rel=1e-9, abs=1e-6), k

    records = store.load(compiled.key)
    assert len(records) == 2 * len(compiled.plan.schedule)
    assert all(r.source == "fused" for r in records)
    cal = compiled.recalibrate(store)
    assert cal.n_records > 0

    recompiled, diff = compiled.replan(cal, store=store, cache=cache)
    assert recompiled.key != compiled.key
    assert recompiled.provenance.calibration == cal.version
    # the replanned network executes fused too, and its records keep the
    # new provenance key
    rep2 = recompiled.profile(warmup=False, fused=True)
    assert rep2.fused
    assert all(t.plan_key == recompiled.key for t in rep2.timings)
