"""Per-kernel validation: interpret=True Pallas execution vs pure-jnp
oracles, swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import (decode_attention_op,
                                            decode_attention_ref)
from repro.kernels.split_matmul import split_matmul_op, split_matmul_ref
from repro.kernels.winograd_conv import conv2d_ref, winograd_conv2d


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _legal(v, extent, align):
    """Clamp a requested tile param to the padded problem extent — explicit
    tiles must be legal now (the kernels raise instead of silently
    rewriting oversize requests; see kernels.tiles.check_tile)."""
    return min(v, -(-extent // align) * align)


# ------------------------------------------------------------ split_matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n,c0,width", [
    (8, 64, 256, 0, 256),        # full width
    (50, 768, 3072, 2480, 592),  # the paper's ViT running example split
    (17, 100, 301, 96, 128),     # ragged everything
    (128, 512, 1024, 512, 512),  # aligned halves
    (1, 32, 64, 8, 40),          # tiny
])
def test_split_matmul_matches_ref(m, k, n, c0, width, dtype):
    rng = np.random.default_rng(hash((m, k, n, c0, width)) % 2**32)
    x = _rand(rng, (m, k), dtype)
    w = _rand(rng, (k, n), dtype)
    got = split_matmul_op(x, w, c0, width, bm=_legal(32, m, 8),
                          bn=_legal(128, n, 128), bk=_legal(128, k, 128),
                          interpret=True)
    want = split_matmul_ref(x, w, c0, width)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    # rounding error of the blocked K-accumulation grows ~sqrt(K), and
    # near-zero outputs only have atol to absorb it
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * np.sqrt(k))


def test_split_matmul_covers_partition():
    """c_fast + c_slow slices concatenate to the full product — the
    paper's correctness invariant for co-execution."""
    rng = np.random.default_rng(0)
    x = _rand(rng, (50, 768), jnp.float32)
    w = _rand(rng, (768, 3072), jnp.float32)
    c_fast = 2480
    a = split_matmul_op(x, w, 0, c_fast, interpret=True)
    b = split_matmul_op(x, w, c_fast, 3072 - c_fast, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([a, b], -1)),
                               np.asarray(x @ w), rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- decode_attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("h,kv,hd,s,pos,window", [
    (8, 8, 64, 256, 100, 0),      # MHA
    (16, 2, 128, 512, 511, 0),    # GQA 8:1
    (4, 1, 128, 300, 17, 0),      # ragged S
    (16, 8, 256, 256, 200, 64),   # sliding window (gemma3-style)
    (40, 8, 128, 1024, 700, 0),   # llama4-scout geometry
])
def test_decode_attention_matches_ref(h, kv, hd, s, pos, window, dtype):
    rng = np.random.default_rng(hash((h, kv, s, pos)) % 2**32)
    b = 2
    q = _rand(rng, (b, h, hd), dtype)
    k = _rand(rng, (b, s, kv, hd), dtype)
    v = _rand(rng, (b, s, kv, hd), dtype)
    got = decode_attention_op(q, k, v, jnp.int32(pos), window=window,
                              bs=128, interpret=True)
    want = decode_attention_op(q, k, v, jnp.int32(pos), window=window,
                               use_kernel=False)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_decode_attention_masks_future():
    """Values beyond pos must not influence the output."""
    rng = np.random.default_rng(3)
    q = _rand(rng, (1, 4, 64), jnp.float32)
    k = _rand(rng, (1, 128, 4, 64), jnp.float32)
    v = _rand(rng, (1, 128, 4, 64), jnp.float32)
    pos = jnp.int32(40)
    base = decode_attention_op(q, k, v, pos, bs=128, interpret=True)
    k2 = k.at[:, 41:].set(999.0)
    v2 = v.at[:, 41:].set(-999.0)
    poisoned = decode_attention_op(q, k2, v2, pos, bs=128, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               rtol=1e-6)


# ----------------------------------------------------------- winograd_conv
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,h,w,cin,cout", [
    (1, 8, 8, 32, 128),
    (2, 16, 16, 64, 160),
    (1, 15, 17, 32, 136),        # odd spatial dims
])
def test_winograd_conv_matches_direct(b, h, w, cin, cout, dtype):
    rng = np.random.default_rng(hash((b, h, w, cin, cout)) % 2**32)
    x = _rand(rng, (b, h, w, cin), dtype) * 0.3
    wgt = _rand(rng, (3, 3, cin, cout), dtype) * 0.3
    tiles = -(-h // 2) * -(-w // 2)
    got = winograd_conv2d(x, wgt, interpret=True, bm=_legal(32, tiles, 8),
                          bn=_legal(128, cout, 128),
                          bk=_legal(128, cin, 128))
    want = conv2d_ref(x, wgt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_winograd_flop_reduction_claim():
    """F(2x2,3x3) does 16 multiplies per 4 outputs vs 36 direct — the 2.25x
    reduction that motivates TFLite's kernel switch (Fig. 6b)."""
    assert 36 / 16 == 2.25


# --------------------------------------------------------------- ssd_chunk
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,t,h,hd,n,chunk", [
    (1, 128, 2, 16, 8, 64),
    (2, 256, 4, 16, 8, 64),
    (1, 256, 2, 64, 64, 128),      # zamba2-like head geometry
    (2, 512, 2, 32, 16, 256),
])
def test_ssd_chunk_kernel_matches_scan(b, t, h, hd, n, chunk, dtype):
    from repro.kernels.ssd_chunk import ssd_chunk_op
    rng = np.random.default_rng(hash((b, t, h, hd, n)) % 2**32)
    x = _rand(rng, (b, t, h, hd), dtype)
    bm = _rand(rng, (b, t, n), dtype)
    cm = _rand(rng, (b, t, n), dtype)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(b, t, h)), dtype)
    a = jnp.asarray(-rng.uniform(0.1, 1.5, size=(h,)), dtype)
    s0 = _rand(rng, (b, h, hd, n), dtype)
    sf_k, y_k = ssd_chunk_op(x, bm, cm, dt, a, s0, chunk=chunk,
                             interpret=True)
    sf_r, y_r = ssd_chunk_op(x, bm, cm, dt, a, s0, use_kernel=False)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(sf_k), np.asarray(sf_r),
                               rtol=5e-4, atol=5e-4)


def test_ssd_chunk_kernel_state_carries_across_chunks():
    """Splitting T into more chunks must not change the result."""
    from repro.kernels.ssd_chunk import ssd_chunk_op
    rng = np.random.default_rng(7)
    b, t, h, hd, n = 1, 256, 2, 16, 8
    x = _rand(rng, (b, t, h, hd), jnp.float32)
    bm = _rand(rng, (b, t, n), jnp.float32)
    cm = _rand(rng, (b, t, n), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(b, t, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1.5, size=(h,)), jnp.float32)
    s0 = _rand(rng, (b, h, hd, n), jnp.float32)
    sf1, y1 = ssd_chunk_op(x, bm, cm, dt, a, s0, chunk=256, interpret=True)
    sf2, y2 = ssd_chunk_op(x, bm, cm, dt, a, s0, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(np.asarray(sf1), np.asarray(sf2),
                               rtol=5e-4, atol=5e-4)
