"""Tests for the typed op-graph IR (repro.graph) and its pipeline
integration: planner, plan cache, executor, measurement, api, CLI.

Acceptance anchors:
  * `from_units(vgg16())` plans bit-identical decisions (and totals) to
    the pre-IR `plan_network` implementation, and the graph-cached planner
    warm-hits entries the unit-list planner wrote (legacy fingerprints);
  * an attention block and an SSM block built by `graph.from_model` plan,
    execute, and record measurements through the same cached path as
    vgg16/resnet18, with executed output matching the unsplit oracle;
  * a fan-out graph gathers a shared split output exactly once (8-virtual-
    device subprocess, same idiom as test_executor.py).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.networks import NETWORKS, pool_out_edge
from repro.core.predictor import sample_conv_ops, sample_linear_ops, \
    train_predictor
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.core.types import AttnOp, ConvOp, LinearOp, SSMOp
from repro.graph import (Graph, Node, fan_out_demo, from_model, from_units,
                         model_names)
from repro.kernels import registry

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)

#: one representative op per registered kernel kind (shape-inference tests)
SAMPLE_OPS = {
    "linear": LinearOp(4, 32, 64),
    "conv": ConvOp(28, 28, 16, 24, 3, 2),
    "attention": AttnOp(H=4, S=128, KV=2, hd=16, window=8),
    "ssm": SSMOp(T=2, H=4, hd=32, N=16),
}


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


# ------------------------------------------------------------ IR basics

def test_node_validation():
    with pytest.raises(ValueError, match="positive byte"):
        Node(id="p", kind="pool", pool_bytes=0, inputs=("x",))
    with pytest.raises(ValueError, match="exactly one input"):
        Node(id="p", kind="pool", pool_bytes=64, inputs=())
    with pytest.raises(ValueError, match=">= 2 inputs"):
        Node(id="a", kind="add", inputs=("x",))
    with pytest.raises(ValueError, match="needs an op"):
        Node(id="l", kind="linear")
    with pytest.raises(ValueError, match="node kind"):
        Node(id="l", kind="linear", op=ConvOp(8, 8, 4, 8))
    with pytest.raises(KeyError, match="unregistered"):
        Node(id="s", kind="softmax")
    with pytest.raises(ValueError, match="at most one input"):
        Node(id="l", kind="linear", op=LinearOp(1, 4, 4),
             inputs=("a", "b"))


def test_graph_validation():
    lin = LinearOp(1, 8, 8)
    with pytest.raises(ValueError, match="duplicate"):
        Graph([Node(id="a", kind="linear", op=lin),
               Node(id="a", kind="linear", op=lin)])
    with pytest.raises(ValueError, match="unknown node"):
        Graph([Node(id="a", kind="linear", op=lin, inputs=("ghost",))])
    with pytest.raises(ValueError, match="cycle"):
        Graph([Node(id="a", kind="linear", op=lin, inputs=("b",)),
               Node(id="b", kind="linear", op=lin, inputs=("a",)),
               Node(id="c", kind="linear", op=lin, inputs=("b",))])
    with pytest.raises(ValueError, match="exactly one output"):
        Graph([Node(id="a", kind="linear", op=lin),
               Node(id="b", kind="linear", op=lin)])


def test_topological_order_and_consumers():
    g, producer = fan_out_demo()
    ids = [n.id for n in g]
    assert ids.index(producer) < ids.index("left") < ids.index("join")
    assert set(g.consumers(producer)) == {"left", "right"}
    assert g.sole_consumer(producer) is None          # fan-out
    assert g.sole_consumer("left").id == "join"
    assert g.output.id == "join"
    assert [n.id for n in g.sources] == [producer]


def test_graph_json_round_trip_and_content_addressing():
    g = from_model("tiny_decoder", blocks=2)
    g2 = Graph.from_json(json.loads(json.dumps(g.to_json())))
    assert [n.id for n in g2] == [n.id for n in g]
    assert g2.fingerprint() == g.fingerprint()
    # renaming every id leaves the content-addressed fingerprint unchanged
    ren = {n.id: f"x{i}" for i, n in enumerate(g.nodes)}
    g3 = Graph([dataclasses.replace(n, id=ren[n.id],
                                    inputs=tuple(ren[s] for s in n.inputs))
                for n in g.nodes])
    assert g3.fingerprint() == g.fingerprint()
    # ...but changing structure changes it
    g4 = from_model("tiny_decoder", blocks=2, cache_len=64)
    assert g4.fingerprint() != g.fingerprint()


@pytest.mark.parametrize("network", sorted(NETWORKS))
def test_unit_chain_fingerprint_matches_legacy(network):
    from repro.runtime.plan import network_fingerprint
    units = NETWORKS[network]()
    g = from_units(units)
    assert g.is_unit_chain()
    assert g.fingerprint() == network_fingerprint(units)
    assert g.to_units() == units


def test_dags_are_not_unit_chains():
    g = from_model("tiny_ssm")
    assert not g.is_unit_chain()
    with pytest.raises(ValueError, match="unit chain"):
        g.to_units()


# ------------------------------------------- shape inference (satellite)

@pytest.mark.parametrize("kind", sorted(SAMPLE_OPS))
def test_shape_contracts_round_trip_codec(kind):
    """Satellite: for every registered kernel kind, input/output shapes
    survive the op JSON codec round trip."""
    assert sorted(SAMPLE_OPS) == registry.kinds(), \
        "new kernel kind registered without a shape-inference sample"
    op = SAMPLE_OPS[kind]
    entry = registry.get(kind)
    op2 = registry.op_from_json(json.loads(json.dumps(
        registry.op_to_json(op))))
    assert op2 == op
    assert entry.input_shape(op2) == entry.input_shape(op)
    assert entry.output_shape(op2) == entry.output_shape(op)
    assert entry.weight_shape(op2) == entry.weight_shape(op)
    assert registry.op_label(op2) == registry.op_label(op)


def test_pool_out_edge_rejects_nonpositive_bytes():
    """Satellite: non-positive byte counts fail with a clear error."""
    with pytest.raises(ValueError, match="positive output byte"):
        pool_out_edge(0, 64)
    with pytest.raises(ValueError, match="positive output byte"):
        pool_out_edge(-4, 64)
    with pytest.raises(ValueError, match="positive channel"):
        pool_out_edge(4 * 64, 0)
    assert pool_out_edge(4 * 56 * 56 * 64, 64) == 56


def test_graph_shape_inference():
    g = from_model("tiny_decoder")
    g.check_shapes()                       # strict edge validation passes
    assert g.output_shape("embed") == (1, 64)
    assert g.input_shape("b0.attn") == (1, 64)
    assert g.output_shape("b0.mlp_res") == (1, 64)
    assert g.input_shape("b0.attn_res") is None        # structural
    # pool shape recovery goes through the producer's channel count
    gc = from_units(NETWORKS["vgg16"]()[:4])
    pool_id = [n.id for n in gc if n.kind == "pool"][0]
    assert gc.output_shape(pool_id) == (112, 112, 64)
    # mismatched residual shapes are rejected
    lin = LinearOp(1, 8, 8)
    bad = Graph([Node(id="a", kind="linear", op=lin),
                 Node(id="b", kind="linear", op=LinearOp(1, 8, 16),
                      inputs=("a",)),
                 Node(id="j", kind="add", inputs=("a", "b"))])
    with pytest.raises(ValueError, match="mismatched shapes"):
        bad.output_shape("j")


def test_from_model_resolves_registry_names():
    assert "tiny_decoder" in model_names()
    g = from_model("gemma3-12b")           # alias through models.registry
    kinds = {n.kind for n in g}
    assert "attention" in kinds
    with pytest.raises(ValueError, match="unknown model"):
        from_model("not_a_model")


# ------------------------------------------------------------- planning

def test_plan_graph_bit_identical_to_pre_ir_planner(mux_predictors):
    """Acceptance: from_units(vgg16()) plans bit-identical decisions (and
    totals) to the pre-IR unit-list planner."""
    from repro.core.planner import plan_graph, plan_network
    cp, gp = mux_predictors
    units = NETWORKS["vgg16"]()
    ref = plan_network(units, cp, gp, threads=3)
    got = plan_graph(from_units(units), cp, gp, threads=3)
    assert list(got.decisions.values()) == ref.decisions
    assert got.baseline_us == ref.baseline_us
    assert got.individual_us == ref.individual_us
    assert got.end_to_end_us == ref.end_to_end_us
    assert got.opaque_us == {}
    assert list(got.decisions) == [f"n{i}" for i, (k, _) in
                                   enumerate(units) if k != "pool"]


def test_graph_cached_planner_warm_hits_unit_list_entries(mux_predictors,
                                                          tmp_path):
    """Legacy network_fingerprint keys stay warm: the graph spelling hits
    the entry the unit spelling wrote, and the stored bytes stay in the
    pre-IR format (no ids, no graph section)."""
    from repro.runtime import PlanCache, plan_graph_cached, \
        plan_network_cached
    cp, gp = mux_predictors
    units = NETWORKS["resnet18"]()[:6]
    cache = PlanCache(tmp_path)
    p1 = plan_network_cached(units, cp, gp, threads=3, cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    stored = cache.path_for(p1.provenance).read_bytes()
    p2 = plan_graph_cached(from_units(units), cp, gp, threads=3,
                           cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    assert p2.key == p1.key
    assert cache.path_for(p2.provenance).read_bytes() == stored
    doc = json.loads(stored)
    assert "graph" not in doc
    assert all("id" not in e for e in doc["schedule"])
    # per-node decision view works on legacy plans via canonical ids
    assert list(p2.decisions_by_node) == \
        [f"n{i}" for i, (k, _) in enumerate(units) if k != "pool"]


def test_dag_plan_serializes_with_graph_and_ids(mux_predictors, tmp_path):
    from repro.runtime import CoexecPlan, PlanCache, plan_graph_cached
    cp, gp = mux_predictors
    g = from_model("tiny_decoder")
    cache = PlanCache(tmp_path)
    plan = plan_graph_cached(g, cp, gp, threads=3, cache=cache)
    doc = json.loads(plan.dumps())
    assert doc["provenance"]["network_fingerprint"] == g.fingerprint()
    assert [e["id"] for e in doc["schedule"]] == [n.id for n in g]
    attn = [e for e in doc["schedule"] if e["unit"] == "attention"]
    assert len(attn) == 1 and attn[0]["pred_us"] > 0 and "op" in attn[0]
    assert {e["unit"] for e in doc["schedule"]} == \
        {"linear", "attention", "add"}
    back = CoexecPlan.loads(plan.dumps())
    assert back.decisions_by_node.keys() == plan.decisions_by_node.keys()
    assert back.graph_ir().fingerprint() == g.fingerprint()
    with pytest.raises(ValueError, match="graph_ir"):
        back.units
    # warm hit on the second compile of the same graph
    plan_graph_cached(g, cp, gp, threads=3, cache=cache)
    assert cache.hits == 1


def test_custom_id_chain_plans_canonicalize_to_legacy_format(
        mux_predictors, tmp_path):
    """A unit-chain graph with non-canonical ids fingerprints to the
    legacy digest (content-addressed: ids don't matter) — so its plan
    must also SERIALIZE in the legacy format, or one cache key would map
    to two payload shapes depending on who planned first."""
    from repro.runtime import PlanCache, plan_graph_cached, \
        plan_network_cached
    cp, gp = mux_predictors
    units = NETWORKS["resnet18"]()[:4]
    chain = from_units(units)
    renamed = Graph([
        dataclasses.replace(n, id=f"layer.{i}",
                            inputs=(f"layer.{i-1}",) if n.inputs else ())
        for i, n in enumerate(chain.nodes)])
    assert renamed.fingerprint() == chain.fingerprint()
    cache = PlanCache(tmp_path)
    p1 = plan_graph_cached(renamed, cp, gp, threads=3, cache=cache)
    doc = json.loads(p1.dumps())
    assert "graph" not in doc and all("id" not in e
                                      for e in doc["schedule"])
    assert p1.units == units                 # legacy view stays available
    # the unit-list spelling warm-hits the same entry, same payload shape
    p2 = plan_network_cached(units, cp, gp, threads=3, cache=cache)
    assert cache.hits == 1 and p2.key == p1.key
    assert list(p2.decisions_by_node) == \
        [f"n{i}" for i, (k, _) in enumerate(units) if k != "pool"]


def test_opaque_latency_is_positive_and_scales():
    from repro.core.planner import opaque_latency_us
    small = opaque_latency_us(AttnOp(H=4, S=64, KV=2, hd=16), "moto2022")
    big = opaque_latency_us(AttnOp(H=4, S=4096, KV=2, hd=16), "moto2022")
    assert 0 < small < big


# ------------------------------------------------------------------ api

def test_compile_accepts_graphs_and_model_names(mux_predictors, tmp_path):
    import repro
    cp, gp = mux_predictors
    target = repro.Target(device="moto2022", threads=3)
    g = from_model("tiny_decoder")
    c1 = repro.compile(g, target, predictors=(cp, gp), cache=tmp_path)
    assert not c1.from_cache
    c2 = repro.compile("tiny_decoder", target, predictors=(cp, gp),
                       cache=tmp_path)
    assert c2.from_cache and c2.key == c1.key     # name -> same graph
    assert set(c1.decisions_by_node) == \
        {n.id for n in g if n.splittable}
    assert c1.graph.fingerprint() == g.fingerprint()
    text = c1.explain()
    assert "b0.attn" in text and "gpu-only (unsplit kind)" in text


def test_compile_unknown_name_lists_both_registries(tmp_path):
    import repro
    target = repro.Target(device="moto2022")
    with pytest.raises(ValueError) as ei:
        repro.compile("mobilenet_v9", target, cache=tmp_path)
    msg = str(ei.value)
    assert "resnet18" in msg and "tiny_decoder" in msg
    names = repro.available_networks()
    assert "vgg16" in names["networks"] and "tiny_ssm" in names["models"]


def test_compile_grid_mode_plans_graphs(tmp_path):
    import repro
    target = repro.Target(device="moto2022", threads=3, seed=0)
    c = repro.compile("tiny_ssm", target, mode="grid", cache=tmp_path)
    assert c.plan.provenance.planner == "grid"
    specs = {s.unit for s in c.plan.exec_specs()}
    assert "ssm" in specs
    c2 = repro.compile("tiny_ssm", target, mode="grid", cache=tmp_path)
    assert c2.from_cache


# -------------------------------------------- execution (degraded mesh)

@pytest.mark.parametrize("model", ["tiny_decoder", "tiny_ssm",
                                   "tiny_hybrid"])
def test_model_graph_executes_and_records_through_cached_path(
        mux_predictors, tmp_path, model):
    """Acceptance: attention/SSM blocks plan, execute, and record
    measurements through the same cached path as the conv nets, and the
    executed output matches the unsplit oracle."""
    import repro
    from repro.measure import MeasurementStore
    cp, gp = mux_predictors
    target = repro.Target(device="moto2022", threads=3)
    blocks = 2 if model == "tiny_hybrid" else 1
    g = from_model(model, blocks=blocks, cache_len=64)
    compiled = repro.compile(g, target, predictors=(cp, gp),
                             cache=tmp_path / "plans")
    store = MeasurementStore(tmp_path / "meas")
    report = compiled.record(store=store, warmup=False)
    exe = compiled.executor()
    np.testing.assert_allclose(
        np.asarray(compiled.run(), np.float32),
        np.asarray(exe.run_oracle(), np.float32), rtol=2e-4, atol=2e-4)
    assert len(report.timings) == len(g)
    assert [t.node_id for t in report.timings] == [n.id for n in g]
    opaque = [t for t in report.timings if t.unit in ("attention", "ssm")]
    assert opaque and all(t.mode == "exclusive" and t.pred_us > 0
                          for t in opaque)
    # the records landed in the store under this plan's provenance digest
    records = store.load(compiled.key)
    assert len(records) == len(g)
    assert {r.node_id for r in records} == {n.id for n in g}
    # second compile of the same graph is a pure cache hit
    again = repro.compile(g, target, predictors=(cp, gp),
                          cache=tmp_path / "plans")
    assert again.from_cache and again.key == compiled.key


def test_plan_diff_carries_node_ids(mux_predictors, tmp_path):
    from repro.core.sync import SyncMechanism
    from repro.measure.replan import diff_plans
    from repro.runtime import PlanCache, plan_graph_cached
    cp, gp = mux_predictors
    g, producer = fan_out_demo(c=48)
    cache = PlanCache(tmp_path)
    plan = plan_graph_cached(g, cp, gp, threads=3, cache=cache)
    # a hand-moved decision set over the same graph -> deterministic diff
    # (flip the producer's split to whatever the planner did NOT choose)
    moved = dict(plan.decisions_by_node)
    target = moved[producer]
    flipped_gpu = 0 if target.c_gpu else target.op.C_out
    moved[producer] = dataclasses.replace(
        target, c_cpu=target.op.C_out - flipped_gpu, c_gpu=flipped_gpu)
    from repro.runtime.plan import build_graph_schedule
    other = dataclasses.replace(
        plan, schedule=build_graph_schedule(g, moved, {}))
    diff = diff_plans(plan, other, cp, gp,
                      mechanism=SyncMechanism.SVM_POLL)
    changed = [c for c in diff.changes]
    assert changed and changed[0].node_id == producer
    assert producer in diff.summary()


# ------------------------------ split execution + fan-out (subprocess)

_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.coexec import coexec_mesh
    from repro.core.partitioner import PartitionDecision
    from repro.core.types import LinearOp
    from repro.graph import Graph, Node
    from repro.runtime.executor import PlanExecutor
    from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                    build_graph_schedule)

    C = 48
    nodes = [
        Node(id="l1", kind="linear", op=LinearOp(4, 32, C)),
        Node(id="l2", kind="linear", op=LinearOp(4, C, C),
             inputs=("l1",)),
        Node(id="left", kind="linear", op=LinearOp(4, C, C),
             inputs=("l2",)),
        Node(id="right", kind="linear", op=LinearOp(4, C, C),
             inputs=("l2",)),
        Node(id="join", kind="add", inputs=("left", "right")),
    ]
    g = Graph(nodes)

    def dec(op, c_gpu):
        return PartitionDecision(op=op, c_cpu=op.C_out - c_gpu,
                                 c_gpu=c_gpu, pred_cpu_us=1.0,
                                 pred_gpu_us=1.0, pred_total_us=2.0)

    decisions = {n.id: dec(n.op, 32) for n in g if n.op is not None}
    prov = PlanProvenance(
        device="moto2022", threads=3, mechanism="svm_poll", step=8,
        seed=1, network_fingerprint=g.fingerprint(),
        predictor_checksum="")
    plan = CoexecPlan(provenance=prov,
                      schedule=build_graph_schedule(g, decisions, {}),
                      graph_json=g.to_json())

    mesh = coexec_mesh(jax.devices())
    exe = PlanExecutor(plan, mesh=mesh)
    assert exe.split_capable
    y_chain, rep_chain = exe.run(chain=True)
    y_gather, rep_gather = exe.run(chain=False)
    y_oracle = exe.run_oracle()
    np.testing.assert_allclose(np.asarray(y_chain), np.asarray(y_oracle),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(y_gather), np.asarray(y_oracle),
                               rtol=2e-5, atol=2e-5)

    # l1 -> l2 is a sole-consumer compatible edge: elided when chaining
    assert rep_chain.elided == 1 and rep_gather.elided == 0
    # acceptance: the fanned-out split output (l2) is gathered exactly
    # once.  chain=True reshard points: l2 (shared by left+right, ONCE),
    # left, right = 3.  A per-consumer gather would make it 4, and the
    # no-elision run pays l1's gather too: 4 total.
    assert rep_chain.reshard_points == 3, rep_chain.reshard_points
    assert rep_gather.reshard_points == 4, rep_gather.reshard_points
    by_id = {t.node_id: t for t in rep_chain.timings}
    # records snapshot gather state at compute time: l2 is still
    # group-local here — its single gather happens when `left` consumes
    # it (and `right` reuses the materialized activation)
    assert not by_id["l1"].gathered_output
    assert not by_id["l2"].gathered_output
    assert by_id["l2"].chained_input         # l1 -> l2 elided edge
    assert not by_id["left"].chained_input   # fan-out edge cannot chain
    assert by_id["l2"].mode == by_id["left"].mode == "coexec"
    print("FANOUT_GATHER_ONCE_OK")
""")


def test_fan_out_gathers_shared_split_output_exactly_once():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FANOUT_GATHER_ONCE_OK" in out.stdout


# ------------------------------------------------------ CLI / bench

def test_bench_list_prints_suite_names(capsys):
    """Satellite: `benchmarks/run.py --list` prints the registered suite
    names and exits 0 (no suite module imports)."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    try:
        from benchmarks.run import SUITES, main
        assert main(["--list"]) == 0
    finally:
        sys.path.pop(0)
    lines = capsys.readouterr().out.strip().splitlines()
    assert lines == list(SUITES)
    assert {"tab2", "tab3", "calibration"} <= set(lines)


def test_cli_surfaces_registry_error(capsys, tmp_path):
    from repro.cli import main
    assert main(["plan", "--network", "mobilenet_v9",
                 "--cache-dir", str(tmp_path)]) == 2
    err = capsys.readouterr().err
    assert "unknown network" in err and "tiny_decoder" in err


def test_cli_plans_and_executes_model_graphs(capsys, tmp_path):
    from repro.cli import main
    args = ["--model", "tiny_decoder", "--samples", "60",
            "--estimators", "15", "--cache-dir", str(tmp_path)]
    assert main(["plan", *args, "--explain"]) == 0
    out = capsys.readouterr().out
    assert "cache MISS" in out and "b0.attn" in out
    assert main(["execute", *args, "--no-warmup"]) == 0
    out = capsys.readouterr().out
    assert "cache HIT" in out and "fidelity:" in out


def test_cli_network_accepts_model_names_with_graph_knobs(capsys,
                                                          tmp_path):
    """A model name passed via --network honors --blocks/--cache-len
    exactly like --model (the help text invites either spelling)."""
    from repro.cli import main
    assert main(["plan", "--network", "tiny_decoder", "--blocks", "2",
                 "--cache-len", "64", "--samples", "60",
                 "--estimators", "15", "--cache-dir", str(tmp_path),
                 "--explain"]) == 0
    out = capsys.readouterr().out
    assert "b1.attn" in out                  # second block exists
    assert "S64" in out                      # cache_len reached the AttnOp
