"""Tests for the substrate layers: optimizer, checkpointing, data pipeline,
serving engine, planner."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import DataConfig, SyntheticTokenStream, make_batch
from repro.models import build_model, get_config
from repro.optim import AdamWConfig, adamw_update, init_adamw
from repro.serving import Request, ServingEngine


# ---------------------------------------------------------------- optimizer
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                      total_steps=200)
    state = init_adamw(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        grads = jax.grad(loss)(params)
        params, state = adamw_update(cfg, params, grads, state)
    assert float(loss(params)) < 1e-2


def test_adamw_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, warmup_steps=1,
                      weight_decay=0.0)
    state = init_adamw(params)
    huge = {"w": jnp.asarray([1e9, 0.0, 0.0])}
    new, _ = adamw_update(cfg, params, huge, state)
    assert np.all(np.abs(np.asarray(new["w"])) < 10.0)


def test_adamw_state_tree_matches_params():
    cfg = get_config("rwkv6_1b6").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = init_adamw(params)
    assert jax.tree.structure(state.mu) == jax.tree.structure(params)


# --------------------------------------------------------------- checkpoint
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    restored = restore_checkpoint(tmp_path, 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert x.dtype == y.dtype
        np.testing.assert_array_equal(np.asarray(x, np.float32),
                                      np.asarray(y, np.float32))


# --------------------------------------------------------------------- data
def test_token_stream_shapes_and_determinism():
    cfg = DataConfig(batch_size=3, seq_len=32, seed=5)
    a = next(iter(SyntheticTokenStream(1000, cfg)))
    b = next(iter(SyntheticTokenStream(1000, cfg)))
    assert a["tokens"].shape == (3, 32)
    assert a["labels"].shape == (3, 32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # next-token alignment
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    assert a["tokens"].max() < 1000 and a["tokens"].min() >= 0


def test_make_batch_adds_frames_for_encdec():
    cfg = get_config("whisper_large_v3").reduced()
    batch = make_batch(cfg, 2, 16)
    assert batch["frames"].shape == (2, cfg.encoder_seq, cfg.d_model)


# ------------------------------------------------------------------ serving
def test_serving_engine_generates_requested_tokens():
    cfg = get_config("codeqwen15_7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, model, params, max_batch=2, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=5 + i).astype(np.int32),
                    max_new_tokens=4 + i) for i in range(3)]
    out = engine.run(reqs)
    assert len(out) == 3
    for r, c in zip(reqs, out):
        assert c.rid == r.rid
        assert len(c.tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in c.tokens)


def test_serving_greedy_is_deterministic():
    cfg = get_config("rwkv6_1b6").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
    req = [Request(rid=0, prompt=prompt, max_new_tokens=6)]
    e1 = ServingEngine(cfg, model, params, max_len=32)
    e2 = ServingEngine(cfg, model, params, max_len=32)
    assert e1.run(req)[0].tokens == e2.run(req)[0].tokens
