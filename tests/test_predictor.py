"""Tests for the GBDT predictors and feature augmentation (Sections 3, 5.2)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # graceful fallback, see hypothesis_fallback
    from hypothesis_fallback import given, settings, st

from repro.core.predictor import (GBDTParams, GBDTRegressor, mape,
                                  measure_ops, sample_linear_ops,
                                  train_predictor)
from repro.core.predictor.features import whitebox_features, blackbox_features
from repro.core.types import LinearOp

_FAST = GBDTParams(n_estimators=80, max_depth=7, learning_rate=0.15)


def test_gbdt_fits_nonlinear_function():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 10, size=(3000, 6))
    y = np.sin(X[:, 0]) * X[:, 1] + 3.0 * (X[:, 2] > 5) + 0.3 * X[:, 3]
    m = GBDTRegressor(_FAST).fit(X[:2500], y[:2500])
    err = np.abs(m.predict(X[2500:]) - y[2500:]).mean()
    assert err < 0.35 * np.abs(y).mean()


def test_gbdt_predict_deterministic():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(500, 4))
    y = X[:, 0] * 2 + X[:, 1] ** 2
    m = GBDTRegressor(_FAST, seed=7).fit(X, y)
    assert np.array_equal(m.predict(X), m.predict(X))


@settings(max_examples=20, deadline=None)
@given(L=st.integers(1, 256), c_in=st.integers(8, 2048),
       c_out=st.integers(8, 4096))
def test_feature_matrices_are_finite(L, c_in, c_out):
    ops = [LinearOp(L, c_in, c_out)]
    assert np.isfinite(blackbox_features(ops)).all()
    assert np.isfinite(whitebox_features(ops, "pixel5")).all()


def test_whitebox_beats_blackbox_on_gpu(linear_train_ops):
    """The paper's central prediction claim (Tab. 4 ablation)."""
    test = sample_linear_ops(250, seed=9)
    y = measure_ops(test, "oneplus11", "gpu")
    bb = train_predictor(linear_train_ops, "oneplus11", "gpu",
                         whitebox=False, params=_FAST)
    wb = train_predictor(linear_train_ops, "oneplus11", "gpu",
                         whitebox=True, params=_FAST)
    m_bb = mape(bb.predict(test), y)
    m_wb = mape(wb.predict(test), y)
    assert m_wb < m_bb, (m_wb, m_bb)
    assert m_wb < 0.12          # Table 1 GPU MAPEs are 3.7%-4.4%


def test_cpu_predictor_accuracy(linear_train_ops):
    test = sample_linear_ops(250, seed=9)
    p = train_predictor(linear_train_ops, "moto2022", "cpu2",
                        whitebox=False, params=_FAST)
    m = mape(p.predict(test), measure_ops(test, "moto2022", "cpu2"))
    assert m < 0.12             # Table 1 CPU MAPEs are 2.4%-11.5%


def test_predictor_save_load(tmp_path, pixel5_linear_predictors):
    cp, gp = pixel5_linear_predictors
    path = tmp_path / "gp.pkl"
    gp.save(path)
    from repro.core.predictor import LatencyPredictor
    gp2 = LatencyPredictor.load(path)
    ops = sample_linear_ops(20, seed=3)
    assert np.allclose(gp.predict(ops), gp2.predict(ops))


def test_hpo_runs_and_returns_predictor():
    ops = sample_linear_ops(300, seed=5)
    p = train_predictor(ops, "pixel4", "gpu", whitebox=True, hpo_trials=2)
    assert p.predict(ops[:5]).shape == (5,)
