"""Per-architecture smoke tests (assignment requirement).

Each assigned architecture is instantiated as a REDUCED same-family variant
(2 layers, d_model<=512, <=4 experts) and runs one forward/train step on
CPU, asserting output shapes and absence of NaNs; serving architectures
also run prefill + decode and check consistency with the full forward.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import make_batch
from repro.models import ARCH_IDS, build_model, get_config

B, T = 2, 16


def _reduced(arch):
    cfg = get_config(arch).reduced()
    return cfg, build_model(cfg)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_no_nans(arch):
    cfg, model = _reduced(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, B, T, seed=1).items()}

    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    flat = jax.tree.leaves(grads)
    assert flat, f"{arch}: empty grads"
    for g in flat:
        assert np.all(np.isfinite(np.asarray(g, np.float32))), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    """Prefill T tokens then decode one more; the decode logits must match
    a full forward over T+1 tokens (numerical tolerance)."""
    cfg, model = _reduced(arch)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, T + 1)),
                       jnp.int32)
    max_len = T + 8
    cache = model.init_cache(B, max_len)

    if cfg.is_encoder_decoder:
        frames = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.float32)
        logits_pre, cache = jax.jit(model.prefill)(params, toks[:, :T],
                                                   cache, frames)
        logits_dec, _ = jax.jit(model.decode_step)(
            params, toks[:, T:T + 1], cache, jnp.int32(T))
        assert logits_dec.shape == (B, cfg.vocab_size)
        assert np.all(np.isfinite(np.asarray(logits_dec, np.float32)))
        return

    logits_pre, cache = jax.jit(model.prefill)(params, toks[:, :T], cache)
    assert logits_pre.shape == (B, cfg.vocab_size)
    logits_dec, cache2 = jax.jit(model.decode_step)(
        params, toks[:, T:T + 1], cache, jnp.int32(T))
    assert logits_dec.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits_dec, np.float32)))

    # oracle: full forward over T+1 tokens (attention/ssm paths only; MoE
    # dispatch differs between shapes due to per-batch capacity, so compare
    # only for non-MoE architectures)
    if not cfg.is_moe:
        if hasattr(model, "forward"):
            full_logits, _ = jax.jit(model.forward)(params, toks)
            np.testing.assert_allclose(
                np.asarray(logits_dec, np.float32),
                np.asarray(full_logits[:, -1, :], np.float32),
                rtol=0.08, atol=0.08)


def test_reduced_configs_are_small():
    for arch in ARCH_IDS:
        cfg = get_config(arch).reduced()
        assert cfg.n_layers <= 4
        assert cfg.d_model <= 512
        assert cfg.n_experts <= 4
