"""Tests for the measurement/calibration/replanning subsystem
(repro.measure) and its integrations: the executor's unified records, the
on-disk store, calibrator fitting + persistence, calibrated replanning
through the plan cache, and the serving engine's auto-record/drift hooks.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.predictor import (sample_conv_ops, sample_linear_ops,
                                  train_predictor, training_from_records)
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.core.simulator.measure import (measure_latency_us_batch,
                                          measure_records)
from repro.core.types import ConvOp, LinearOp
from repro.measure import (Calibrator, CalibratedPredictor,
                           MeasurementRecord, MeasurementStore,
                           fidelity_error, record_for_op)
from repro.runtime import (PlanCache, PlanExecutor, calibration_version,
                           plan_network_cached, predictor_checksum)
from repro.runtime.executor import ExecutionReport, OpTiming
from repro.runtime.plan import PlanProvenance

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


def _small_units():
    return [("conv", ConvOp(28, 28, 32, 64, 3, 1)),
            ("conv", ConvOp(28, 28, 64, 64, 3, 2)),
            ("pool", 4 * 7 * 7 * 64),
            ("conv", ConvOp(7, 7, 64, 96, 3, 1)),
            ("pool", 4 * 96),
            ("linear", LinearOp(1, 96, 128))]


def _plan(units, mux_predictors, cache_dir):
    cp, gp = mux_predictors
    return plan_network_cached(units, cp, gp, threads=3,
                               cache=PlanCache(cache_dir))


# ---------------------------------------------------------- record schema

def test_measurement_record_json_roundtrip_bitstable():
    recs = [
        record_for_op(LinearOp(4, 32, 64), index=3, wall_us=12.5,
                      pred_us=3.25, device="moto2022", backend="gpu"),
        record_for_op(ConvOp(28, 28, 32, 64, 3, 2), wall_us=1234.0625,
                      pred_us=980.5, device="pixel5", backend="cpu3",
                      host="ci", plan_key="abc",
                      network_fingerprint="def"),
        MeasurementRecord(index=2, unit="pool", label="pool 64B",
                          mode="pool", c_fast=0, c_slow=0,
                          chained_input=False, gathered_output=True,
                          wall_us=7.03125, pred_us=0.0),
    ]
    for r in recs:
        doc = r.to_json()
        back = MeasurementRecord.from_json(json.loads(json.dumps(doc)))
        assert back == r                       # dataclass equality, op incl.
        assert back.to_json() == doc           # bit-stable re-encode


def test_record_features_route_through_registry():
    from repro.kernels import registry
    op = ConvOp(8, 8, 16, 24, 3, 2)
    r = record_for_op(op, wall_us=1.0, pred_us=1.0)
    assert r.features() == registry.get("conv").base_features(op)
    assert r.unit == "conv" and r.label == registry.op_label(op)
    pool = MeasurementRecord(index=0, unit="pool", label="pool", mode="pool",
                             c_fast=0, c_slow=0, chained_input=False,
                             gathered_output=True, wall_us=1.0, pred_us=0.0)
    assert pool.features() is None


def test_optiming_is_the_measurement_record():
    """The executor's one-off OpTiming format was unified into the shared
    schema; the alias (and its 10-field constructor) keeps working."""
    assert OpTiming is MeasurementRecord
    t = OpTiming(index=0, unit="linear", label="l", mode="exclusive",
                 c_fast=8, c_slow=0, chained_input=False,
                 gathered_output=True, wall_us=2.0, pred_us=1.0)
    assert t.op is None and t.source == "executor"


def test_execution_report_json_roundtrip(mux_predictors, tmp_path):
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    exe = PlanExecutor(plan)
    _, rep = exe.run()
    doc = json.loads(json.dumps(rep.to_json()))
    back = ExecutionReport.from_json(doc)
    assert back == rep
    assert back.to_json() == rep.to_json()     # bit-stable
    # records carry the store-keying provenance
    for t in rep.timings:
        assert t.plan_key == plan.key
        assert t.network_fingerprint == plan.provenance.network_fingerprint
        assert t.device == plan.provenance.device
        assert t.host != ""
    # conv/linear records embed their op; pools don't
    assert all((t.op is None) == (t.unit == "pool") for t in rep.timings)


# ------------------------------------------------------------------ store

def test_measurement_store_append_only(mux_predictors, tmp_path):
    plan = _plan(_small_units(), mux_predictors, tmp_path / "plans")
    exe = PlanExecutor(plan)
    _, rep = exe.run()
    store = MeasurementStore(tmp_path / "meas")
    store.append(rep)                          # an ExecutionReport directly
    assert store.keys() == [plan.key]
    assert store.count(plan.key) == len(rep.timings)
    _, rep2 = exe.run()
    store.append(rep2.timings)                 # or bare records
    loaded = store.load(plan.key)
    assert len(loaded) == 2 * len(rep.timings)     # append-only: both runs
    assert loaded[:len(rep.timings)] == rep.timings
    # corrupt lines are skipped, never trusted
    with open(store.path_for(plan.key), "a") as f:
        f.write("{not json}\n")
    assert len(store.load(plan.key)) == 2 * len(rep.timings)


def test_store_keys_match_plan_cache_digests(mux_predictors, tmp_path):
    """The store files sit under the same provenance digests as the plan
    cache, so a plan's measurements are found from its cache key."""
    cache = PlanCache(tmp_path / "plans")
    plan = _plan(_small_units(), mux_predictors, tmp_path / "plans")
    store = MeasurementStore(tmp_path / "meas")
    _, rep = PlanExecutor(plan).run()
    store.append(rep)
    assert store.path_for(plan.key).stem == cache.path_for(
        plan.provenance).stem


# ------------------------------------------- simulator + training records

def test_simulator_measure_records_unified_schema():
    ops = [LinearOp(64, 128, 256), ConvOp(28, 28, 32, 64, 3, 1)]
    recs = measure_records(ops, "pixel5", "gpu", seed=3)
    walls = measure_latency_us_batch(ops, "pixel5", "gpu", seed=3)
    np.testing.assert_allclose([r.wall_us for r in recs], walls)
    assert [r.op for r in recs] == ops
    assert all(r.source == "simulator" and r.backend == "gpu"
               and r.mode == "simulated" and r.device == "pixel5"
               for r in recs)
    # noise-free oracle as the prediction side
    assert all(r.pred_us > 0 and r.wall_us != r.pred_us for r in recs)


def test_records_become_training_samples_with_zero_glue():
    ops = sample_linear_ops(60, seed=7)
    recs = measure_records(ops, "moto2022", "cpu3", seed=5)
    tr_ops, y = training_from_records(recs)
    assert tr_ops == ops and len(y) == len(ops)
    pred = train_predictor(tr_ops, "moto2022", "cpu3", whitebox=False,
                           y_us=y, params=_FAST)
    out = pred.predict(ops[:5])
    assert out.shape == (5,) and np.all(np.isfinite(out)) and np.all(out > 0)


def test_training_from_records_drops_pools_coexec_and_nonpositive():
    recs = [record_for_op(LinearOp(1, 8, 8), wall_us=5.0, pred_us=1.0),
            record_for_op(LinearOp(1, 8, 8), wall_us=0.0, pred_us=1.0),
            # co-executed: wall times a channel-split run of the full op —
            # not a valid per-backend (op, latency) pair
            record_for_op(LinearOp(1, 8, 8), wall_us=2.5, pred_us=1.0,
                          mode="coexec", source="executor"),
            MeasurementRecord(index=0, unit="pool", label="p", mode="pool",
                              c_fast=0, c_slow=0, chained_input=False,
                              gathered_output=True, wall_us=3.0,
                              pred_us=0.0)]
    ops, y = training_from_records(recs)
    assert len(ops) == 1 and y.tolist() == [5.0]
    # mixed executed runs split per kind (predictors are per-kind models)
    recs.append(record_for_op(ConvOp(8, 8, 4, 4, 3, 1), wall_us=7.0,
                              pred_us=1.0, mode="exclusive",
                              source="executor"))
    lin_ops, lin_y = training_from_records(recs, kind="linear")
    conv_ops, conv_y = training_from_records(recs, kind="conv")
    assert [o.C_out for o in lin_ops] == [8] and lin_y.tolist() == [5.0]
    assert len(conv_ops) == 1 and conv_y.tolist() == [7.0]


# ------------------------------------------------------------- calibrator

def _synth_records(scale=40.0, slope=1.0, n=24, mode="exclusive"):
    rng = np.random.default_rng(0)
    recs = []
    for i in range(n):
        pred = float(rng.uniform(50, 5000))
        wall = scale * pred ** slope * float(np.exp(rng.normal(0, 0.05)))
        recs.append(record_for_op(LinearOp(1, 8 * (i + 1), 16),
                                  index=i, wall_us=wall, pred_us=pred,
                                  mode=mode, source="executor"))
    return recs


def test_calibrator_shrinks_fidelity_error_and_never_increases_it():
    recs = _synth_records(scale=40.0)
    cal = Calibrator.fit(recs)
    pre = fidelity_error(recs)
    post = cal.fidelity_error(recs)
    assert post < pre                  # ~log(40) per record shrunk away
    assert post < 0.1 * pre
    # identity is always a fit candidate: already-calibrated records
    # cannot get worse
    perfect = _synth_records(scale=1.0, n=12)
    cal2 = Calibrator.fit(perfect)
    assert cal2.fidelity_error(perfect) <= fidelity_error(perfect) + 1e-9


def test_calibrator_fits_per_kind_and_mode():
    recs = (_synth_records(scale=10.0, mode="exclusive")
            + _synth_records(scale=100.0, mode="coexec"))
    cal = Calibrator.fit(recs)
    assert ("linear", "exclusive") in cal.corrections
    assert ("linear", "coexec") in cal.corrections
    assert ("linear", "*") in cal.corrections
    ex = cal.correction_for("linear", "exclusive")
    co = cal.correction_for("linear", "coexec")
    assert ex.b < co.b                 # different offsets per mode
    # the per-kind aggregate (what wraps per-backend predictors) is fit on
    # unsplit records only — coexec unit totals must not leak into it
    assert cal.correction_for("linear", "*").n == ex.n
    # unknown mode falls back to the per-kind aggregate; unknown kind is
    # the identity
    assert cal.correction_for("linear", "never-seen") == \
        cal.correction_for("linear", "*")
    np.testing.assert_allclose(cal.correct_us("conv", "*", [7.0]), [7.0])
    # zero predictions stay zero (the partitioner's empty-side candidates)
    np.testing.assert_allclose(
        cal.correct_us("linear", "exclusive", [0.0]), [0.0])


def test_calibrator_raises_on_zero_usable_records():
    pool_only = [MeasurementRecord(
        index=0, unit="pool", label="p", mode="pool", c_fast=0, c_slow=0,
        chained_input=False, gathered_output=True, wall_us=3.0, pred_us=0.0)]
    with pytest.raises(ValueError, match="zero usable"):
        Calibrator.fit(pool_only)


def test_calibrator_persists_across_processes(tmp_path):
    """Satellite: save → load in a fresh interpreter reproduces the exact
    corrections and the content-addressed version digest."""
    cal = Calibrator.fit(_synth_records())
    path = cal.save(tmp_path / "cal.json")
    back = Calibrator.load(path)
    assert back.corrections == cal.corrections
    assert back.version == cal.version
    prog = (
        "from repro.measure import Calibrator\n"
        f"cal = Calibrator.load({str(path)!r})\n"
        "print(cal.version, cal.n_records, len(cal.corrections))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    ver, n, ncorr = out.stdout.split()
    assert ver == cal.version
    assert (int(n), int(ncorr)) == (cal.n_records, len(cal.corrections))


# ----------------------------------------- calibrated predictors + keys

def test_calibrated_predictor_wraps_without_retraining(mux_predictors):
    cp, _ = mux_predictors
    cal = Calibrator.fit(_synth_records(scale=3.0))
    wrapped = cal.wrap(cp)
    assert isinstance(wrapped, CalibratedPredictor)
    assert wrapped.device == cp.device
    ops = [LinearOp(32, 64, 128), ConvOp(14, 14, 32, 64, 3, 1)]
    base = cp.predict(ops)
    out = wrapped.predict(ops)
    # linear ops corrected by the fitted (linear, *) group; conv untouched
    # (never measured in the synthetic records)
    corr = cal.correction_for("linear", "*")
    np.testing.assert_allclose(out[0], float(corr.apply_us(base[0])))
    np.testing.assert_allclose(out[1], base[1])
    # re-wrapping never stacks corrections
    assert cal.wrap(wrapped).inner is cp
    # checksum unwraps: calibration invalidates via provenance instead
    assert predictor_checksum(wrapped) == predictor_checksum(cp)
    assert calibration_version(wrapped) == cal.version
    assert calibration_version(cp) == ""


def test_provenance_calibration_field_changes_key_only_when_set():
    base = dict(device="moto2022", threads=3, mechanism="svm_poll", step=8,
                seed=1, network_fingerprint="nf", predictor_checksum="pc")
    p0 = PlanProvenance(**base)
    p1 = PlanProvenance(**base, calibration="")
    p2 = PlanProvenance(**base, calibration="deadbeef")
    assert p0.key == p1.key            # legacy keys/json stay bit-identical
    assert "calibration" not in p0.to_json()
    assert p2.key != p0.key
    assert p2.to_json()["calibration"] == "deadbeef"
    assert PlanProvenance.from_json(p0.to_json()) == p0
    assert PlanProvenance.from_json(p2.to_json()) == p2


# ------------------------------------------------- executor warmup guard

def test_warmup_run_does_not_publish_report(mux_predictors, tmp_path):
    """Satellite: the untimed warmup pass must never land on last_report —
    a warmup report leaking there would poison the measurement store."""
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    exe = PlanExecutor(plan)
    _, internal = exe._execute()
    assert exe.last_report is None     # _execute never publishes
    _, rep = exe.run(warmup=True)
    assert exe.last_report is rep      # only the timed run published


# --------------------------------------------------- acceptance criterion

@pytest.mark.parametrize("network", ["resnet18", "vgg16"])
def test_recalibrate_and_replan_end_to_end(mux_predictors, tmp_path,
                                           network):
    """Acceptance: >= 2 recorded executions -> recalibrate() shrinks the
    executed-vs-predicted fidelity error; replan() round-trips through the
    plan cache under a new provenance digest with the old entry untouched.
    """
    import repro
    from repro.core.networks import NETWORKS

    cache_dir = tmp_path / "plans"
    cache = PlanCache(cache_dir)
    target = repro.Target(device="moto2022", threads=3)
    compiled = repro.compile(NETWORKS[network](), target,
                             predictors=mux_predictors, cache=cache)
    store = MeasurementStore(tmp_path / "meas")
    for _ in range(2):
        compiled.record(store=store, warmup=False)
    records = store.load(compiled.key)
    assert len(records) == 2 * len(compiled.plan.schedule)

    cal = compiled.recalibrate(store)
    assert compiled.calibration is cal
    pre = fidelity_error(records)
    post = cal.fidelity_error(records)
    assert post < pre, (pre, post)

    old_path = cache.path_for(compiled.provenance)
    old_bytes = old_path.read_bytes()
    recompiled, diff = compiled.replan(cal, store=store, cache=cache)

    # new digest, old entry untouched
    assert recompiled.key != compiled.key
    assert recompiled.provenance.calibration == cal.version
    assert compiled.provenance.calibration == ""
    assert old_path.read_bytes() == old_bytes
    new_path = cache.path_for(recompiled.provenance)
    assert new_path.exists() and new_path != old_path

    # the diff prices both schedules on the same calibrated grid: the new
    # schedule is that grid's per-op argmin, so the gain is >= 0
    assert diff.old_key == compiled.key
    assert diff.new_key == recompiled.key
    assert diff.predicted_gain_us >= -1e-9
    assert diff.n_ops == len(compiled.plan.decisions)
    assert "plan diff" in diff.summary()

    # replanning again with the same calibrator is a pure warm hit
    again, diff2 = compiled.replan(cal, store=store, cache=cache)
    assert again.from_cache and again.key == recompiled.key
    assert [c.to_json() for c in diff2.changes] == \
        [c.to_json() for c in diff.changes]

    # the replanned network executes (plan -> executor contract survives)
    rep = recompiled.profile(warmup=False)
    assert len(rep.timings) == len(compiled.plan.schedule)


# ------------------------------------------------------- serving engine

def _tiny_engine(**kw):
    from repro.models import build_model, get_config
    from repro.serving import ServingEngine
    import jax

    cfg = get_config("rwkv6_1b6").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(cfg, model, params, max_len=32, **kw)


def test_serving_mixed_temperature_batch_keeps_greedy_rows_greedy():
    """Satellite: sampling is per-request — a greedy request batched with
    a temperature-sampling one must still decode greedily (the engine
    used to apply batch[0].temperature to every row)."""
    from repro.serving import Request

    rng = np.random.default_rng(1)
    prompt = rng.integers(0, 100, size=6).astype(np.int32)
    greedy = Request(rid=0, prompt=prompt, max_new_tokens=6, temperature=0.0)
    hot = Request(rid=1, prompt=prompt, max_new_tokens=6, temperature=5.0)

    _, e1 = _tiny_engine()
    ref = e1.run([greedy])[0].tokens          # greedy alone
    _, e2 = _tiny_engine()
    out = e2.run([Request(rid=1, prompt=prompt, max_new_tokens=6,
                          temperature=5.0), greedy])
    by_rid = {c.rid: c.tokens for c in out}
    assert by_rid[0] == ref                   # greedy row unaffected
    assert len(by_rid[1]) == 6


def test_serving_all_greedy_batches_stay_deterministic():
    from repro.serving import Request

    rng = np.random.default_rng(2)
    prompt = rng.integers(0, 100, size=5).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
            for i in range(2)]
    _, e1 = _tiny_engine()
    _, e2 = _tiny_engine()
    assert [c.tokens for c in e1.run(reqs)] == \
        [c.tokens for c in e2.run(reqs)]


def test_serving_engine_auto_records_and_exposes_drift(mux_predictors,
                                                       tmp_path):
    from repro.serving.engine import ServingEngine

    plan = _plan(_small_units(), mux_predictors, tmp_path / "plans")

    class _Model:                      # never traced: jit is lazy
        @staticmethod
        def prefill(params, toks, cache):
            raise NotImplementedError

        @staticmethod
        def decode_step(params, tok, cache, pos):
            raise NotImplementedError

    store_dir = tmp_path / "meas"
    eng = ServingEngine(cfg=None, model=_Model, params={}, coexec_plan=plan,
                        measurement_store=store_dir)
    assert eng.drift is None
    eng.execute_plan()
    assert eng.drift is None           # one run: nothing to drift from
    eng.execute_plan()
    drift = eng.drift
    assert drift is not None and np.isfinite(drift)
    store = MeasurementStore(store_dir)
    assert store.count(plan.key) == 2 * len(plan.schedule)
