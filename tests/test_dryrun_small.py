"""CI-scale dry-run: the full lower_one() path (shardings, lowering,
compilation, roofline extraction) on an 8-virtual-device test mesh, in a
subprocess so the 512-device production override never leaks here."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from repro.launch.dryrun import lower_one
    recs = []
    for arch, shape, mp in [
        ("rwkv6_1b6", "decode_32k", False),
        ("rwkv6_1b6", "long_500k", True),
        ("deepseek_v2_lite", "decode_32k", False),
        ("gemma3_12b", "long_500k", False),
        ("llama3_405b", "long_500k", False),     # must report a skip
    ]:
        rec = lower_one(arch, shape, multi_pod=mp, verbose=False,
                        extra_tag="citest", test_mesh=True)
        recs.append({k: rec.get(k) for k in
                     ("arch", "shape", "status", "bottleneck",
                      "hlo_flops")})
    print("DRYRUN_JSON:" + json.dumps(recs))
""")


def test_lower_one_on_test_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", _PROG], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [ln for ln in r.stdout.splitlines()
            if ln.startswith("DRYRUN_JSON:")][0]
    recs = json.loads(line[len("DRYRUN_JSON:"):])
    by_key = {(x["arch"], x["shape"]): x for x in recs}
    assert by_key[("rwkv6_1b6", "decode_32k")]["status"] == "ok"
    assert by_key[("deepseek_v2_lite", "decode_32k")]["status"] == "ok"
    assert by_key[("gemma3_12b", "long_500k")]["status"] == "ok"
    assert by_key[("llama3_405b", "long_500k")]["status"] == "skipped"
    for x in recs:
        if x["status"] == "ok":
            assert x["hlo_flops"] > 0
