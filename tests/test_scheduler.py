"""Tests for the continuous-batching scheduler, plan portfolios, and the
drift-triggered replan loop (PR 8).

Covers: scheduler-vs-solo token equality (continuous batching must not
change greedy completions), mixed-length left-padded batches through the
fixed-batch engine, the decode early-break accounting, windowed drift +
the latest-vs-first alias, portfolio select/save/load/tamper, bucketed
plan provenance byte-compat, Poisson traffic determinism, calibrator
composition, and the two serving acceptance criteria: the portfolio
scheduler beating the fixed-batch reference on p99 latency AND tokens/s,
and a simulated mid-run throttle triggering an in-place replan whose
post-replan fidelity error is lower than pre-replan.
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

import repro
from repro.core.predictor import sample_conv_ops, sample_linear_ops, \
    train_predictor
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.measure.calibrate import (MIN_AFFINE_SPREAD, AffineCorrection,
                                     Calibrator, _fit_group)
from repro.models import build_model, get_config
from repro.runtime.plan import PlanProvenance
from repro.serving import (ContinuousScheduler, FixedBatchReference, Request,
                           SchedulerConfig, ServingEngine, ThrottleSim,
                           poisson_requests)

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)


@pytest.fixture(scope="module")
def gqa_model():
    cfg = get_config("codeqwen15_7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


@pytest.fixture(scope="module")
def plan_cache_dir(tmp_path_factory):
    return tmp_path_factory.mktemp("plans")


def _portfolio(gqa_model, mux_predictors, cache, buckets):
    cfg, _, _ = gqa_model
    return repro.compile_portfolio(
        cfg, repro.Target(device="moto2022"), buckets=buckets,
        cache=cache, predictors=mux_predictors)


def _reqs(prompts, max_new, arrivals=None, temps=None):
    rng = np.random.default_rng(7)
    vocab = 256
    out = []
    for i, t in enumerate(prompts):
        out.append(Request(
            rid=i,
            prompt=rng.integers(1, vocab, t).astype(np.int32),
            max_new_tokens=max_new[i] if isinstance(max_new, (list, tuple))
            else max_new,
            temperature=0.0 if temps is None else temps[i],
            arrival_s=0.0 if arrivals is None else arrivals[i]))
    return out


# ------------------------------------------------------- scheduler basics

def test_scheduler_matches_solo_greedy(gqa_model):
    """Continuous batching with staggered arrivals and mixed prompt
    lengths must produce exactly the completions each request gets when
    served alone — slot join/evict cannot leak across timelines."""
    cfg, model, params = gqa_model
    reqs = _reqs(prompts=[3, 7, 2, 9, 5], max_new=[4, 2, 5, 3, 4],
                 arrivals=[0.0, 0.0, 0.002, 0.004, 0.01])
    sched = ContinuousScheduler(
        cfg, model, params,
        config=SchedulerConfig(max_batch=2, max_len=32))
    rep = sched.run(reqs)
    got = {c.rid: c.tokens for c in rep.completions}
    assert sorted(got) == [0, 1, 2, 3, 4]
    for r in reqs:
        solo = ServingEngine(cfg, model, params, max_batch=1, max_len=32)
        want = solo.run([dataclasses.replace(r, arrival_s=0.0)])[0].tokens
        assert got[r.rid] == want, f"request {r.rid} diverged"
    assert rep.total_tokens == sum(len(t) for t in got.values())
    for s in rep.stats:
        assert s.ttft_s > 0.0
        assert s.latency_s >= s.ttft_s


def test_scheduler_rejects_non_slotted_models():
    cfg = get_config("rwkv6_1b6").reduced()
    model = build_model(cfg)
    with pytest.raises(ValueError, match="per-slot position"):
        ContinuousScheduler(cfg, model, params=None)


def test_scheduler_validates_request_length(gqa_model):
    cfg, model, params = gqa_model
    sched = ContinuousScheduler(
        cfg, model, params, config=SchedulerConfig(max_len=16))
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.run(_reqs(prompts=[14], max_new=8))
    with pytest.raises(ValueError, match="unknown clock"):
        SchedulerConfig(clock="sundial")


# --------------------------------------------- fixed-batch engine repairs

def test_mixed_length_padded_batch_matches_alone(gqa_model):
    """A short prompt left-padded behind a long one must decode exactly
    as it would alone (the pad-aware start mask + relative RoPE phase)."""
    cfg, model, params = gqa_model
    reqs = _reqs(prompts=[3, 10], max_new=5)
    batched = ServingEngine(cfg, model, params, max_batch=2,
                            max_len=32).run(reqs)
    for r, c in zip(reqs, batched):
        solo = ServingEngine(cfg, model, params, max_batch=1,
                             max_len=32).run([r])[0]
        assert c.tokens == solo.tokens, f"request {r.rid} diverged"


def test_engine_decode_step_accounting(gqa_model):
    """The decode loop pays exactly max(max_new) - 1 steps — a batch of
    short requests must not pay for the engine-level budget, and an
    all-single-token batch pays zero decode steps."""
    cfg, model, params = gqa_model
    engine = ServingEngine(cfg, model, params, max_batch=4, max_len=32)
    engine.run(_reqs(prompts=[4, 3, 2, 5], max_new=[1, 4, 1, 1]))
    assert engine.last_batch_decode_steps == 3
    engine.run(_reqs(prompts=[4, 3], max_new=[1, 1]))
    assert engine.last_batch_decode_steps == 0


def test_engine_windowed_drift_and_alias(gqa_model):
    cfg, model, params = gqa_model
    engine = ServingEngine(cfg, model, params)
    assert engine.drift is None
    assert engine.drift_latest_vs_first is None
    # a single noisy FIRST run must not poison the windowed trigger...
    engine._fidelity_log = [5.0] + [0.1] * 8
    assert abs(engine.drift) < 0.05
    # ...but the legacy alias keeps the raw two-point comparison
    assert engine.drift_latest_vs_first == pytest.approx(-4.9)
    # genuine sustained drift is visible on the window
    engine._fidelity_log = [0.1] * 6 + [0.8] * 4
    assert engine.drift == pytest.approx(0.7)


# ------------------------------------------------------------ traffic gen

def test_poisson_requests_deterministic():
    a = poisson_requests(40, rate=100.0, vocab_size=64, seed=3)
    b = poisson_requests(40, rate=100.0, vocab_size=64, seed=3)
    assert len(a) == 40
    for x, y in zip(a, b):
        assert x.arrival_s == y.arrival_s
        np.testing.assert_array_equal(x.prompt, y.prompt)
        assert x.max_new_tokens == y.max_new_tokens
    arrivals = [r.arrival_s for r in a]
    assert arrivals == sorted(arrivals)
    mean_gap = arrivals[-1] / len(arrivals)
    assert 0.25 / 100.0 < mean_gap < 4.0 / 100.0
    c = poisson_requests(40, rate=100.0, vocab_size=64, seed=4)
    assert [r.arrival_s for r in c] != arrivals


# -------------------------------------------------------------- portfolio

def test_portfolio_select_save_load_tamper(gqa_model, mux_predictors,
                                           plan_cache_dir, tmp_path):
    pf = _portfolio(gqa_model, mux_predictors, plan_cache_dir,
                    buckets=((1, 32), (2, 32)))
    b, compiled = pf.select(1, 16)
    assert (b.batch, b.seq) == (1, 32)         # smallest covering bucket
    assert compiled.plan.provenance.bucket == "b1s32"
    b2, _ = pf.select(2, 32)
    assert (b2.batch, b2.seq) == (2, 32)
    b3, _ = pf.select(4, 64)                    # nothing covers: largest
    assert (b3.batch, b3.seq) == (2, 32)
    keys = {c.key for c in pf.entries.values()}
    assert len(keys) == 2                       # bucket tag splits digests

    path = pf.save(tmp_path / "portfolio.json")
    loaded = repro.PlanPortfolio.load(path)
    assert [bk.tag for bk in loaded.buckets] == [bk.tag for bk in pf.buckets]
    assert {c.key for c in loaded.entries.values()} == keys
    assert pf.can_replan() and not loaded.can_replan()

    doc = json.loads(path.read_text())
    doc["model"] = "tampered"
    path.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="checksum mismatch"):
        repro.PlanPortfolio.load(path)


def test_bucket_provenance_is_byte_compatible():
    """An unbucketed provenance must keep its pre-PR-8 digest and JSON
    shape — existing on-disk plan caches stay warm."""
    base = PlanProvenance(device="moto2022", threads=3, mechanism="spin",
                          step=8, seed=0, network_fingerprint="f" * 8,
                          predictor_checksum="p" * 8)
    assert "bucket" not in base.to_json()
    assert dataclasses.replace(base, bucket="").key == base.key
    tagged = dataclasses.replace(base, bucket="b2s32")
    assert tagged.key != base.key
    assert tagged.to_json()["bucket"] == "b2s32"
    assert PlanProvenance.from_json(tagged.to_json()) == tagged


# ------------------------------------------------------------- calibrator

def test_calibrator_compose_matches_sequential_application():
    inner = Calibrator({("linear", "*"): AffineCorrection(1.1, 0.2, 4)})
    outer = Calibrator({("linear", "*"): AffineCorrection(0.9, -0.1, 3),
                        ("conv", "*"): AffineCorrection(1.0, 0.5, 2)})
    composed = outer.compose(inner)
    for pred in (3.0, 120.0, 9e4):
        twice = outer.correct_us("linear", "*",
                                 inner.correct_us("linear", "*", pred))
        once = composed.correct_us("linear", "*", pred)
        np.testing.assert_allclose(once, twice, rtol=1e-12)
    # keys present on only one side compose against the identity
    np.testing.assert_allclose(
        composed.correct_us("conv", "*", 10.0),
        outer.correct_us("conv", "*", 10.0), rtol=1e-12)
    assert outer.compose(None) is outer


def test_affine_fit_gated_on_prediction_spread():
    """Clustered log-predictions make the affine slope unidentifiable —
    the fit must fall back to a pure shift instead of extrapolating."""
    logp = np.log(np.array([100.0, 101.0, 102.0, 103.0]))
    logw = np.log(np.array([180.0, 250.0, 140.0, 210.0]))
    assert float(np.ptp(logp)) < MIN_AFFINE_SPREAD
    corr = _fit_group(logp, logw)
    assert corr.a == 1.0
    spread = np.log(np.array([10.0, 100.0, 1000.0, 10000.0]))
    wall = 2.0 * spread + 0.3
    assert _fit_group(spread, wall).a == pytest.approx(2.0, abs=1e-6)


# --------------------------------------------------- serving acceptance

def test_scheduler_beats_fixed_batch_reference(gqa_model, mux_predictors,
                                               plan_cache_dir):
    """Acceptance: at the same arrival rate the portfolio scheduler wins
    BOTH p99 latency and tokens/s against the fixed-batch reference
    served by the single largest plan."""
    cfg, model, params = gqa_model
    pf = _portfolio(gqa_model, mux_predictors, plan_cache_dir,
                    buckets=((1, 32), (2, 32), (4, 32)))
    _, largest = pf.select(4, 32)
    cost = largest.plan.end_to_end_us * 1e-6
    # rate chosen from the plan's own step cost: past the fixed-batch
    # engine's capacity (padded prefill + head-of-line blocking) but
    # under the scheduler's
    rate = 0.33 / cost
    reqs = poisson_requests(200, rate=rate, vocab_size=cfg.vocab_size,
                            prompt_lens=(2, 4, 12), max_new=(2, 4),
                            temperatures=(0.0,), seed=11)
    sched = ContinuousScheduler(
        cfg, model, params, portfolio=pf,
        config=SchedulerConfig(max_batch=4, max_len=32,
                               fidelity_every=10**9))
    srep = sched.run(reqs)
    frep = FixedBatchReference(largest, max_batch=4).run(reqs)
    assert srep.bucket_switches > 0
    assert len(srep.bucket_steps) >= 2
    assert srep.latency_p(99) < frep.latency_p(99)
    assert srep.tokens_per_s > frep.tokens_per_s


def test_throttle_triggers_validated_replan(gqa_model, mux_predictors,
                                            plan_cache_dir, tmp_path):
    """Acceptance: a mid-run simulated throttle drives the bucket's
    windowed drift over threshold, the scheduler replans in place, and
    the committed plan's fidelity error is lower than the trailing
    pre-replan window."""
    cfg, model, params = gqa_model
    pf = _portfolio(gqa_model, mux_predictors, plan_cache_dir,
                    buckets=((2, 32),))
    bucket = pf.buckets[0]
    old_key = pf.entries[bucket].key
    cost = pf.entries[bucket].plan.end_to_end_us * 1e-6
    rate = 0.1 / cost
    reqs = poisson_requests(48, rate=rate, vocab_size=cfg.vocab_size,
                            prompt_lens=(2, 4, 12), max_new=(2, 4),
                            temperatures=(0.0,), seed=23)
    sched = ContinuousScheduler(
        cfg, model, params, portfolio=pf,
        measurement_store=tmp_path / "measurements",
        plan_cache=plan_cache_dir,
        config=SchedulerConfig(max_batch=2, max_len=32, fidelity_every=4,
                               fidelity_window=4, drift_cooldown=2),
        throttle=ThrottleSim(at_s=100 * cost, scale=2.5))
    rep = sched.run(reqs)
    assert rep.replan_events, "throttle never triggered a replan"
    ev = rep.replan_events[0]
    assert ev.post_fidelity is not None
    assert ev.post_fidelity < ev.pre_fidelity
    assert ev.new_key != ev.old_key
    # the portfolio now serves the repaired, calibrated plan
    new = pf.entries[bucket]
    assert new.key != old_key
    assert new.plan.provenance.calibration != ""
    assert rep.to_json()["replan_events"][0]["bucket"] == bucket.tag
