"""Unit + property tests for the mobile-platform performance models."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # graceful fallback, see hypothesis_fallback
    from hypothesis_fallback import given, settings, st

from repro.core.simulator import (DEVICES, cpu_latency_us, dispatch_for,
                                  gpu_latency_us, select_conv_kernel,
                                  true_latency_us, measure_latency_us)
from repro.core.simulator.gpu_model import (KERNEL_CONV_CONSTANT,
                                            KERNEL_CONV_GENERIC,
                                            KERNEL_CONV_WINOGRAD)
from repro.core.types import ConvOp, LinearOp

DEV_NAMES = sorted(DEVICES)


# ---------------------------------------------------------------- invariants
dims = st.integers(min_value=1, max_value=4096)
small_dims = st.integers(min_value=1, max_value=512)


@settings(max_examples=60, deadline=None)
@given(L=small_dims, c_in=dims, c_out=dims,
       dev=st.sampled_from(DEV_NAMES),
       threads=st.integers(min_value=1, max_value=3))
def test_latency_positive_and_finite(L, c_in, c_out, dev, threads):
    op = LinearOp(L, c_in, c_out)
    g = gpu_latency_us(op, DEVICES[dev])
    c = cpu_latency_us(op, DEVICES[dev], threads)
    assert np.isfinite(g) and g > 0
    assert np.isfinite(c) and c > 0


@settings(max_examples=40, deadline=None)
@given(L=small_dims, c_in=dims, c_out=st.integers(64, 2048),
       dev=st.sampled_from(DEV_NAMES))
def test_cpu_latency_monotone_in_flops_scale(L, c_in, c_out, dev):
    """CPU model: 4x the output channels should not be cheaper."""
    t1 = cpu_latency_us(LinearOp(L, c_in, c_out), DEVICES[dev], 2)
    t4 = cpu_latency_us(LinearOp(L, c_in, 4 * c_out), DEVICES[dev], 2)
    assert t4 >= t1


@settings(max_examples=40, deadline=None)
@given(L=small_dims, c_in=dims, c_out=dims, dev=st.sampled_from(DEV_NAMES))
def test_more_threads_never_slower_much(L, c_in, c_out, dev):
    """3 threads may lose to 1 only by the small scheduling overhead."""
    op = LinearOp(L, c_in, c_out)
    t1 = cpu_latency_us(op, DEVICES[dev], 1)
    t3 = cpu_latency_us(op, DEVICES[dev], 3)
    assert t3 <= t1 + 50.0


def test_measurement_noise_is_reproducible():
    op = LinearOp(50, 768, 3072)
    a = measure_latency_us(op, "pixel5", "gpu", seed=3)
    b = measure_latency_us(op, "pixel5", "gpu", seed=3)
    c = measure_latency_us(op, "pixel5", "gpu", seed=4)
    assert a == b
    assert a != c
    assert abs(a / true_latency_us(op, "pixel5", "gpu") - 1) < 0.15


# ------------------------------------------------------- paper's phenomena
def test_fig2_cpu_beats_gpu_for_small_cout_oneplus11():
    """Fig. 2: CPU(3) wins for small C_out, GPU for large (crossover)."""
    small = LinearOp(50, 3072, 128)
    large = LinearOp(50, 3072, 1536)
    assert (true_latency_us(small, "oneplus11", "cpu3")
            < true_latency_us(small, "oneplus11", "gpu"))
    assert (true_latency_us(large, "oneplus11", "gpu")
            < true_latency_us(large, "oneplus11", "cpu3"))


def test_fig5_gpu_latency_spikes_exist():
    """Fig. 5: some C_out in [2048, 2560] is >=1.3x slower than a larger
    neighbour (heuristic workgroup miss)."""
    lat = {c: true_latency_us(LinearOp(50, 768, c), "oneplus11", "gpu")
           for c in range(2048, 2561, 4)}
    spikes = [(c1, c2) for c1 in lat for c2 in lat
              if c2 > c1 and lat[c1] > 1.3 * lat[c2]]
    assert spikes, "no workgroup-heuristic latency spikes"


def test_fig6b_winograd_kernel_switch():
    """Fig. 6b: 3x3 conv on (64,64,128) switches to winograd at C_out=128."""
    dev = DEVICES["oneplus11"]
    assert select_conv_kernel(ConvOp(64, 64, 128, 120, 3, 1), dev) \
        != KERNEL_CONV_WINOGRAD
    assert select_conv_kernel(ConvOp(64, 64, 128, 128, 3, 1), dev) \
        == KERNEL_CONV_WINOGRAD


def test_kernel_selection_constant_memory():
    dev = DEVICES["oneplus11"]
    tiny = ConvOp(64, 64, 16, 8, 1, 1)       # 512 B of weights
    assert select_conv_kernel(tiny, dev) == KERNEL_CONV_CONSTANT
    big = ConvOp(64, 64, 512, 512, 5, 1)
    assert select_conv_kernel(big, dev) == KERNEL_CONV_GENERIC


def test_workgroup_count_correlates_with_latency():
    """Fig. 6a: workgroup count and latency are positively correlated."""
    dev = DEVICES["oneplus11"]
    wgs, lats = [], []
    for c in range(256, 2049, 8):
        op = LinearOp(50, 768, c)
        wgs.append(dispatch_for(op, dev).wg_count)
        lats.append(gpu_latency_us(op, dev))
    r = np.corrcoef(wgs, lats)[0, 1]
    assert r > 0.55, f"corr(wg_count, latency) = {r:.2f}"


def test_sync_overhead_matches_paper_moto2022():
    from repro.core.sync import SyncMechanism, sync_overhead_us
    assert sync_overhead_us("moto2022", SyncMechanism.EVENT) == 162.0
    assert sync_overhead_us("moto2022", SyncMechanism.SVM_POLL) == 7.0
