"""Tests for `repro.analysis` (PR 10): the static plan/IR verifier, the
repo-contract linter, the strict-load wiring, cache rejection logging,
and the scheduler's replan verification gate.

The core of the file is the mutation harness: known-good plan documents
(resnet18 unit chain, tiny_decoder with a head split, a tuned plan, a
portfolio bucket) each get a catalog of single-field mutations applied,
and the verifier must flag every one with the *correct* rule id —
acceptance requires >= 95% caught; we assert 100%.
"""
import copy
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import (RULES, VerificationError, errors, plan_stats,
                            rejections, verify_artifact, verify_bench_report,
                            verify_path, verify_plan, verify_portfolio,
                            verify_tune_entry)
from repro.analysis.lint import (lint_import_light, lint_repo,
                                 lint_silent_clamp, package_root)
from repro.core.networks import NETWORKS
from repro.core.partitioner import PartitionDecision
from repro.graph import from_model
from repro.graph.ir import from_units
from repro.kernels import registry
from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                build_graph_schedule, segments_json)

ROOT = Path(__file__).resolve().parents[1]


# ------------------------------------------------------ known-good plans

def _forced_plan(g, decisions, opaque=None):
    prov = PlanProvenance(
        device="moto2022", threads=3, mechanism="svm_poll", step=8, seed=1,
        network_fingerprint=g.fingerprint(), predictor_checksum="")
    return CoexecPlan(
        provenance=prov,
        schedule=build_graph_schedule(g, decisions, opaque or {}),
        graph_json=None if g.is_unit_chain() else g.to_json(),
        segments=segments_json(g, decisions))


def _decisions(g, *, typed=False, opaque_attn=False):
    decisions, opaque = {}, {}
    for n in g:
        if n.kind in ("linear", "conv"):
            c = n.op.C_out
            decisions[n.id] = PartitionDecision(
                op=n.op, c_cpu=c // 4, c_gpu=c - c // 4,
                pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0)
        elif n.kind == "attention":
            if opaque_attn or not typed:
                opaque[n.id] = 25.0
            else:
                decisions[n.id] = PartitionDecision(
                    op=n.op.with_mode("streaming"), c_cpu=n.op.H // 2,
                    c_gpu=n.op.H // 2, pred_cpu_us=1.0, pred_gpu_us=1.0,
                    pred_total_us=2.0, axis="head")
        elif n.kind == "ssm":
            if typed:
                decisions[n.id] = PartitionDecision(
                    op=n.op.with_mode("recurrent"), c_cpu=n.op.H // 2,
                    c_gpu=n.op.H // 2, pred_cpu_us=1.0, pred_gpu_us=1.0,
                    pred_total_us=2.0, axis="ssm-state")
            else:
                opaque[n.id] = 25.0
    return decisions, opaque


@pytest.fixture(scope="module")
def resnet_doc():
    g = from_units(NETWORKS["resnet18"]())
    return _forced_plan(g, *_decisions(g)).to_json()


@pytest.fixture(scope="module")
def decoder_doc():
    g = from_model("tiny_decoder", cache_len=512)
    return _forced_plan(g, *_decisions(g, typed=True)).to_json()


@pytest.fixture(scope="module")
def tuned_doc():
    g = from_units(NETWORKS["vgg16"]())
    decisions, opaque = _decisions(g)
    # attach a legal non-default tile to one linear decision, the way
    # annotate_plan_tiles does (winner != default blocking)
    for n in g:
        if n.kind != "linear":
            continue
        spec = registry.tile_spec("linear")
        default = spec.default_config(n.op)
        alt = next((c for c in spec.configs(n.op) if c != default), None)
        if alt is None:
            continue
        d = decisions[n.id]
        decisions[n.id] = PartitionDecision(
            op=d.op, c_cpu=d.c_cpu, c_gpu=d.c_gpu,
            pred_cpu_us=d.pred_cpu_us, pred_gpu_us=d.pred_gpu_us,
            pred_total_us=d.pred_total_us, tile=alt)
        break
    else:
        pytest.skip("no linear op with a second legal tile config")
    plan = _forced_plan(g, decisions, opaque)
    prov = plan.provenance
    import dataclasses
    plan = CoexecPlan(
        provenance=dataclasses.replace(prov, tune="tune-v1.k1"),
        schedule=plan.schedule, graph_json=plan.graph_json,
        segments=plan.segments)
    return plan.to_json()


@pytest.fixture(scope="module")
def portfolio_doc(resnet_doc):
    import dataclasses

    from repro.api import Bucket, CompiledNetwork, PlanPortfolio, Target
    entries = {}
    for batch, seq in ((1, 64), (4, 256)):
        b = Bucket(batch, seq)
        plan = CoexecPlan.from_json(copy.deepcopy(resnet_doc))
        plan = CoexecPlan(
            provenance=dataclasses.replace(plan.provenance, bucket=b.tag),
            schedule=plan.schedule, graph_json=plan.graph_json,
            segments=plan.segments)
        entries[b] = CompiledNetwork(
            plan=plan, target=Target(device="moto2022", threads=3))
    return PlanPortfolio("resnet18", Target(device="moto2022", threads=3),
                         entries).to_json()


# --------------------------------------------------- clean-artifact checks

def test_fresh_plans_verify_clean(resnet_doc, decoder_doc, tuned_doc):
    for doc in (resnet_doc, decoder_doc, tuned_doc):
        key = PlanProvenance.from_json(doc["provenance"]).key
        diags = verify_plan(copy.deepcopy(doc), expect_key=key)
        assert not errors(diags), [str(d) for d in errors(diags)]
        # the info-severity resource accounting rides along
        assert any(d.rule == "resource.accounting" for d in diags)


def test_fresh_portfolio_verifies_clean(portfolio_doc):
    diags = verify_portfolio(copy.deepcopy(portfolio_doc))
    assert not errors(diags), [str(d) for d in errors(diags)]


def test_committed_artifacts_verify_clean():
    """Every artifact committed to the repo (the bench reports; plan/tune
    caches are gitignored) must pass static verification — the CI
    `repro verify --all-artifacts` gate."""
    out = subprocess.run(["git", "ls-files", "reports"], cwd=ROOT,
                         capture_output=True, text=True, timeout=60)
    files = [ROOT / f for f in out.stdout.split()
             if f.endswith(".json")] if out.returncode == 0 else []
    if not files:
        files = sorted((ROOT / "reports" / "bench").glob("*.json"))
    assert files, "no committed artifacts found"
    for path in files:
        kind, diags = verify_path(path)
        assert kind != "unknown", path
        assert not errors(diags), (path, [str(d) for d in errors(diags)])


def test_local_plan_cache_verifies_clean():
    """Plans the test/bench runs themselves cached on this machine must
    verify (filename == recomputed digest included); stale entries from
    older schema canons are expected to be *flagged*, not crash."""
    for path in sorted((ROOT / "reports" / "plans").glob("*.json")):
        kind, diags = verify_path(path)
        assert kind == "plan", path
        for d in errors(diags):
            # only provenance/fingerprint staleness is tolerated (an old
            # canon's digest); structural violations are never expected
            assert d.rule in ("graph.fingerprint", "provenance.digest"), \
                (path, str(d))


# ------------------------------------------------------- mutation harness

def _mut_boundary(doc):
    for e in doc["schedule"]:
        d = e.get("decision")
        if d and d["c_cpu"] > 0 and d["c_gpu"] > 0 and "axis" not in d:
            d["c_cpu"] += 8
            return True
    return False


def _mut_default_axis(doc):
    for e in doc["schedule"]:
        if "decision" in e:
            e["decision"]["axis"] = "channel"
            return True
    return False


def _mut_default_mode(doc):
    for e in doc["schedule"]:
        d = e.get("decision", e)
        op = d.get("op")
        if op and op.get("kind") in ("attention", "ssm") and \
                "mode" not in op:
            op["mode"] = registry.default_mode(op["kind"])
            return True
    return False


def _mut_empty_bucket(doc):
    doc["provenance"]["bucket"] = ""
    return True


def _mut_typed_granularity(doc):
    for e in doc["schedule"]:
        d = e.get("decision")
        if d and d.get("axis") == "head":
            d["c_cpu"] += 1
            d["c_gpu"] -= 1                 # sum preserved, grouping broken
            return True
    return False


def _mut_typed_sum(doc):
    for e in doc["schedule"]:
        d = e.get("decision")
        if d and d.get("axis") in ("head", "ssm-state"):
            d["c_cpu"] += 1                 # sum != axis size
            return True
    return False


def _mut_misaligned_tile(doc):
    for e in doc["schedule"]:
        d = e.get("decision")
        if d and "tile" in d:
            param = next(iter(d["tile"]))
            d["tile"][param] = d["tile"][param] + 1   # breaks alignment
            return True
    return False


def _mut_default_tile(doc):
    for e in doc["schedule"]:
        d = e.get("decision")
        if d and "tile" not in d and d["op"]["kind"] == "linear":
            op = registry.op_from_json(d["op"])
            d["tile"] = registry.tile_to_json(registry.default_tile(op))
            return True
    return False


def _mut_schema_version(doc):
    doc["schema_version"] = 99
    doc["provenance"]["schema_version"] = 99
    return True


def _mut_fingerprint(doc):
    doc["provenance"]["network_fingerprint"] = "0" * 24
    return True


def _mut_provenance_field(doc):
    doc["provenance"]["device"] = "some-other-device"
    return True


def _mut_pool_bytes(doc):
    for e in doc["schedule"]:
        if e["unit"] == "pool":
            e["bytes"] = 0
            return True
    return False


def _mut_negative_share(doc):
    for e in doc["schedule"]:
        if "decision" in e:
            e["decision"]["c_cpu"] = -8
            return True
    return False


def _mut_chain_ids(doc):
    if doc.get("graph") is not None:
        return False
    for i, e in enumerate(doc["schedule"]):
        e["id"] = f"n{i}"
        return True
    return False


def _mut_segment_drop(doc):
    segs = doc.get("segments")
    if not segs:
        return False
    for s in segs:
        if len(s["nodes"]) >= 2:            # an emptied segment would be
            s["nodes"] = s["nodes"][:-1]    # malformed, not uncovered
            return True
    return False


def _mut_segment_merge(doc):
    segs = doc.get("segments")
    if not segs or len(segs) < 2:
        return False
    a, b = segs[0], segs[1]
    merged = {"kind": "fused", "nodes": a["nodes"] + b["nodes"]}
    doc["segments"] = [merged] + segs[2:]
    return True


def _mut_segment_kind(doc):
    segs = doc.get("segments")
    if not segs:
        return False
    for s in segs:
        if s["kind"] == "fused":
            s["kind"] = "exclusive"
            return True
    return False


def _mut_unit_kind(doc):
    for e in doc["schedule"]:
        if e.get("decision") and e["unit"] == "linear":
            e["unit"] = "conv"              # decision op stays linear
            return True
    return False


#: (name, mutator, acceptable rule ids) — each mutator returns False when
#: the target plan has no site for it (skipped for that plan)
MUTATIONS = [
    ("boundary-flip", _mut_boundary, {"axis.shares"}),
    ("default-axis-key", _mut_default_axis, {"schema.default-key"}),
    ("default-mode-key", _mut_default_mode, {"schema.default-key"}),
    ("empty-bucket-key", _mut_empty_bucket, {"schema.default-key"}),
    ("head-split-granularity", _mut_typed_granularity, {"axis.legality"}),
    ("typed-share-sum", _mut_typed_sum, {"axis.shares", "axis.legality"}),
    ("tile-misalign", _mut_misaligned_tile, {"tile.legality"}),
    ("tile-at-default", _mut_default_tile, {"schema.default-key"}),
    ("schema-version", _mut_schema_version, {"schema.version"}),
    ("fingerprint-corrupt", _mut_fingerprint, {"graph.fingerprint"}),
    ("provenance-digest", _mut_provenance_field, {"provenance.digest"}),
    ("pool-bytes-zero", _mut_pool_bytes, {"schema.malformed"}),
    ("negative-share", _mut_negative_share, {"schema.malformed"}),
    ("chain-id-keys", _mut_chain_ids, {"schema.default-key"}),
    ("segment-drop-node", _mut_segment_drop, {"segment.cover"}),
    ("segment-merge", _mut_segment_merge,
     {"segment.cover", "segment.mismatch", "segment.gather",
      "segment.convexity", "segment.elision"}),
    ("segment-kind-flip", _mut_segment_kind,
     {"segment.mismatch", "segment.gather"}),
    ("unit-kind-flip", _mut_unit_kind,
     {"schema.malformed", "graph.schedule"}),
]


@pytest.mark.parametrize("plan_name", ["resnet", "decoder", "tuned"])
def test_mutation_harness(plan_name, resnet_doc, decoder_doc, tuned_doc):
    base = {"resnet": resnet_doc, "decoder": decoder_doc,
            "tuned": tuned_doc}[plan_name]
    key = PlanProvenance.from_json(base["provenance"]).key
    applied = caught = 0
    misses = []
    for name, mutate, expected_rules in MUTATIONS:
        doc = copy.deepcopy(base)
        if not mutate(doc):
            continue                        # no site in this plan
        applied += 1
        # fingerprint mutation changes the digest too: only pass the
        # expect_key when the provenance digest is the rule under test
        expect = key if name == "provenance-digest" else None
        got = {d.rule for d in errors(verify_plan(doc, expect_key=expect))}
        if got & expected_rules:
            caught += 1
        else:
            misses.append((name, sorted(got)))
    assert applied >= 10, "mutation catalog barely applied"
    assert caught == applied, f"uncaught mutations: {misses}"


def test_every_emitted_rule_is_documented(resnet_doc):
    """Rule ids are API: everything the verifier can emit is in RULES."""
    for name, mutate, expected in MUTATIONS:
        assert expected <= set(RULES), (name, expected - set(RULES))
    doc = copy.deepcopy(resnet_doc)
    for d in verify_plan(doc):
        assert d.rule in RULES


# ------------------------------------------------------ resource accounting

def test_plan_stats_accounting(resnet_doc, decoder_doc):
    st = plan_stats(copy.deepcopy(resnet_doc))
    assert st.nodes == len(resnet_doc["schedule"])
    assert 0 < st.coexec_nodes <= st.nodes
    assert st.segments == len(resnet_doc["segments"])
    assert st.peak_live_bytes > 0
    assert st.peak_fast_bytes + st.peak_slow_bytes >= st.peak_live_bytes // 2
    assert st.sync_points > 0 and st.boundary_bytes > 0
    st2 = plan_stats(copy.deepcopy(decoder_doc))
    assert st2.fused_segments >= 1
    assert "sync points" in st2.summary()


# ------------------------------------------------------ strict-load wiring

def test_from_json_strict_by_default_with_optout(resnet_doc):
    doc = copy.deepcopy(resnet_doc)
    _mut_boundary(doc)
    with pytest.raises(VerificationError) as ei:
        CoexecPlan.from_json(doc)
    assert any(d.rule == "axis.shares" for d in ei.value.diagnostics)
    quarantined = CoexecPlan.from_json(doc, verify=False)   # opt-out loads
    assert quarantined.provenance.device == "moto2022"


def test_artifact_and_portfolio_rules(resnet_doc, portfolio_doc):
    from repro.api import CompiledNetwork, Target
    plan = CoexecPlan.from_json(copy.deepcopy(resnet_doc))
    art = CompiledNetwork(plan=plan,
                          target=Target(device="moto2022")).to_json()
    assert not errors(verify_artifact(copy.deepcopy(art)))
    bad = copy.deepcopy(art)
    bad["mode"] = "tampered"
    assert {d.rule for d in errors(verify_artifact(bad))} == \
        {"artifact.checksum"}

    pf = copy.deepcopy(portfolio_doc)
    pf["entries"][0]["batch"] = 2           # tag no longer matches bucket
    rules = {d.rule for d in errors(verify_portfolio(pf))}
    assert "portfolio.bucket" in rules and "artifact.checksum" in rules


def test_tune_entry_and_bench_rules(tmp_path):
    from repro.runtime.autotune import TuneCache, TuneKey
    op = registry.op_from_json(
        {"kind": "linear", "L": 1, "C_in": 64, "C_out": 64})
    key = TuneKey.for_op(op, "cpu", "cpu")
    cache = TuneCache(tmp_path)
    spec = registry.tile_spec("linear")
    path = cache.put(key, spec.default_config(op), [("mn8/...", 1.0)])
    doc = json.loads(path.read_text())
    assert not errors(verify_tune_entry(doc, expect_key=path.stem))
    bad = copy.deepcopy(doc)
    bad["tile"]["bm"] = 7                    # misaligned
    assert {d.rule for d in errors(verify_tune_entry(bad))} == \
        {"tile.legality"}
    stale = copy.deepcopy(doc)
    stale["key"]["device"] = "elsewhere"
    assert {d.rule for d in
            errors(verify_tune_entry(stale, expect_key=path.stem))} == \
        {"provenance.digest"}

    bench = {"suite": "t", "metrics": [{"name": "a", "us_per_call": 1.0}]}
    assert not errors(verify_bench_report(bench))
    bench["metrics"].append({"name": "b", "us_per_call": float("nan")})
    assert {d.rule for d in errors(verify_bench_report(bench))} == \
        {"bench.metric"}


def test_plan_cache_rejection_logged(tmp_path, resnet_doc):
    """Corrupt/mismatched cache entries must miss *loudly*: once per
    digest, naming the verifier rule that failed."""
    from repro.runtime.cache import PlanCache
    rejections.clear()
    cache = PlanCache(tmp_path)
    prov = PlanProvenance.from_json(copy.deepcopy(
        resnet_doc["provenance"]))
    path = cache.path_for(prov)
    path.parent.mkdir(parents=True, exist_ok=True)

    doc = copy.deepcopy(resnet_doc)
    _mut_boundary(doc)
    path.write_text(json.dumps(doc))
    assert cache.get(prov) is None and cache.misses == 1
    assert rejections.counts() == {"axis.shares": 1}

    cache.get(prov)                          # same digest: logged once
    assert rejections.total() == 1

    path.write_text("{not json")
    # a new digest would be a new entry; same digest stays deduplicated,
    # so clear to observe the malformed rule
    rejections.clear()
    assert cache.get(prov) is None
    assert rejections.counts() == {"schema.malformed": 1}
    assert "cache rejections: 1" in rejections.summary()
    rejections.clear()


def test_explain_carries_verification_line(resnet_doc):
    from repro.api import CompiledNetwork, Target
    plan = CoexecPlan.from_json(copy.deepcopy(resnet_doc))
    text = CompiledNetwork(plan=plan,
                           target=Target(device="moto2022")).explain()
    assert "verify: clean" in text


# ------------------------------------------------------------------ linter

def test_lint_src_is_clean():
    assert lint_repo() == []


def test_lint_flags_synthetic_violations(tmp_path):
    pkg = tmp_path / "fakepkg"
    (pkg / "graph").mkdir(parents=True)
    (pkg / "kernels" / "thing").mkdir(parents=True)
    (pkg / "graph" / "ir.py").write_text(
        "from typing import TYPE_CHECKING\n"
        "if TYPE_CHECKING:\n    import jax\n"       # guarded: legal
        "import jax.numpy as jnp\n")                # top-level: flagged
    (pkg / "kernels" / "thing" / "ops.py").write_text(
        "def matmul(x, w, bm=None):\n"
        "    bm = min(bm, 128)\n"                   # silent clamp: flagged
        "    return x\n"
        "def legal(x, op, tile=None):\n"
        "    bs = min(512, op.S) if tile is None else tile.get('bs')\n"
        "    return bs\n")
    imp = lint_import_light(pkg)
    assert [d.rule for d in imp] == ["lint.import-light"]
    assert "ir.py:4" in imp[0].node
    clamp = lint_silent_clamp(pkg)
    assert [d.rule for d in clamp] == ["lint.no-silent-clamp"]
    assert "ops.py:2" in clamp[0].node


def test_lint_registry_completeness_is_green():
    from repro.analysis.lint import lint_registry
    assert lint_registry(package_root()) == []


# --------------------------------------------------------------- CLI + CI

def _jax_free_env():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    return env


def test_cli_verify_and_lint_never_import_jax(tmp_path, resnet_doc):
    """Same discipline as the facade's import-light test: the whole
    verify/lint CLI paths — including scanning real artifacts — must not
    pull in jax."""
    plan_file = tmp_path / "plan.json"
    plan_file.write_text(json.dumps(resnet_doc))
    code = (
        "import sys\n"
        "from repro.cli import main\n"
        f"assert main(['verify', {str(plan_file)!r}]) == 0\n"
        "assert main(['lint']) == 0\n"
        "assert 'jax' not in sys.modules, 'jax was imported'\n"
        "print('verify+lint jax-free')\n")
    out = subprocess.run([sys.executable, "-c", code], env=_jax_free_env(),
                         cwd=ROOT, capture_output=True, text=True,
                         timeout=180)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "verify+lint jax-free" in out.stdout


def test_cli_verify_exit_codes(tmp_path, resnet_doc, capsys):
    from repro.cli import main
    good = tmp_path / "good.json"
    good.write_text(json.dumps(resnet_doc))
    assert main(["verify", str(good)]) == 0
    out = capsys.readouterr().out
    assert "ok" in out and "plan" in out

    bad_doc = copy.deepcopy(resnet_doc)
    _mut_boundary(bad_doc)
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(bad_doc))
    assert main(["verify", str(bad)]) == 1
    out = capsys.readouterr().out
    assert "FAIL" in out and "axis.shares" in out

    assert main(["verify"]) == 2             # nothing to verify
    assert main(["verify", str(good), "-v"]) == 0
    assert "resource.accounting" in capsys.readouterr().out


def test_cli_lint_exit_zero(capsys):
    from repro.cli import main
    assert main(["lint"]) == 0
    assert "0 finding(s)" in capsys.readouterr().out


# ------------------------------------------------- scheduler replan gate

def test_scheduler_replan_rejects_corrupted_candidate(monkeypatch):
    """A corrupted replan candidate must never reach the slot pool: the
    gate refuses the swap, records no ReplanEvent, and the old plan keeps
    serving (the drift monitor still resets)."""
    jax = pytest.importorskip("jax")
    import dataclasses

    import repro
    from repro.core.predictor import (sample_conv_ops, sample_linear_ops,
                                      train_predictor)
    from repro.core.predictor.gbdt import GBDTParams
    from repro.core.predictor.train import MuxPredictor
    from repro.models import build_model, get_config
    from repro.serving import (ContinuousScheduler, SchedulerConfig,
                               ThrottleSim, poisson_requests)
    fast = GBDTParams(n_estimators=30, max_depth=5, learning_rate=0.2)
    lt, ct = sample_linear_ops(200, seed=1), sample_conv_ops(200, seed=1)
    gp = MuxPredictor(
        train_predictor(lt, "moto2022", "gpu", whitebox=True, params=fast),
        train_predictor(ct, "moto2022", "gpu", whitebox=True, params=fast))
    cp = MuxPredictor(
        train_predictor(lt, "moto2022", "cpu3", whitebox=False, params=fast),
        train_predictor(ct, "moto2022", "cpu3", whitebox=False, params=fast))
    cfg = get_config("codeqwen15_7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    import tempfile
    with tempfile.TemporaryDirectory() as cache_dir:
        pf = repro.compile_portfolio(
            cfg, repro.Target(device="moto2022"), buckets=((2, 32),),
            cache=cache_dir, predictors=(cp, gp))
        bucket = pf.buckets[0]
        old_key = pf.entries[bucket].key
        cost = pf.entries[bucket].plan.end_to_end_us * 1e-6

        from repro.api import CompiledNetwork
        real_replan = CompiledNetwork.replan

        def corrupted_replan(self, calibrator=None, **kw):
            new, diff = real_replan(self, calibrator, **kw)
            doc = new.plan.to_json()
            assert _mut_negative_share(doc), "no decision to corrupt"
            bad_plan = CoexecPlan.from_json(doc, verify=False)
            bad = CompiledNetwork(plan=bad_plan, target=new.target,
                                  mode=new.mode, predictors=new.predictors)
            return bad, diff

        monkeypatch.setattr(CompiledNetwork, "replan", corrupted_replan)
        reqs = poisson_requests(
            48, rate=0.1 / cost, vocab_size=cfg.vocab_size,
            prompt_lens=(2, 4, 12), max_new=(2, 4), temperatures=(0.0,),
            seed=23)
        sched = ContinuousScheduler(
            cfg, model, params, portfolio=pf, plan_cache=cache_dir,
            config=SchedulerConfig(max_batch=2, max_len=32,
                                   fidelity_every=4, fidelity_window=4,
                                   drift_cooldown=2),
            throttle=ThrottleSim(at_s=100 * cost, scale=2.5))
        rep = sched.run(reqs)
        assert rep.replan_events == [], \
            "corrupted candidate reached the slot pool"
        assert pf.entries[bucket].key == old_key
        assert dataclasses.asdict(rep.stats[0]) is not None
