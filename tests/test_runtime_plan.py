"""Tests for the compiled co-execution plan subsystem (repro.runtime).

Covers: CoexecPlan JSON round-trip, PlanCache hit/miss/invalidation on
provenance changes, the zero-work guarantee on a warm hit, and exact
equivalence of the vectorized planners with the seed's per-candidate loop
formulation (reimplemented here as the reference).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.networks import NETWORKS
from repro.core.partitioner import (PartitionDecision, _candidate_splits,
                                    grid_search_partition,
                                    optimal_partition_batch)
from repro.core.planner import plan_network
from repro.core.predictor import sample_conv_ops, sample_linear_ops, \
    train_predictor
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import LatencyPredictor, MuxPredictor
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.measure import (measure_latency_us,
                                          measure_latency_us_batch)
from repro.core.sync import SyncMechanism, sync_overhead_us
from repro.core.types import ConvOp, LinearOp
from repro.runtime import (CoexecPlan, PlanCache, network_fingerprint,
                           plan_network_cached, predictor_checksum)

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)


def _small_units():
    return [("conv", ConvOp(28, 28, 32, 64, 3, 1)),
            ("pool", 4 * 14 * 14 * 64),
            ("conv", ConvOp(14, 14, 64, 96, 3, 1)),
            ("linear", LinearOp(1, 96, 128))]


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


# ------------------------------------------------------- serialization

def test_plan_json_roundtrip(mux_predictors, tmp_path):
    cp, gp = mux_predictors
    cache = PlanCache(tmp_path)
    plan = plan_network_cached(_small_units(), cp, gp, threads=3,
                               cache=cache)
    back = CoexecPlan.loads(plan.dumps())
    assert back.provenance == plan.provenance
    assert back.decisions == plan.decisions          # exact float equality
    assert back.baseline_us == plan.baseline_us
    assert back.individual_us == plan.individual_us
    assert back.end_to_end_us == plan.end_to_end_us
    assert back.units == _small_units()

    path = tmp_path / "sub" / "plan.json"
    plan.save(path)
    assert CoexecPlan.load(path).decisions == plan.decisions
    # the artifact is plain JSON with the documented top-level shape
    # ("segments" is the fused executor's partition metadata, omitted
    # when a plan predates it)
    doc = json.loads(path.read_text())
    assert set(doc) == {"schema_version", "provenance", "schedule",
                        "report", "segments"}


def test_fingerprint_and_checksum_are_stable(mux_predictors):
    cp, gp = mux_predictors
    assert network_fingerprint(_small_units()) == \
        network_fingerprint(_small_units())
    assert network_fingerprint(_small_units()) != \
        network_fingerprint(_small_units()[:-1])
    assert predictor_checksum(cp, gp) == predictor_checksum(cp, gp)
    assert predictor_checksum(cp) != predictor_checksum(gp)


# --------------------------------------------------------------- cache

def test_cache_miss_then_hit(mux_predictors, tmp_path):
    cp, gp = mux_predictors
    cache = PlanCache(tmp_path)
    p1 = plan_network_cached(_small_units(), cp, gp, threads=3, cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    p2 = plan_network_cached(_small_units(), cp, gp, threads=3, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    assert p2.decisions == p1.decisions
    assert p2.end_to_end_us == p1.end_to_end_us
    assert cache.keys() == [p1.key]


def test_warm_hit_performs_zero_measure_or_predict_calls(
        mux_predictors, tmp_path, monkeypatch):
    cp, gp = mux_predictors
    cache = PlanCache(tmp_path)
    plan_network_cached(_small_units(), cp, gp, threads=3, cache=cache)

    def _boom(*a, **k):
        raise AssertionError("warm cache hit must not touch the "
                             "simulator or the predictors")

    # sever every scoring entry point: the predictor class and both the
    # scalar and batched measurement functions in every importing module
    monkeypatch.setattr(LatencyPredictor, "predict", _boom)
    monkeypatch.setattr(MuxPredictor, "predict", _boom)
    for mod in ("repro.core.simulator.measure", "repro.core.partitioner",
                "repro.core.planner", "repro.core.predictor.train"):
        m = sys.modules[mod]
        for fn in ("measure_latency_us", "measure_latency_us_batch"):
            if hasattr(m, fn):
                monkeypatch.setattr(m, fn, _boom)

    plan = plan_network_cached(_small_units(), cp, gp, threads=3,
                               cache=cache)
    assert cache.hits == 1
    assert len(plan.decisions) == 3


def test_candidate_step_is_forwarded_and_keyed(mux_predictors, tmp_path):
    cp, gp = mux_predictors
    units = [("conv", ConvOp(28, 28, 32, 100, 3, 1))]
    cache = PlanCache(tmp_path)
    p8 = plan_network_cached(units, cp, gp, threads=3, cache=cache)
    p100 = plan_network_cached(units, cp, gp, threads=3, step=100,
                               cache=cache)
    # a step-100 grid over 100 channels is {0, 100}: exclusive only
    assert all(d.exclusive for d in p100.decisions)
    assert p100.provenance.step == 100
    assert p8.key != p100.key


def test_cache_invalidation_on_provenance_change(mux_predictors, tmp_path):
    cp, gp = mux_predictors
    cache = PlanCache(tmp_path)
    plan_network_cached(_small_units(), cp, gp, threads=3, cache=cache)

    # different thread count -> miss
    plan_network_cached(_small_units(), cp, gp, threads=2, cache=cache)
    # different sync mechanism -> miss
    plan_network_cached(_small_units(), cp, gp, threads=3,
                        mechanism=SyncMechanism.EVENT, cache=cache)
    # different network -> miss
    plan_network_cached(_small_units()[:-1], cp, gp, threads=3, cache=cache)
    # retrained predictor (different data) -> different checksum -> miss
    lt = sample_linear_ops(120, seed=9)
    ct = sample_conv_ops(120, seed=9)
    gp2 = MuxPredictor(
        train_predictor(lt, "moto2022", "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, "moto2022", "gpu", whitebox=True, params=_FAST))
    plan_network_cached(_small_units(), cp, gp2, threads=3, cache=cache)

    assert cache.hits == 0
    assert cache.misses == 5
    assert len(cache.keys()) == 5

    # every original request is now warm
    plan_network_cached(_small_units(), cp, gp, threads=3, cache=cache)
    assert cache.hits == 1


# ------------------------------------------- seed-loop equivalence oracle

def _seed_optimal_partition(op, cpu_pred, gpu_pred, *,
                            mechanism=SyncMechanism.SVM_POLL, step=8):
    """The seed's per-op implementation, kept verbatim as the oracle."""
    device = gpu_pred.device
    overhead = sync_overhead_us(device, mechanism)
    c_gpu = _candidate_splits(op.C_out, step)
    c_cpu = op.C_out - c_gpu
    gpu_ops = [op.with_cout(int(c)) for c in c_gpu]
    cpu_ops = [op.with_cout(int(c)) for c in c_cpu]
    t_gpu = np.where(c_gpu > 0, gpu_pred.predict(gpu_ops), 0.0)
    t_cpu = np.where(c_cpu > 0, cpu_pred.predict(cpu_ops), 0.0)
    coexec = (c_gpu > 0) & (c_cpu > 0)
    total = np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead, 0.0)
    i = int(np.argmin(total))
    return PartitionDecision(op=op, c_cpu=int(c_cpu[i]), c_gpu=int(c_gpu[i]),
                             pred_cpu_us=float(t_cpu[i]),
                             pred_gpu_us=float(t_gpu[i]),
                             pred_total_us=float(total[i]))


def _seed_grid_search(op, device, threads, *,
                      mechanism=SyncMechanism.SVM_POLL, step=8, seed=0):
    overhead = sync_overhead_us(device, mechanism)
    backend_cpu = f"cpu{threads}"
    c_gpu = _candidate_splits(op.C_out, step)
    c_cpu = op.C_out - c_gpu
    t_gpu = np.array([measure_latency_us(op.with_cout(int(c)), device, "gpu",
                                         seed=seed) if c else 0.0
                      for c in c_gpu])
    t_cpu = np.array([measure_latency_us(op.with_cout(int(c)), device,
                                         backend_cpu, seed=seed) if c else 0.0
                      for c in c_cpu])
    coexec = (c_gpu > 0) & (c_cpu > 0)
    total = np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead, 0.0)
    i = int(np.argmin(total))
    return PartitionDecision(op=op, c_cpu=int(c_cpu[i]), c_gpu=int(c_gpu[i]),
                             pred_cpu_us=float(t_cpu[i]),
                             pred_gpu_us=float(t_gpu[i]),
                             pred_total_us=float(total[i]))


@pytest.mark.parametrize("network", ["vgg16", "resnet18"])
def test_vectorized_planning_matches_seed_loop(mux_predictors, network):
    """Acceptance: batched planning is bit-identical to the seed loops."""
    cp, gp = mux_predictors
    units = NETWORKS[network]()
    ops = [payload for kind, payload in units if kind != "pool"]

    batched = optimal_partition_batch(ops, cp, gp)
    looped = [_seed_optimal_partition(op, cp, gp) for op in ops]
    assert batched == looped            # dataclass eq: exact ints + floats

    report = plan_network(units, cp, gp, threads=3)
    assert report.decisions == looped


def test_vectorized_grid_search_matches_seed_loop():
    ops = [LinearOp(50, 768, 640), LinearOp(8, 256, 1000),
           ConvOp(28, 28, 64, 96, 3, 1), ConvOp(14, 14, 128, 130, 1, 1)]
    for op in ops:
        assert grid_search_partition(op, "pixel5", 3) == \
            _seed_grid_search(op, "pixel5", 3)


def test_batched_measurement_matches_scalar():
    ops = [LinearOp(50, 768, 640), LinearOp(1, 16, 0),
           ConvOp(28, 28, 64, 96, 3, 1)]
    batch = measure_latency_us_batch(ops, "pixel5", "gpu", seed=3)
    scalar = [measure_latency_us(op, "pixel5", "gpu", seed=3) for op in ops]
    assert batch.tolist() == scalar
    assert batch[1] == 0.0


# --------------------------------------------------------- integrations

def test_serving_engine_accepts_plan(mux_predictors, tmp_path):
    from repro.serving.engine import ServingEngine

    cp, gp = mux_predictors
    cache = PlanCache(tmp_path)
    plan = plan_network_cached(_small_units(), cp, gp, threads=3,
                               cache=cache)

    class _Model:                      # never traced: jit is lazy
        @staticmethod
        def prefill(params, toks, cache):
            raise NotImplementedError

        @staticmethod
        def decode_step(params, tok, cache, pos):
            raise NotImplementedError

    eng = ServingEngine(cfg=None, model=_Model, params={},
                        coexec_plan=plan)
    assert eng.coexec_plan is plan
    with pytest.raises(TypeError):
        ServingEngine(cfg=None, model=_Model, params={},
                      coexec_plan={"not": "a plan"})


def test_plan_cli_cold_then_warm(tmp_path):
    env = dict(os.environ)
    root = os.path.join(os.path.dirname(__file__), "..")
    env["PYTHONPATH"] = os.path.join(root, "src")
    cmd = [sys.executable, "-m", "repro.runtime.plan",
           "--network", "resnet18", "--device", "moto2022", "--threads", "3",
           "--samples", "120", "--estimators", "25",
           "--cache-dir", str(tmp_path),
           "--out", str(tmp_path / "plan.json")]
    cold = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=300)
    assert cold.returncode == 0, cold.stderr[-2000:]
    assert "cache MISS" in cold.stdout
    plan = CoexecPlan.load(tmp_path / "plan.json")
    assert plan.provenance.device == "moto2022"
    assert len(plan.decisions) > 0

    warm = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=300)
    assert warm.returncode == 0, warm.stderr[-2000:]
    assert "cache HIT" in warm.stdout
