"""Tests for the sharding rules (spec construction + divisibility guard)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_host_mesh
from repro.sharding.rules import batch_spec, param_spec, sanitize


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
POD_MESH = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def _leaf(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.bfloat16)


def _spec_for(path_str, shape, mesh=MESH):
    class _K:
        def __init__(self, key):
            self.key = key
    path = tuple(_K(p) for p in path_str.split("/"))
    return param_spec(path, _leaf(shape), mesh)


def test_sanitize_drops_nondivisible_axes():
    assert sanitize(("model", None), (20, 64), MESH) == P(None, None)
    assert sanitize(("model", None), (32, 64), MESH) == P("model", None)
    assert sanitize((None, "model"), (4, 128), MESH) == P(None, "model")


def test_embed_sharded_on_vocab_and_dmodel():
    spec = _spec_for("embed", (102400, 2048))
    assert spec == P("model", "data")


def test_attention_projection_2d_sharded():
    spec = _spec_for("pattern/0/attn/wq", (16, 4096, 4096))
    assert spec == P(None, "data", "model")       # stacked layer dim free


def test_moe_expert_axis_on_model():
    spec = _spec_for("pattern/0/ffn/w_gate", (26, 64, 2048, 1408))
    assert spec == P(None, "model", "data", None)


def test_awkward_head_count_degrades_gracefully():
    # whisper: kv*hd = 1280 divides 16; a 20-dim leaf would not
    spec = _spec_for("decoder/self_attn/wk", (32, 1280, 1280))
    assert spec == P(None, "data", "model")
    spec2 = _spec_for("decoder/self_attn/bq", (32, 20))
    assert spec2 == P(None, None)                 # 20 % 16 != 0 -> replicate


def test_norms_replicated():
    assert _spec_for("pattern/0/ln1", (26, 2048)) == P(None, None)


def test_batch_spec_handles_small_batches():
    assert batch_spec(256, MESH) == P(("data",))
    assert batch_spec(1, MESH) == P(None)
    assert batch_spec(512, POD_MESH) == P(("pod", "data"))


def test_param_shardings_cover_whole_model():
    """Every leaf of a real model gets a valid NamedSharding on a real
    (1, n) host mesh."""
    from repro.models import build_model, get_config
    from repro.sharding.rules import param_shardings
    mesh = make_host_mesh()
    cfg = get_config("deepseek_v2_lite").reduced()
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    sh = param_shardings(params, mesh)
    assert jax.tree.structure(sh) == jax.tree.structure(params)
