import os
import sys

# NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
# benches must see the real single-device CPU platform.  Only
# src/repro/launch/dryrun.py (a separate process) forces 512 host devices.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# the tests dir itself, so `from hypothesis_fallback import ...` resolves
# regardless of pytest's import mode
sys.path.insert(0, os.path.dirname(__file__))

import numpy as np
import pytest

from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import train_predictor
from repro.core.predictor.dataset import sample_conv_ops, sample_linear_ops

_FAST = GBDTParams(n_estimators=80, max_depth=7, learning_rate=0.15)


@pytest.fixture(scope="session")
def linear_train_ops():
    return sample_linear_ops(900, seed=1)


@pytest.fixture(scope="session")
def conv_train_ops():
    return sample_conv_ops(900, seed=1)


@pytest.fixture(scope="session")
def pixel5_linear_predictors(linear_train_ops):
    gp = train_predictor(linear_train_ops, "pixel5", "gpu", whitebox=True,
                         params=_FAST)
    cp = train_predictor(linear_train_ops, "pixel5", "cpu3", whitebox=False,
                         params=_FAST)
    return cp, gp
