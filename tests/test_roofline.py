"""Tests for the HLO cost walker and roofline extraction."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.analysis import (collective_bytes_per_device,
                                     model_flops_estimate)
from repro.roofline.hlo_walk import analyze_hlo


def test_walker_counts_scan_trip_counts():
    """XLA cost_analysis counts a while body once; the walker must multiply
    by the known trip count."""
    def scanned(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):          # older jax returns one dict per device
        ca = ca[0]
    raw = ca.get("flops", 0.0)
    walked = analyze_hlo(compiled.as_text()).flops
    expected = 7 * 2 * 128 ** 3
    assert abs(walked - expected) / expected < 0.05, walked
    assert raw < walked                     # proves the raw undercount


def test_walker_matmul_flops_exact():
    f = jax.jit(lambda a, b: a @ b)
    a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
    walked = analyze_hlo(f.lower(a, b).compile().as_text())
    assert walked.flops == 2 * 64 * 256 * 32


def test_walker_nested_scans_multiply():
    def nested(x, ws):
        def outer(x, w):
            def inner(x, _):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(inner, x, None, length=3)
            return y, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    walked = analyze_hlo(jax.jit(nested).lower(x, ws).compile().as_text())
    expected = 5 * 3 * 2 * 64 ** 3
    assert abs(walked.flops - expected) / expected < 0.05


def test_collective_parse_from_real_sharded_hlo():
    """Collective operand bytes from an actual SPMD-partitioned program."""
    import os
    import subprocess
    import sys
    import textwrap
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.roofline.analysis import collective_bytes_per_device
        mesh = jax.make_mesh((8,), ("m",))
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 64), jnp.float32)
        with mesh:
            f = jax.jit(lambda x, y: x @ y, in_shardings=(
                NamedSharding(mesh, P(None, "m")),
                NamedSharding(mesh, P("m", None))))
            txt = f.lower(a, b).compile().as_text()
        out = collective_bytes_per_device(txt)
        # contracting-dim sharding => all-reduce of the (64,64) f32 result
        assert out["all-reduce"] == 64 * 64 * 4, out
        print("COLL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    r = subprocess.run([sys.executable, "-c", prog], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "COLL_OK" in r.stdout


def test_model_flops_estimate_scales():
    from repro.launch.shapes import INPUT_SHAPES
    from repro.models import get_config
    cfg = get_config("codeqwen15_7b")
    t = model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
    p = model_flops_estimate(cfg, INPUT_SHAPES["prefill_32k"])
    d = model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
    assert t > p > d
    # train is 6NBT, prefill 2NBT with the respective token counts
    assert abs(t / (6 * cfg.active_param_count() * 256 * 4096) - 1) < 1e-6


def test_moe_uses_active_params():
    from repro.launch.shapes import INPUT_SHAPES
    from repro.models import get_config
    moe = get_config("llama4_scout")
    est = model_flops_estimate(moe, INPUT_SHAPES["train_4k"])
    assert est < 6 * moe.param_count() * 256 * 4096
