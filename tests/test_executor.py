"""Tests for the plan execution runtime (repro.runtime.executor).

In-process tests run on the real single-device CPU platform, where
`coexec_mesh` degrades to one group and the executor runs every unit
unsplit (exclusive) — equivalence with the oracle then validates the
registry lowering, pool lowering and shape adaptation.  True split
execution (2 groups), gather-elided chaining and mesh-degradation sweeps
need >1 device, so they run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (kept out of this
process on purpose — see conftest.py).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.networks import (NETWORKS, pool_out_edge, unit_input_shape,
                                 unit_output_shape)
from repro.core.predictor import sample_conv_ops, sample_linear_ops, \
    train_predictor
from repro.core.predictor.gbdt import GBDTParams
from repro.core.predictor.train import MuxPredictor
from repro.core.types import ConvOp, LinearOp
from repro.kernels import registry
from repro.runtime import (PlanCache, PlanExecutor, decision_to_spec,
                           plan_network_cached)

_FAST = GBDTParams(n_estimators=40, max_depth=6, learning_rate=0.2)


@pytest.fixture(scope="module")
def mux_predictors():
    lt = sample_linear_ops(250, seed=1)
    ct = sample_conv_ops(250, seed=1)
    dev = "moto2022"
    gp = MuxPredictor(
        train_predictor(lt, dev, "gpu", whitebox=True, params=_FAST),
        train_predictor(ct, dev, "gpu", whitebox=True, params=_FAST))
    cp = MuxPredictor(
        train_predictor(lt, dev, "cpu3", whitebox=False, params=_FAST),
        train_predictor(ct, dev, "cpu3", whitebox=False, params=_FAST))
    return cp, gp


def _small_units():
    return [("conv", ConvOp(28, 28, 32, 64, 3, 1)),
            ("conv", ConvOp(28, 28, 64, 64, 3, 2)),
            ("pool", 4 * 7 * 7 * 64),
            ("conv", ConvOp(7, 7, 64, 96, 3, 1)),
            ("pool", 4 * 96),
            ("linear", LinearOp(1, 96, 128))]


def _plan(units, mux_predictors, tmp_path):
    cp, gp = mux_predictors
    return plan_network_cached(units, cp, gp, threads=3,
                               cache=PlanCache(tmp_path))


# ------------------------------------------------------------ registry

def test_registry_is_the_shared_dispatch_table():
    lin = LinearOp(4, 32, 64)
    conv = ConvOp(8, 8, 16, 24, 3, 2)
    assert registry.op_kind(lin) == "linear"
    assert registry.op_kind(conv) == "conv"
    assert registry.get("linear").input_shape(lin) == (4, 32)
    assert registry.get("linear").weight_shape(lin) == (32, 64)
    assert registry.get("conv").output_shape(conv) == (4, 4, 24)
    # the predictors featurize through the same table
    from repro.core.predictor.features import blackbox_features
    feats = blackbox_features([lin])
    assert feats.shape == (1, len(registry.get("linear").base_features(lin)))
    np.testing.assert_allclose(feats[0],
                               registry.get("linear").base_features(lin))
    # lowerings resolve lazily and compute
    low = registry.get_lowering("linear")
    x = jnp.ones((4, 32)); w = jnp.ones((32, 64))
    np.testing.assert_allclose(np.asarray(low.oracle(x, w, lin)),
                               np.asarray(x @ w))
    # decoder-block kinds are first-class registry entries (graph IR era),
    # but never splittable; unknown kinds still raise
    assert not registry.get("attention").splittable
    assert not registry.get("ssm").splittable
    with pytest.raises(KeyError):
        registry.get("softmax")


def test_conv_lowering_crops_to_declared_shape():
    # SAME stride-2 conv at odd H gives ceil(H/S); ConvOp declares floor
    op = ConvOp(35, 35, 8, 16, 3, 2)
    low = registry.get_lowering("conv")
    x = jnp.ones((1, 35, 35, 8)); w = jnp.ones((3, 3, 8, 16))
    assert low.oracle(x, w, op).shape == (1,) + registry.get(
        "conv").output_shape(op)


def test_networks_expose_shapes():
    assert unit_input_shape(("conv", ConvOp(28, 28, 32, 64, 3, 2))) == \
        (28, 28, 32)
    assert unit_input_shape(("pool", 4 * 7 * 7 * 64)) is None
    assert unit_output_shape(("conv", ConvOp(28, 28, 32, 64, 3, 2))) == \
        (14, 14, 64)
    assert unit_output_shape(("linear", LinearOp(2, 8, 10))) == (2, 10)
    assert unit_output_shape(("pool", 4 * 14 * 14 * 64), c_prev=64) == \
        (14, 14, 64)
    assert pool_out_edge(4 * 512, 512) == 1          # global pooling
    assert pool_out_edge(4 * 56 * 56 * 64, 64) == 56


# ----------------------------------------------------------- exec specs

def test_exec_specs_mirror_schedule(mux_predictors, tmp_path):
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    specs = plan.exec_specs()
    assert [s.unit for s in specs] == [k for k, _ in _small_units()]
    for spec, dec in zip([s for s in specs if s.unit != "pool"],
                         plan.decisions):
        assert spec == decision_to_spec(dec)
        assert (spec.c_fast, spec.c_slow) == (dec.c_gpu, dec.c_cpu)
        assert spec.exclusive == dec.exclusive
    pool = [s for s in specs if s.unit == "pool"]
    assert [p.pool_bytes for p in pool] == [4 * 7 * 7 * 64, 4 * 96]
    assert all(not p.coexec for p in pool)


def test_executor_rejects_mismatched_units(mux_predictors, tmp_path):
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    with pytest.raises(ValueError, match="fingerprint"):
        PlanExecutor(plan, units=_small_units()[:-1])


# ----------------------------------- oracle equivalence (degraded mesh)

def test_degraded_mesh_runs_exclusively(mux_predictors, tmp_path):
    """Satellite: on this single-device platform the mesh degrades to one
    group and every planned co-execution runs as exclusive execution."""
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    exe = PlanExecutor(plan)
    assert not exe.split_capable           # 1 CPU device -> 1 group
    y, rep = exe.run()
    assert rep.count("coexec") == 0
    assert rep.count("exclusive") == 4 and rep.count("pool") == 2
    assert rep.reshard_points == 0 and rep.elided == 0
    np.testing.assert_allclose(np.asarray(y), np.asarray(exe.run_oracle()),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("network,n_units", [("resnet18", 5), ("vgg16", 4)])
@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-4),
                                       (jnp.bfloat16, 5e-2)])
def test_executed_slice_matches_oracle_across_dtypes(
        mux_predictors, tmp_path, network, n_units, dtype, tol):
    units = NETWORKS[network]()[:n_units]
    plan = _plan(units, mux_predictors, tmp_path)
    exe = PlanExecutor(plan, dtype=dtype)
    y, rep = exe.run()
    assert len(rep.timings) == n_units
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(exe.run_oracle(), np.float32),
                               rtol=tol, atol=tol)


def test_executed_resnet18_plan_end_to_end(mux_predictors, tmp_path):
    """Acceptance: a cached resnet18 CoexecPlan executes end to end and
    matches the unsplit oracle."""
    units = NETWORKS["resnet18"]()
    plan = _plan(units, mux_predictors, tmp_path)
    # warm cache: the executor consumes the stored artifact
    plan = _plan(units, mux_predictors, tmp_path)
    exe = PlanExecutor(plan)
    y, rep = exe.run()
    assert y.shape == (1, 1000)
    assert len(rep.timings) == len(units)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exe.run_oracle()),
                               rtol=2e-4, atol=2e-4)
    summary = rep.fidelity_summary()
    assert summary.startswith("fidelity:") and "reshard" in summary


def test_execution_report_serializes(mux_predictors, tmp_path):
    plan = _plan(_small_units(), mux_predictors, tmp_path)
    exe = PlanExecutor(plan)
    _, rep = exe.run()
    doc = json.loads(json.dumps(rep.to_json()))
    assert doc["network_fingerprint"] == plan.provenance.network_fingerprint
    assert len(doc["timings"]) == len(_small_units())
    assert {"index", "unit", "mode", "wall_us", "pred_us"} <= \
        set(doc["timings"][0])


def test_serving_engine_executes_plan(mux_predictors, tmp_path):
    from repro.serving.engine import ServingEngine

    plan = _plan(_small_units(), mux_predictors, tmp_path)

    class _Model:                      # never traced: jit is lazy
        @staticmethod
        def prefill(params, toks, cache):
            raise NotImplementedError

        @staticmethod
        def decode_step(params, tok, cache, pos):
            raise NotImplementedError

    eng = ServingEngine(cfg=None, model=_Model, params={}, coexec_plan=plan)
    y, rep = eng.execute_plan()
    assert eng.last_execution_report is rep
    assert rep.fidelity_summary().startswith("fidelity:")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(eng.plan_executor.run_oracle()),
        rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError):
        ServingEngine(cfg=None, model=_Model, params={}).execute_plan()


# ------------------------------------ true split execution (subprocess)

_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    from repro.core.coexec import coexec_mesh, mesh_groups
    from repro.core.networks import NETWORKS
    from repro.core.partitioner import PartitionDecision
    from repro.core.types import ConvOp, LinearOp
    from repro.runtime.executor import PlanExecutor
    from repro.runtime.plan import (CoexecPlan, PlanProvenance,
                                    build_schedule, network_fingerprint)

    devs = jax.devices()
    assert len(devs) == 8
    # satellite: coexec_mesh degrades on <2 and odd device counts
    for k, want in [(1, 1), (2, 2), (3, 2), (5, 2), (8, 2)]:
        assert mesh_groups(coexec_mesh(devs[:k])) == want, k

    def forced_plan(units, splits):
        decs = []
        i = 0
        for kind, payload in units:
            if kind == "pool":
                continue
            c_fast, c_slow = splits[i]
            decs.append(PartitionDecision(
                op=payload, c_cpu=c_slow, c_gpu=c_fast,
                pred_cpu_us=1.0, pred_gpu_us=1.0, pred_total_us=2.0))
            i += 1
        prov = PlanProvenance(
            device="moto2022", threads=3, mechanism="svm_poll", step=8,
            seed=1, network_fingerprint=network_fingerprint(units),
            predictor_checksum="")
        return CoexecPlan(provenance=prov,
                          schedule=build_schedule(units, decs))

    mesh = coexec_mesh(devs)

    def check(units, splits, tag):
        exe = PlanExecutor(forced_plan(units, splits), mesh=mesh)
        assert exe.split_capable
        y_chain, rep_chain = exe.run(chain=True)
        y_gather, rep_gather = exe.run(chain=False)
        y_oracle = exe.run_oracle()
        np.testing.assert_allclose(np.asarray(y_chain),
                                   np.asarray(y_oracle),
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(np.asarray(y_gather),
                                   np.asarray(y_oracle),
                                   rtol=2e-5, atol=2e-5)
        # elision must not change values, only the number of sync points
        np.testing.assert_allclose(np.asarray(y_chain),
                                   np.asarray(y_gather),
                                   rtol=1e-6, atol=1e-6)
        assert rep_chain.reshard_points < rep_gather.reshard_points, tag
        assert rep_chain.elided > 0 and rep_gather.elided == 0, tag
        assert rep_chain.count("coexec") == rep_gather.count("coexec") > 0
        print(tag, "reshard", rep_chain.reshard_points, "vs",
              rep_gather.reshard_points, "elided", rep_chain.elided)

    units = [("conv", ConvOp(16, 16, 8, 32, 3, 1)),
             ("conv", ConvOp(16, 16, 32, 48, 3, 1)),
             ("conv", ConvOp(16, 16, 48, 48, 3, 2)),
             ("pool", 4 * 4 * 4 * 48),
             ("conv", ConvOp(4, 4, 48, 64, 3, 1)),
             ("linear", LinearOp(1, 4 * 4 * 64, 100)),
             ("linear", LinearOp(1, 100, 40))]
    check(units, [(24, 8), (32, 16), (16, 32), (40, 24), (60, 40),
                  (30, 10)], "synthetic")

    # a real resnet18 tail slice (stage-4 convs + global pool + classifier),
    # mixed with exclusive ops
    tail = NETWORKS["resnet18"]()[-6:]
    ops = [p for k, p in tail if k != "pool"]
    splits = []
    for j, op in enumerate(ops):
        if j == 1:
            splits.append((op.C_out, 0))         # exclusive boundary
        else:
            splits.append((op.C_out - op.C_out // 4, op.C_out // 4))
    check(tail, splits, "resnet18-tail")
    print("SPLIT_EXEC_OK")
""")


def test_split_execution_and_gather_elision_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SPLIT_EXEC_OK" in out.stdout
