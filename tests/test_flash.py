"""Flash (chunked online-softmax) attention vs naive oracles, including
the MLA latent variants and hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # graceful fallback, see hypothesis_fallback
    from hypothesis_fallback import given, settings, st

from repro.models.flash import (flash_decode, flash_full, flash_latent_full,
                                flash_latent_decode)
from repro.models.layers import _causal_mask, attention_scores


def _naive(q, k, v, window=0):
    t = q.shape[1]
    return attention_scores(q, k, v, _causal_mask(t, t, window=window))


@settings(max_examples=8, deadline=None)
@given(t=st.sampled_from([256, 512, 1024]),
       h=st.sampled_from([4, 8]),
       kv=st.sampled_from([1, 2, 4]),
       window=st.sampled_from([0, 64]))
def test_flash_full_matches_naive(t, h, kv, window):
    if h % kv:
        kv = 1
    rng = np.random.default_rng(t + h + kv)
    q = jnp.asarray(rng.normal(size=(2, t, h, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, t, kv, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, t, kv, 32)), jnp.float32)
    got = flash_full(q, k, v, window=window, bq=128, bk=128)
    want = _naive(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def test_flash_decode_matches_naive():
    rng = np.random.default_rng(0)
    s = 1024
    q = jnp.asarray(rng.normal(size=(2, 1, 8, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, s, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, s, 2, 64)), jnp.float32)
    for pos in (0, 100, s - 1):
        got = flash_decode(q, k, v, jnp.int32(pos), bk=256)
        mask = (jnp.arange(s) <= pos)[None, :]
        want = attention_scores(q, k, v, mask)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


def test_flash_full_grad_is_finite():
    """Backward through the checkpointed double scan must be stable."""
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)

    def f(q, k, v):
        return flash_full(q, k, v, bq=64, bk=64).sum()

    grads = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in grads:
        assert np.all(np.isfinite(np.asarray(g)))


def test_flash_latent_matches_dense_mla():
    """flash_latent_full vs the dense absorbed-latent oracle."""
    rng = np.random.default_rng(2)
    b, t, h, r, rd = 2, 256, 4, 32, 16
    q_lat = jnp.asarray(rng.normal(size=(b, t, h, r)), jnp.float32)
    q_rope = jnp.asarray(rng.normal(size=(b, t, h, rd)), jnp.float32)
    c_kv = jnp.asarray(rng.normal(size=(b, t, r)), jnp.float32)
    k_rope = jnp.asarray(rng.normal(size=(b, t, rd)), jnp.float32)
    scale = 0.11
    got = flash_latent_full(q_lat, q_rope, c_kv, k_rope, scale,
                            bq=64, bk=64)
    scores = (jnp.einsum("bthr,bsr->bhts", q_lat, c_kv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) * scale
    mask = jnp.arange(t)[None, :] <= jnp.arange(t)[:, None]
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, -1)
    want = jnp.einsum("bhts,bsr->bthr", probs, c_kv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)

    got_d = flash_latent_decode(q_lat[:, -1:], q_rope[:, -1:], c_kv,
                                k_rope, jnp.int32(t - 1), scale, bk=64)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want[:, -1:]),
                               rtol=3e-4, atol=3e-4)


def test_rwkv_chunked_wkv_matches_step_path():
    """End-to-end: chunked-WKV forward vs the step recurrence on the same
    reduced rwkv6 model (bf16 model tolerance)."""
    from repro.models import build_model, get_config
    import repro.models.ssm as ssm

    cfg = get_config("rwkv6_1b6").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 128)),
                       jnp.int32)
    chunked, _ = m.forward(params, toks)
    orig = ssm._WKV_CHUNK
    try:
        ssm._WKV_CHUNK = 10 ** 9          # force the step path
        step, _ = m.forward(params, toks)
    finally:
        ssm._WKV_CHUNK = orig
    a = np.asarray(chunked, np.float32)
    b = np.asarray(step, np.float32)
    assert np.abs(a - b).max() < 0.08     # bf16 accumulation noise
    assert np.mean(np.abs(a - b) > 0.02) < 5e-3


def test_ssd_chunked_matches_step_scan():
    """Chunked SSD (EXPERIMENTS.md §Perf A) vs the per-timestep scan."""
    import repro.models.ssm as ssm
    rng = np.random.default_rng(0)
    b, t, h, hd, n = 2, 512, 4, 16, 8
    x = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, t, n)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(b, t, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1.5, size=(h,)), jnp.float32)
    h0 = jnp.asarray(rng.normal(size=(b, h, hd, n)), jnp.float32)

    decay = jnp.exp(dt * a)

    def step(s, inp):
        x_t, b_t, c_t, dec_t, dt_t = inp
        upd = dt_t[..., None, None] * (x_t[..., :, None]
                                       * b_t[:, None, None, :])
        s = dec_t[..., None, None] * s + upd
        return s, jnp.einsum("bhdn,bn->bhd", s, c_t)

    seq = (x.swapaxes(0, 1), bm.swapaxes(0, 1), cm.swapaxes(0, 1),
           decay.swapaxes(0, 1), dt.swapaxes(0, 1))
    sf_ref, ys = jax.lax.scan(step, h0, seq)
    y_ref = ys.swapaxes(0, 1)
    sf_chk, y_chk = ssm._ssd_chunked(x, bm, cm, dt, a, h0)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf_chk), np.asarray(sf_ref),
                               rtol=3e-4, atol=3e-4)


def test_wkv_chunked_exact_across_decay_regimes():
    from repro.models.ssm import _wkv_chunked, _wkv_step
    rng = np.random.default_rng(0)
    for decay_lo in (0.55, 0.05, 0.95):
        b, t, h, hd = 2, 128, 2, 16
        r = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, t, h, hd)), jnp.float32)
        w = jnp.asarray(rng.uniform(decay_lo, 0.999, size=(b, t, h, hd)),
                        jnp.float32)
        u = jnp.asarray(rng.normal(size=(h, hd)) * 0.1, jnp.float32)
        s0 = jnp.asarray(rng.normal(size=(b, h, hd, hd)), jnp.float32)

        def step(s, inp):
            return _wkv_step(s, inp, u)

        seq = tuple(z.swapaxes(0, 1) for z in (r, k, v, w))
        sf_ref, outs = jax.lax.scan(step, s0, seq)
        o_ref = outs.swapaxes(0, 1)
        sf_chk, o_chk = _wkv_chunked(r, k, v, w, u, s0)
        np.testing.assert_allclose(np.asarray(o_chk), np.asarray(o_ref),
                                   rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(np.asarray(sf_chk), np.asarray(sf_ref),
                                   rtol=5e-4, atol=5e-4)
