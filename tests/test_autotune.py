"""Tile-config autotuner: grid legality, numerics, cache, plan threading.

Covers the PR-9 contract end to end: every numerics-preserving candidate
is bit-identical fp32 to the default blocking on all four op kinds,
reduction-axis variation is tolerance-exact, illegal explicit tiles raise
at validation (no silent clamping), the TuneCache digest discipline
(cold/warm/corrupt/cross-instance), the byte-compatibility guarantees for
pre-tile plan JSON and provenance digests, tile-aware predictor
featurization, and the `compile(..., tune=True)` annotation pass.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.partitioner import PartitionDecision  # noqa: E402
from repro.core.types import AttnOp, ConvOp, LinearOp, SSMOp  # noqa: E402
from repro.kernels import registry  # noqa: E402
from repro.runtime.autotune import (TuneCache, TuneKey,  # noqa: E402
                                    annotate_plan_tiles, autotune,
                                    tune_cache_version)
from repro.runtime.plan import (PlanProvenance, decision_from_json,  # noqa: E402
                                decision_to_json, decision_to_spec,
                                predictor_checksum, spec_label)

#: one small op per kind (conv is winograd-eligible: C_out >= 128)
OPS = {
    "linear": LinearOp(L=16, C_in=256, C_out=256),
    "conv": ConvOp(H_in=8, W_in=8, C_in=32, C_out=128),
    "attention": AttnOp(H=4, S=256, KV=2, hd=16),
    "ssm": SSMOp(T=64, H=2, hd=8, N=16),
}


def _io(op):
    from repro.runtime.autotune import _op_arrays
    return _op_arrays(op, seed=3)


def _pallas(op, tile):
    x, w = _io(op)
    low = registry.get_lowering(registry.op_kind(op))
    return np.asarray(jax.block_until_ready(
        low.pallas(x, w, op, interpret=True, tile=tile)))


# ------------------------------------------------ differential: numerics

@pytest.mark.parametrize("kind", sorted(OPS))
def test_preserving_grid_is_bit_identical_to_default(kind):
    """Every candidate in the numerics-preserving grid computes the exact
    same fp32 bytes as the default blocking — output tiling only."""
    op = OPS[kind]
    spec = registry.tile_spec(kind)
    grid = spec.configs(op)
    default = spec.default_config(op)
    assert default in grid
    # reduction params stay pinned to the default-resolved value
    for cfg in grid:
        for p in spec.params:
            if p.reduction:
                assert cfg.get(p.name) == default.get(p.name), cfg.label()
    ref = _pallas(op, None)
    x, w = _io(op)
    oracle = np.asarray(
        registry.get_lowering(kind).oracle(x, w, op))
    np.testing.assert_allclose(ref, oracle, rtol=2e-3, atol=2e-3)
    for cfg in grid:
        y = _pallas(op, cfg)
        assert y.tobytes() == ref.tobytes(), cfg.label()


@pytest.mark.parametrize("kind,tile_kw", [
    ("linear", {"bk": 128}),          # split reduction: reassociates
    ("attention", {"bs": 128}),       # smaller cache block than default
    ("ssm", {"chunk": 32}),           # finer chunking than default
])
def test_reduction_axis_variation_is_tolerance_exact(kind, tile_kw):
    op = OPS[kind]
    spec = registry.tile_spec(kind)
    default = spec.default_config(op)
    cfg = spec.config(**{**default.as_dict(), **tile_kw})
    assert cfg != default
    y = _pallas(op, cfg)
    np.testing.assert_allclose(y, _pallas(op, None), rtol=1e-5, atol=1e-5)


def test_extended_linear_grid_searches_reduction_axis():
    op = OPS["linear"]
    spec = registry.tile_spec("linear")
    bks = {cfg.get("bk") for cfg in spec.configs(op,
                                                 preserve_numerics=False)}
    assert len(bks) > 1                       # bk actually varies
    assert all(len({c.get("bk")
                    for c in spec.configs(op)}) == 1 for _ in [0])


def test_attention_preserving_grid_collapses_to_default():
    op = OPS["attention"]
    spec = registry.tile_spec("attention")
    assert spec.configs(op) == [spec.default_config(op)]


# ------------------------------------------- strict validation, no clamp

def test_illegal_explicit_tiles_raise_at_kernel_entry():
    lin = OPS["linear"]
    spec = registry.tile_spec("linear")
    x, w = _io(lin)
    low = registry.get_lowering("linear")
    base = spec.default_config(lin).as_dict()
    for bad in ({"bm": 12},              # not a multiple of the min tile
                {"bn": 1024},            # exceeds the padded C_out extent
                {"bm": -8}):             # not positive
        cfg = spec.config(**{**base, **bad})
        with pytest.raises(ValueError, match="tile"):
            low.pallas(x, w, lin, interpret=True, tile=cfg)
    ssm = OPS["ssm"]
    with pytest.raises(ValueError, match="divide"):
        registry.get_lowering("ssm").pallas(
            *_io(ssm), ssm, interpret=True,
            tile=registry.tile_spec("ssm").config(chunk=48))


def test_clamp_lives_in_registry_not_kernels():
    """The old silent kernel clamp is now an explicit registry rewrite."""
    op = OPS["linear"]
    spec = registry.tile_spec("linear")
    oversize = spec.config(bm=256, bn=512, bk=256)
    extents = registry.tile_extents(op)
    with pytest.raises(ValueError, match="exceeds the padded"):
        spec.validate_tile(oversize, extents)
    clamped = spec.clamp_tile(oversize, extents)
    assert clamped.get("bm") == 16 and clamped.get("bn") == 256
    assert registry.resolve_tile(op, clamped) == clamped


def test_vmem_budget_rejects_oversized_working_sets():
    big = LinearOp(L=4096, C_in=4096, C_out=4096)
    spec = registry.tile_spec("linear")
    with pytest.raises(ValueError, match="VMEM budget"):
        spec.validate_tile(spec.config(bm=4096, bn=4096, bk=4096),
                           registry.tile_extents(big))


def test_winograd_min_cout_hoisted_into_registry():
    assert registry.WINOGRAD_MIN_COUT == 128
    assert OPS["conv"].C_out >= registry.WINOGRAD_MIN_COUT


# --------------------------------------------------------- tile codecs

def test_tile_json_roundtrip_and_mismatch():
    spec = registry.tile_spec("linear")
    cfg = spec.config(bm=8, bn=256, bk=256)
    assert registry.tile_from_json(
        "linear", registry.tile_to_json(cfg)) == cfg
    with pytest.raises(ValueError, match="spec params"):
        registry.tile_from_json("linear", {"bm": 8})
    with pytest.raises(ValueError, match="unknown tile param"):
        spec.config(bz=4)


# ------------------------------------------------------------ TuneCache

def test_tune_cache_cold_warm_corrupt_and_cross_instance(tmp_path):
    op = OPS["linear"]
    key = TuneKey.for_op(op, "host", "cpu")
    cache = TuneCache(tmp_path)
    assert cache.get(key) is None and cache.misses == 1
    tile = registry.tile_spec("linear").config(bm=8, bn=256, bk=256)
    path = cache.put(key, tile, [("bm8/bn256/bk256", 12.0)])
    assert cache.get(key) == tile and cache.hits == 1
    # a fresh instance (≈ another process) hits the same file
    other = TuneCache(tmp_path)
    assert other.get(key) == tile and other.hits == 1
    assert other.keys() == [key.key]
    # corrupt JSON and mismatched keys are misses, never trusted
    path.write_text("{not json")
    assert TuneCache(tmp_path).get(key) is None
    doc = {"schema_version": 1, "key": {"device": "elsewhere"},
           "tile": registry.tile_to_json(tile), "measured_us": []}
    path.write_text(json.dumps(doc))
    assert TuneCache(tmp_path).get(key) is None
    # a different search mode never aliases
    relaxed = TuneKey.for_op(op, "host", "cpu", preserve_numerics=False)
    assert relaxed.key != key.key


def test_tune_key_digests_kernel_version():
    op = OPS["linear"]
    key = TuneKey.for_op(op, "host", "cpu")
    bumped = dataclasses.replace(key, kernel_version=key.kernel_version + 1)
    assert bumped.key != key.key
    assert tune_cache_version() == \
        f"tune-v1.k{registry.KERNEL_TILE_VERSION}"


def test_autotune_hysteresis_and_cache(tmp_path, monkeypatch):
    op = OPS["linear"]
    spec = registry.tile_spec("linear")
    default = spec.default_config(op)
    winner = spec.config(bm=8, bn=256, bk=256)
    assert winner in spec.configs(op)

    timings = {winner: 50.0, default: 100.0}

    def fake_measure(op_, tile, **kw):
        cfg = registry.resolve_tile(op_, tile)
        return timings.get(cfg, 100.0)

    import repro.runtime.autotune as at
    monkeypatch.setattr(at, "measure_tile_us", fake_measure)
    cache = TuneCache(tmp_path)
    best = autotune(op, cache=cache, device="host", backend="cpu")
    assert best == winner and cache.misses == 1
    # warm: returned from disk without re-measuring
    monkeypatch.setattr(at, "measure_tile_us",
                        lambda *a, **k: pytest.fail("measured on warm hit"))
    assert autotune(op, cache=TuneCache(tmp_path), device="host",
                    backend="cpu") == winner
    # hysteresis: a 1% win does not dethrone the default
    monkeypatch.setattr(at, "measure_tile_us", fake_measure)
    timings[winner] = 99.5
    best = autotune(op, device="host", backend="cpu")
    assert best == default


# ------------------------------------------- plan byte-compat regression

def _decision(op, tile=None):
    return PartitionDecision(op=op, c_cpu=0, c_gpu=op.C_out,
                             pred_cpu_us=0.0, pred_gpu_us=1.0,
                             pred_total_us=1.0, tile=tile)


def test_untuned_decision_json_has_no_tile_key():
    """Pre-PR-9 byte compatibility: tile is omit-when-default, so every
    existing plan file and cache entry keeps its exact bytes."""
    d = decision_to_json(_decision(OPS["linear"]))
    assert "tile" not in d
    back = decision_from_json(d)
    assert back.tile is None
    assert decision_to_json(back) == d


def test_tiled_decision_roundtrips_and_validates():
    spec = registry.tile_spec("linear")
    tile = spec.config(bm=8, bn=256, bk=256)
    d = decision_to_json(_decision(OPS["linear"], tile))
    assert d["tile"] == {"bm": 8, "bn": 256, "bk": 256}
    assert decision_from_json(d).tile == tile
    with pytest.raises(ValueError, match="exceeds the padded"):
        decision_to_json(_decision(
            OPS["linear"], spec.config(bm=256, bn=512, bk=256)))


def test_tune_provenance_is_byte_compatible():
    base = PlanProvenance(device="moto2022", threads=3, mechanism="spin",
                          step=8, seed=0, network_fingerprint="f" * 8,
                          predictor_checksum="p" * 8)
    assert "tune" not in base.to_json()
    assert dataclasses.replace(base, tune="").key == base.key
    tagged = dataclasses.replace(base, tune=tune_cache_version())
    assert tagged.key != base.key
    assert tagged.to_json()["tune"] == tune_cache_version()
    assert PlanProvenance.from_json(tagged.to_json()) == tagged


def test_exec_spec_equality_and_label_carry_tile():
    tile = registry.tile_spec("linear").config(bm=8, bn=256, bk=256)
    plain = decision_to_spec(_decision(OPS["linear"]), "n0")
    tiled = decision_to_spec(_decision(OPS["linear"], tile), "n0")
    assert plain != tiled                 # a retuned tile is a new program
    assert "tile[" not in spec_label(plain)
    assert f"tile[{tile.label()}]" in spec_label(tiled)


def test_predictor_checksum_tile_tag():
    class Fake:
        device, backend, whitebox = "d", "cpu", True
        models = {}
    blind = Fake()
    aware = Fake()
    aware.tiles = True
    legacy = predictor_checksum(blind)
    blind.tiles = False                   # explicit False == pre-field
    assert predictor_checksum(blind) == legacy
    assert predictor_checksum(aware) != legacy


# ------------------------------------------- tile-aware predictor feats

def test_tile_features_and_tile_aware_training():
    from repro.core.predictor.features import (feature_names,
                                               tile_feature_names,
                                               tile_features)
    from repro.core.predictor.train import train_predictor
    assert tile_feature_names("linear") == ["tile_bm", "tile_bn", "tile_bk"]
    ops = [OPS["linear"], LinearOp(L=64, C_in=128, C_out=128)]
    feats = tile_features(ops)            # None -> clamped defaults
    d0 = registry.default_tile(ops[0])
    assert list(feats[0]) == [float(v) for _, v in d0.values]
    assert feature_names("linear", True, tiles=True)[-3:] == \
        tile_feature_names("linear")
    train = [LinearOp(L=8 * i, C_in=128, C_out=128)
             for i in range(1, 13)]
    p = train_predictor(train, "moto2022", "cpu", tiles=True)
    assert p.tile_aware
    tiles = [registry.default_tile(op) for op in ops]
    got = p.predict(ops, tiles)
    assert got.shape == (2,) and np.all(np.isfinite(got))
    blind = train_predictor(train, "moto2022", "cpu")
    assert not blind.tile_aware           # and pre-field unpickles too
    assert predictor_checksum(p) != predictor_checksum(blind)


# -------------------------------------------------- annotate + compile

def _patch_fixed_winner(monkeypatch, op, winner):
    def fake_measure(op_, tile, **kw):
        return 10.0 if registry.resolve_tile(op_, tile) == winner else 90.0
    import repro.runtime.autotune as at
    monkeypatch.setattr(at, "measure_tile_us", fake_measure)


def test_compile_tune_true_threads_tiles_and_new_cache_key(
        tmp_path, monkeypatch):
    import repro
    op = OPS["linear"]
    spec = registry.tile_spec("linear")
    winner = spec.config(bm=8, bn=256, bk=256)
    _patch_fixed_winner(monkeypatch, op, winner)
    target = repro.Target(device="moto2022", threads=3)
    kw = dict(cache=tmp_path / "plans", samples=60, estimators=8,
              predictor_cache=tmp_path / "pred")
    base = repro.compile([op] * 2, target, **kw)
    tuned = repro.compile([op] * 2, target, tune=True,
                          tune_cache=tmp_path / "tune", **kw)
    assert base.key != tuned.key
    assert base.provenance.tune == ""
    assert tuned.provenance.tune == tune_cache_version()
    tiles = [d.tile for d in tuned.decisions]
    assert tiles and all(t == winner for t in tiles)
    assert all(d.tile is None for d in base.decisions)
    assert f"tile[{winner.label()}]" in tuned.explain()
    assert f"tune={tune_cache_version()}" in tuned.explain()
    # warm recompile: plan-cache hit, tiles survive the JSON roundtrip
    monkeypatch.setattr("repro.runtime.autotune.measure_tile_us",
                        lambda *a, **k: pytest.fail("tuned on warm hit"))
    warm = repro.compile([op] * 2, target, tune=True,
                         tune_cache=tmp_path / "tune", **kw)
    assert warm.from_cache and warm.key == tuned.key
    assert [d.tile for d in warm.decisions] == tiles


def test_all_default_tune_keeps_plan_json_identical(tmp_path, monkeypatch):
    """When every op tunes to its default, the tuned plan differs from the
    untuned one only by the provenance tune tag — no tile keys leak."""
    import repro
    op = OPS["linear"]
    _patch_fixed_winner(monkeypatch, op,
                        registry.tile_spec("linear").default_config(op))
    target = repro.Target(device="moto2022", threads=3)
    kw = dict(cache=tmp_path / "plans", samples=60, estimators=8,
              predictor_cache=tmp_path / "pred")
    base = repro.compile([op], target, **kw)
    tuned = repro.compile([op], target, tune=True,
                          tune_cache=tmp_path / "tune", **kw)
    a = json.loads(base.plan.dumps())
    b = json.loads(tuned.plan.dumps())
    assert b["provenance"].pop("tune") == tune_cache_version()
    a["provenance"].pop("key", None), b["provenance"].pop("key", None)
    assert a == b
    assert '"tile"' not in tuned.plan.dumps()


def test_annotate_plan_tiles_dedups_ops(monkeypatch):
    calls = []
    op = OPS["linear"]
    spec = registry.tile_spec("linear")
    winner = spec.config(bm=8, bn=256, bk=256)

    def fake_autotune(op_, **kw):
        calls.append(op_)
        return winner

    import repro.runtime.autotune as at
    monkeypatch.setattr(at, "autotune", fake_autotune)
    schedule = [{"decision": decision_to_json(_decision(op))}
                for _ in range(3)]
    plan = type("P", (), {"schedule": schedule})()
    annotate_plan_tiles(plan, device="host", backend="cpu")
    assert len(calls) == 1                # tuned once, applied thrice
    for entry in schedule:
        assert entry["decision"]["tile"] == registry.tile_to_json(winner)


# -------------------------------- split lowerings accept tuned tiles

_SPLIT_TILE_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    import jax.numpy as jnp
    from repro.core.coexec import coexec_mesh
    from repro.core.types import AttnOp, SSMOp
    from repro.kernels import registry

    mesh = coexec_mesh(jax.devices())
    rng = np.random.default_rng(11)

    def unit_io(op):
        ent = registry.entry_for(op)
        x = jnp.asarray(rng.standard_normal(ent.input_shape(op)),
                        jnp.float32)
        w = jnp.asarray(ent.init_weight(op, rng), jnp.float32)
        return ent, x, w

    # Split lowerings accept the tuned tile and stay bit-identical to the
    # unsplit oracle (their shard_map math is tile-independent); a
    # different tile must compile a DISTINCT cached program — a retuned
    # plan can never silently alias a stale jitted program.
    from repro.core import coexec

    attn = AttnOp(H=8, S=256, KV=4, hd=16)
    tile = registry.tile_spec("attention").config(bs=128)
    ent, x, w = unit_io(attn)
    ref = np.asarray(ent.lowering.oracle(x, w, attn))
    low = registry.get_split_lowering("attention", "head")
    split, packed = low.pack(w, attn, 4, mesh)
    y0 = np.asarray(low.run(x, packed, split, mesh, attn, 4))
    n_after_default = len(coexec._PROGRAM_CACHE)
    y1 = np.asarray(low.run(x, packed, split, mesh, attn, 4, tile=tile))
    assert len(coexec._PROGRAM_CACHE) == n_after_default + 1
    assert y0.tobytes() == ref.tobytes()
    assert y1.tobytes() == ref.tobytes()
    print("HEAD_TILE_OK")

    ssm = SSMOp(T=64, H=8, hd=8, N=16)
    tile = registry.tile_spec("ssm").config(chunk=32)
    ent, x, w = unit_io(ssm)
    ref = np.asarray(ent.lowering.oracle(x, w, ssm))
    low = registry.get_split_lowering("ssm", "ssm-state")
    split, packed = low.pack(w, ssm, 4, mesh)
    y0 = np.asarray(low.run(x, packed, split, mesh, ssm, 4))
    n_after_default = len(coexec._PROGRAM_CACHE)
    y1 = np.asarray(low.run(x, packed, split, mesh, ssm, 4, tile=tile))
    assert len(coexec._PROGRAM_CACHE) == n_after_default + 1
    assert y0.tobytes() == ref.tobytes()
    assert y1.tobytes() == ref.tobytes()
    print("SSM_TILE_OK")
""")


def test_split_lowerings_accept_tuned_tiles_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SPLIT_TILE_PROG], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "HEAD_TILE_OK" in out.stdout, out.stdout[-2000:]
    assert "SSM_TILE_OK" in out.stdout, out.stdout[-2000:]
