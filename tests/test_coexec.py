"""Tests for the TPU-native co-execution layer (core/coexec.py).

The shard_map path needs >1 device, so it runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (kept out of this process
on purpose — see conftest.py).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:      # graceful fallback, see hypothesis_fallback
    from hypothesis_fallback import given, settings, st

from repro.core.coexec import (SplitPlan, coexec_mesh, mesh_groups,
                               throughput_split)


@settings(max_examples=50, deadline=None)
@given(c_out=st.integers(8, 8192),
       share=st.floats(0.0, 1.0),
       align=st.sampled_from([4, 8, 16]))
def test_throughput_split_invariants(c_out, share, align):
    plan = throughput_split(c_out, share, align=align)
    assert plan.c_fast + plan.c_slow == c_out
    assert 0 <= plan.c_fast <= c_out
    assert plan.c_pad >= max(plan.c_fast, plan.c_slow)
    assert plan.c_pad % align == 0


def test_split_plan_pad_is_minimal():
    p = SplitPlan(c_out=100, c_fast=60, align=8)
    assert p.c_pad == 64        # ceil(60/8)*8


def test_coexec_mesh_degrades_to_single_group_on_one_device():
    """Satellite: <2 devices used to crash on reshape(2, 0); now the mesh
    collapses to one group (the executor then runs everything exclusive).
    This process sees the real single-device CPU platform (conftest)."""
    import jax

    mesh = coexec_mesh()
    assert mesh_groups(mesh) == 1
    assert mesh.devices.shape == (1, len(jax.devices()))
    with pytest.raises(ValueError):
        coexec_mesh([])


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.coexec import (coexec_matmul, coexec_mesh, pack_weights,
                                   throughput_split, coexec_linear_ref)
    assert len(jax.devices()) == 8
    mesh = coexec_mesh()
    rng = np.random.default_rng(0)
    for c_out, share in [(96, 0.5), (200, 0.8), (513, 0.3), (64, 1.0),
                         (64, 0.0)]:
        x = jnp.asarray(rng.normal(size=(17, 40)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(40, c_out)), jnp.float32)
        plan = throughput_split(c_out, share)
        y = coexec_matmul(x, pack_weights(w, plan), plan, mesh)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(coexec_linear_ref(x, w)),
                                   rtol=2e-5, atol=2e-5)
    print("COEXEC_OK")
""")


def test_coexec_matmul_matches_reference_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "COEXEC_OK" in out.stdout
