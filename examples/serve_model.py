"""End-to-end serving driver (deliverable b): serve a reduced gemma3-12b
with batched requests through the prefill+decode engine.

    PYTHONPATH=src python examples/serve_model.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import time                                             # noqa: E402

import jax                                              # noqa: E402
import numpy as np                                      # noqa: E402

from repro.models import build_model, get_config        # noqa: E402
from repro.serving import Request, ServingEngine        # noqa: E402


def main():
    cfg = get_config("gemma3_12b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, model, params, max_batch=4, max_len=96)

    rng = np.random.default_rng(0)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(4, 24)
                                    ).astype(np.int32),
                max_new_tokens=16,
                temperature=0.7 if i % 2 else 0.0)
        for i in range(8)
    ]
    t0 = time.time()
    completions = engine.run(requests)
    dt = time.time() - t0
    for c in completions:
        print(f"request {c.rid}: generated {len(c.tokens)} tokens "
              f"{c.tokens[:8]}...")
    toks = sum(len(c.tokens) for c in completions)
    print(f"\n{toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, reduced gemma3 on host CPU)")


if __name__ == "__main__":
    main()
