"""Quickstart: partition the paper's ViT-Base-32 running-example layer.

Trains the latency predictors for a Pixel 5, partitions the (50,768)x
(768,3072) linear layer between GPU and 3 CPU threads, and compares the
predictor-driven decision against exhaustive grid search — reproducing the
Section 3.2 walk-through.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

from repro.core import (LinearOp, grid_search_partition,    # noqa: E402
                        optimal_partition, speedup_vs_gpu)
from repro.core.predictor import (sample_linear_ops,        # noqa: E402
                                  train_predictor)


def main():
    device, threads = "pixel5", 3
    print(f"== device={device}, {threads} CPU threads ==")
    print("training latency predictors (GBDT, white-box features)...")
    train = sample_linear_ops(2500, seed=1)
    gpu_pred = train_predictor(train, device, "gpu", whitebox=True)
    cpu_pred = train_predictor(train, device, f"cpu{threads}",
                               whitebox=False)

    op = LinearOp(L=50, C_in=768, C_out=3072)   # ViT-Base-32 MLP up-proj
    dec = optimal_partition(op, cpu_pred, gpu_pred)
    print(f"\npredictor decision: {dec.c_gpu} channels -> GPU, "
          f"{dec.c_cpu} -> CPU")
    print(f"predicted times: gpu {dec.pred_gpu_us:.0f}us "
          f"cpu {dec.pred_cpu_us:.0f}us total {dec.pred_total_us:.0f}us")
    s = speedup_vs_gpu(dec, device, threads)
    print(f"measured speedup vs GPU-only: {s:.2f}x")

    grid = grid_search_partition(op, device, threads)
    sg = speedup_vs_gpu(grid, device, threads)
    print(f"\ngrid-search oracle: {grid.c_gpu}/{grid.c_cpu} -> {sg:.2f}x")
    print(f"predictor achieves {s/sg*100:.0f}% of the oracle speedup "
          f"(paper: 1.89x vs 2.01x on Pixel 5)")


if __name__ == "__main__":
    main()
