"""End-to-end training driver (deliverable b): train a ~100M-param dense
model for a few hundred steps on the synthetic pipeline and verify the
loss decreases.

    PYTHONPATH=src python examples/train_small.py [--steps 200]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax                                               # noqa: E402
import jax.numpy as jnp                                  # noqa: E402
import numpy as np                                       # noqa: E402

import dataclasses                                       # noqa: E402

from repro.data import DataConfig, SyntheticTokenStream  # noqa: E402
from repro.launch.steps import make_train_step           # noqa: E402
from repro.models import build_model, get_config         # noqa: E402
from repro.models.config import ModelConfig              # noqa: E402
from repro.optim import AdamWConfig, init_adamw          # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    # ~100M params: 12 layers, d=768, qwen-style GQA
    cfg = dataclasses.replace(
        get_config("codeqwen15_7b"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
        d_ff=2048, vocab_size=32000)
    print(f"params: {cfg.param_count()/1e6:.0f}M")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    opt_cfg = AdamWConfig(lr=6e-4, total_steps=args.steps,
                          warmup_steps=20)
    train = jax.jit(make_train_step(model, opt_cfg), donate_argnums=(0, 1))
    opt_state = init_adamw(params)
    stream = iter(SyntheticTokenStream(cfg.vocab_size,
                                       DataConfig(args.batch, args.seq,
                                                  seed=0)))
    losses = []
    t0 = time.time()
    for step in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(stream).items()}
        params, opt_state, loss = train(params, opt_state, batch)
        losses.append(float(loss))
        if step % 20 == 0:
            print(f"step {step:4d} loss {losses[-1]:.4f}")
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({(time.time()-t0)/args.steps:.2f}s/step)")
    assert last < first, "training did not reduce the loss"
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
