"""End-to-end network co-execution planning (paper Table 3) + the
TPU-native channel-split demo.

Part 1: compile ResNet-18 for GPU + 3 CPU threads on the Moto 2022 model
        through the `repro.compile` facade, then EXECUTE the compiled
        network (on this single-device host the mesh degrades to one
        group and ops run unsplit; the fidelity summary still pairs
        executed wall time with the plan's predictions per op).
Part 2: run an actual uneven channel-split matmul across two device groups
        via shard_map (subprocess with 8 virtual devices).

    PYTHONPATH=src python examples/coexec_e2e.py
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import repro                                               # noqa: E402
from repro.core.predictor import (sample_conv_ops,         # noqa: E402
                                  sample_linear_ops, train_predictor)
from repro.core.predictor.train import MuxPredictor        # noqa: E402


def part1():
    dev, threads = "moto2022", 3
    print("== Part 1: ResNet-18 end-to-end partition plan ==")
    lt = sample_linear_ops(1500, seed=1)
    ct = sample_conv_ops(2000, seed=1)
    gp = MuxPredictor(train_predictor(lt, dev, "gpu", whitebox=True),
                      train_predictor(ct, dev, "gpu", whitebox=True))
    cp = MuxPredictor(
        train_predictor(lt, dev, f"cpu{threads}", whitebox=False),
        train_predictor(ct, dev, f"cpu{threads}", whitebox=False))
    compiled = repro.compile("resnet18",
                             repro.Target(device=dev, threads=threads),
                             predictors=(cp, gp),
                             cache=ROOT / "reports" / "plans")
    r = compiled.report()
    print(f"plan cache {'HIT' if compiled.from_cache else 'MISS (compiled)'}"
          f" (key {compiled.key})")
    print(f"baseline (GPU only): {r.baseline_us/1e3:.1f} ms")
    print(f"co-exec individual:  {r.individual_us/1e3:.1f} ms "
          f"({r.individual_speedup:.2f}x)")
    print(f"co-exec end-to-end:  {r.end_to_end_us/1e3:.1f} ms "
          f"({r.end_to_end_speedup:.2f}x; paper: 1.11x on Moto 2022)")
    co = sum(1 for d in r.decisions if not d.exclusive)
    print(f"{co}/{len(r.decisions)} ops co-executed")

    y = compiled.run()
    print(f"executed plan -> output {tuple(y.shape)}")
    print(compiled.last_report.fidelity_summary())


_PART2 = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.coexec import (coexec_matmul, coexec_mesh, pack_weights,
                                   throughput_split)
    mesh = coexec_mesh()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(50, 768)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(768, 3072)), jnp.float32)
    # group 0 is 4x faster than group 1 -> it takes ~80% of the channels
    plan = throughput_split(3072, fast_share=0.8)
    y = coexec_matmul(x, pack_weights(w, plan), plan, mesh)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               rtol=2e-4, atol=2e-4)
    print(f"channel split: {plan.c_fast} fast-group / {plan.c_slow} "
          f"slow-group channels (padded to {plan.c_pad}) -- results match")
""")


def part2():
    print("\n== Part 2: shard_map channel-split matmul (8 virt devices) ==")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run([sys.executable, "-c", _PART2], env=env,
                         capture_output=True, text=True, timeout=300)
    print(out.stdout.strip() or out.stderr[-800:])


if __name__ == "__main__":
    part1()
    part2()
