from repro.kernels.split_matmul.ops import split_matmul_op
from repro.kernels.split_matmul.ref import split_matmul_ref
from repro.kernels.split_matmul.split_matmul import split_matmul

__all__ = ["split_matmul", "split_matmul_op", "split_matmul_ref"]
