"""Pallas TPU kernel: channel-partitioned matmul (the co-execution primitive).

Computes Y = X @ W[:, c0 : c0 + width] — one compute group's share of a
channel-split linear layer (paper Section 2, Fig. 4) — as a blocked MXU
matmul with explicit VMEM tiling.

TPU adaptation of the paper's workgroup story: the BlockSpec (bm, bn, bk)
plays the role of the OpenCL workgroup shape; N-padding of the channel
slice to bn is the tile-quantization analogue of the delegate's float4
slicing, and is exactly the discontinuity the white-box predictor features
expose (DESIGN.md §2B).

Grid: (M/bm, W/bn, K/bk) with a VMEM fp32 accumulator; the K grid dimension
is innermost and accumulating.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import check_tile as _check_tile


def _split_matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def split_matmul(x: jax.Array, w: jax.Array, c0: int, width: int, *,
                 bm: int = None, bn: int = None, bk: int = None,
                 interpret: bool = False) -> jax.Array:
    """Y = X @ W[:, c0:c0+width] via a blocked Pallas kernel.

    x: (M, K); w: (K, N).  c0/width are static Python ints (the
    partitioner's decision is made offline).  Returns (M, width).

    Tile params left as None take the default blocking clamped to the
    problem extents; explicitly requested tiles must already be legal
    (aligned and within the padded extents) or ValueError is raised —
    clamping lives in registry.TileSpec.clamp_tile, not here.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and 0 <= c0 and c0 + width <= n
    assert width > 0

    bm = _check_tile("bm", bm, 128, m, 8)
    bn = _check_tile("bn", bn, 128, width, 128)
    bk = _check_tile("bk", bk, 512, k, 128)

    # slice this group's channels; pad all dims to block multiples
    w_slice = jax.lax.slice(w, (0, c0), (k, c0 + width))
    m_pad, k_pad, n_pad = (-m) % bm, (-k) % bk, (-width) % bn
    if m_pad or k_pad:
        x = jnp.pad(x, ((0, m_pad), (0, k_pad)))
    if k_pad or n_pad:
        w_slice = jnp.pad(w_slice, ((0, k_pad), (0, n_pad)))
    mp, kp = x.shape
    np_ = w_slice.shape[1]
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_split_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_slice)
    return out[:m, :width]


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult
