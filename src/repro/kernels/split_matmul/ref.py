"""Pure-jnp oracle for the split_matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def split_matmul_ref(x: jax.Array, w: jax.Array, c0: int,
                     width: int) -> jax.Array:
    return x @ jax.lax.slice(w, (0, c0), (w.shape[0], c0 + width))
