"""Jitted public wrapper for the split_matmul kernel.

On a real TPU this runs the Pallas kernel natively; in this CPU container
`interpret=True` executes the kernel body in Python for correctness
validation (tests/test_kernels.py sweeps shapes/dtypes against ref.py).

This module also registers the "linear" lowering in the shared kernel
registry (repro.kernels.registry): the plan executor dispatches linear
units here — full-width `split_matmul_op` on the Pallas path, plain
``x @ w`` as the oracle.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import registry
from repro.kernels.split_matmul.split_matmul import split_matmul
from repro.kernels.split_matmul.ref import split_matmul_ref


@functools.partial(jax.jit,
                   static_argnames=("c0", "width", "bm", "bn", "bk",
                                    "interpret", "use_kernel"))
def split_matmul_op(x, w, c0: int, width: int, *, bm: int = None,
                    bn: int = None, bk: int = None, interpret: bool = False,
                    use_kernel: bool = True):
    if not use_kernel:
        return split_matmul_ref(x, w, c0, width)
    return split_matmul(x, w, c0, width, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)


# ------------------------------------------------------- registry hookup

def _linear_pallas(x, w, op, *, interpret: bool = False, tile=None):
    if tile is None:
        return split_matmul_op(x, w, 0, op.C_out, interpret=interpret)
    v = registry.resolve_tile(op, tile).as_dict()
    return split_matmul_op(x, w, 0, op.C_out, bm=v["bm"], bn=v["bn"],
                           bk=v["bk"], interpret=interpret)


def _linear_oracle(x, w, op):
    return x @ w


registry.register_lowering("linear", pallas=_linear_pallas,
                           oracle=_linear_oracle)
