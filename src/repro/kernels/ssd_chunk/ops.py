"""Jitted wrapper for the chunked-SSD Pallas kernel, plus the registry
lowering that lets graph-IR "ssm" nodes execute through the shared
`(x, w, op)` unit contract (see kernels/registry.py)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.ssd_chunk.ref import ssd_scan_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_kernel"))
def ssd_chunk_op(x, b, c, dt, a, state0, *, chunk: int = 256,
                 interpret: bool = False, use_kernel: bool = True):
    if not use_kernel:
        return ssd_scan_ref(x, b, c, dt, a, state0)
    return ssd_chunk_scan(x, b, c, dt, a, state0, chunk=chunk,
                          interpret=interpret)


# ------------------------------------------------- registry unit lowering

def _unpack_params(w, op):
    """Slice the flat parameter vector of an SSMOp into the scan operands,
    applying the stabilizing transforms (dt bounded positive, a strictly
    negative) so a generically-initialized node never overflows the decay
    exp(dt * a).  Shared by the Pallas path and the oracle, so the two
    stay elementwise comparable."""
    t, h, hd, n = op.T, op.H, op.hd, op.N
    sizes = [t * n, t * n, t * h, h, h * hd * n]
    parts, lo = [], 0
    for s in sizes:
        parts.append(w[lo:lo + s])
        lo += s
    b = parts[0].reshape(1, t, n)
    c = parts[1].reshape(1, t, n)
    dt = 0.05 + 0.2 * jax.nn.sigmoid(parts[2].reshape(1, t, h))
    a = -(0.1 + jnp.abs(parts[3]))
    state0 = parts[4].reshape(1, h, hd, n)
    return b, c, dt, a, state0


def _unit_ssm(x, w, op, *, use_kernel: bool, interpret: bool = False):
    """`(x, w, op)` unit contract of an SSMOp node: `x` is the (T, H*hd)
    inner-projected token block, `w` the flat B/C/dt/a/state0 vector."""
    xb = x.reshape(1, op.T, op.H, op.hd)
    b, c, dt, a, state0 = _unpack_params(w, op)
    _, y = ssd_chunk_op(xb, b, c, dt, a, state0,
                        chunk=min(256, op.T), interpret=interpret,
                        use_kernel=use_kernel)
    return y.reshape(op.T, op.H * op.hd)


def ssm_unit_pallas(x, w, op, *, interpret: bool = False):
    return _unit_ssm(x, w, op, use_kernel=True, interpret=interpret)


def ssm_unit_oracle(x, w, op):
    return _unit_ssm(x, w, op, use_kernel=False)


registry.register_lowering("ssm", pallas=ssm_unit_pallas,
                           oracle=ssm_unit_oracle)
