"""Jitted wrapper for the chunked-SSD Pallas kernel, plus the registry
lowerings that let graph-IR "ssm" nodes execute through the shared
`(x, w, op)` unit contract (see kernels/registry.py) — exclusive and
state-split co-execution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.coexec import (COEXEC_AXIS, LANE_AXIS, _merge_stacked,
                               _shard_map, _stacked_spec,
                               cached_coexec_program, gather_stacked,
                               mesh_fingerprint, split_for_mesh)
from repro.kernels import registry
from repro.kernels.ssd_chunk.ref import ssd_scan_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_kernel"))
def ssd_chunk_op(x, b, c, dt, a, state0, *, chunk: int = None,
                 interpret: bool = False, use_kernel: bool = True):
    if not use_kernel:
        return ssd_scan_ref(x, b, c, dt, a, state0)
    return ssd_chunk_scan(x, b, c, dt, a, state0, chunk=chunk,
                          interpret=interpret)


# ------------------------------------------------- registry unit lowering

def _unpack_params(w, op):
    """Slice the flat parameter vector of an SSMOp into the scan operands,
    applying the stabilizing transforms (dt bounded positive, a strictly
    negative) so a generically-initialized node never overflows the decay
    exp(dt * a).  Shared by the Pallas path and the oracle, so the two
    stay elementwise comparable."""
    t, h, hd, n = op.T, op.H, op.hd, op.N
    sizes = [t * n, t * n, t * h, h, h * hd * n]
    parts, lo = [], 0
    for s in sizes:
        parts.append(w[lo:lo + s])
        lo += s
    b = parts[0].reshape(1, t, n)
    c = parts[1].reshape(1, t, n)
    dt = 0.05 + 0.2 * jax.nn.sigmoid(parts[2].reshape(1, t, h))
    a = -(0.1 + jnp.abs(parts[3]))
    state0 = parts[4].reshape(1, h, hd, n)
    return b, c, dt, a, state0


def _unit_ssm(x, w, op, *, use_kernel: bool, interpret: bool = False,
              tile=None):
    """`(x, w, op)` unit contract of an SSMOp node: `x` is the (T, H*hd)
    inner-projected token block, `w` the flat B/C/dt/a/state0 vector."""
    xb = x.reshape(1, op.T, op.H, op.hd)
    b, c, dt, a, state0 = _unpack_params(w, op)
    # the tile-less default keeps the historical min(256, T) chunk so
    # untuned plans stay bit-identical with pre-tile builds
    chunk = (min(256, op.T) if tile is None
             else registry.resolve_tile(op, tile).get("chunk"))
    _, y = ssd_chunk_op(xb, b, c, dt, a, state0,
                        chunk=chunk, interpret=interpret,
                        use_kernel=use_kernel)
    return y.reshape(op.T, op.H * op.hd)


def ssm_unit_pallas(x, w, op, *, interpret: bool = False, tile=None):
    return _unit_ssm(x, w, op, use_kernel=True, interpret=interpret,
                     tile=tile)


def ssm_unit_oracle(x, w, op):
    return _unit_ssm(x, w, op, use_kernel=False)


registry.register_lowering("ssm", pallas=ssm_unit_pallas,
                           oracle=ssm_unit_oracle)


# ----------------------------------------------- state-split co-execution
#
# The SSD scan is independent per state head: B/C projections are shared,
# but dt, a, and the state tensor slice head-wise, and head h owns output
# channels [h*hd, (h+1)*hd) — a contiguous range, so the channel-split
# gather/chaining machinery applies unchanged and the split is
# bit-identical to the unsplit oracle.

def pack_state_split(w, op, n_fast, mesh):
    """Flat B/C/dt/a/state0 vector -> (split, (2, L_pad)): per-side flat
    parameter vectors with H replaced by the padded per-side head count.
    B and C are shared, so they replicate into both sides.

    Every nonlinearity is applied HERE, eagerly: the stabilizing
    transforms (`_unpack_params`, like the unsplit oracle path) AND the
    decay `exp(dt * a)` the scan consumes.  Inside the jitted SPMD
    program the GSPMD partitioner's fusion choices can round composite
    nonlinear chains differently than the oracle's program, and the
    recurrence amplifies a 1-ulp decay difference — so the traced side
    carries only mul/add/einsum over pre-transformed values.  Padded head
    slots hold zeros (decay 0, dt 0, state0 0 -> zero outputs past the
    valid channel range)."""
    registry.validate_axis_split(op, "ssm-state", n_fast)
    t, h, hd, n = op.T, op.H, op.hd, op.N
    h_pad = max(n_fast, h - n_fast)
    b, c, dt, a, state0 = _unpack_params(w, op)
    decay = jnp.exp(dt * a)                      # (1, t, h), eager

    def side(lo, m):
        dt_s = jnp.zeros((t, h_pad), dt.dtype).at[:, :m].set(
            dt[0, :, lo:lo + m])
        dec_s = jnp.zeros((t, h_pad), decay.dtype).at[:, :m].set(
            decay[0, :, lo:lo + m])
        s0_s = jnp.zeros((h_pad, hd, n), state0.dtype).at[:m].set(
            state0[0, lo:lo + m])
        return jnp.concatenate([b.reshape(-1), c.reshape(-1),
                                dt_s.reshape(-1), dec_s.reshape(-1),
                                s0_s.reshape(-1)])

    packed = jnp.stack([side(0, n_fast), side(n_fast, h - n_fast)])
    packed = jax.device_put(                     # consumption sharding
        packed, NamedSharding(mesh, P(COEXEC_AXIS, None)))
    split = split_for_mesh(h * hd, n_fast * hd, mesh)
    return split, packed


def _unpack_packed_side(w_side, op, h_pad):
    """Positional unpack of one side of `pack_state_split`'s layout —
    values are already transformed, so no nonlinearities here."""
    t, hd, n = op.T, op.hd, op.N
    sizes = [t * n, t * n, t * h_pad, t * h_pad, h_pad * hd * n]
    parts, lo = [], 0
    for s in sizes:
        parts.append(w_side[lo:lo + s])
        lo += s
    return (parts[0].reshape(1, t, n), parts[1].reshape(1, t, n),
            parts[2].reshape(1, t, h_pad), parts[3].reshape(1, t, h_pad),
            parts[4].reshape(1, h_pad, hd, n))


def _ssd_scan_decay(x, b, c, dt, decay, state0):
    """`ssd_scan_ref` with the decay factor passed in precomputed —
    the scan body `ssd_scan_ref` runs, minus its leading `exp`."""

    def step(s, inp):
        x_t, b_t, c_t, dec_t, dt_t = inp
        upd = dt_t[..., None, None] * (x_t[..., :, None]
                                       * b_t[:, None, None, :])
        s = dec_t[..., None, None] * s + upd
        return s, jnp.einsum("bhdn,bn->bhd", s, c_t)

    seq = (x.swapaxes(0, 1), b.swapaxes(0, 1), c.swapaxes(0, 1),
           decay.swapaxes(0, 1), dt.swapaxes(0, 1))
    sf, ys = jax.lax.scan(step, state0, seq)
    return sf, ys.swapaxes(0, 1)


def run_state_split(x, packed, split, mesh, op, n_fast, *, gather=True,
                    x_plan=None, use_pallas=False, interpret=False,
                    tile=None):
    """State-split SSD scan over the two-group mesh.

    x: (T, H*hd) replicated token block — or, with `x_plan`, a producer's
    group-local (2, T, c_pad) stack.  Returns (T, H*hd) if gather else the
    group-local (2, T, c_pad) stack.  Numerics are mode-independent
    (`op.mode` picks chunked vs recurrent latency, not different math).
    """
    t, h, hd = op.T, op.H, op.hd
    h_pad = max(n_fast, h - n_fast)
    c_loc = split.c_pad // int(mesh.shape[LANE_AXIS])

    def build():
        def local(x_l, w_l):
            x_full = (_merge_stacked(x_l, x_plan) if x_plan is not None
                      else x_l)
            xb = x_full.reshape(t, h, hd)

            def pad_x(sl):
                return jnp.zeros((t, h_pad, hd), x_full.dtype).at[
                    :, :sl.shape[1]].set(sl)

            first = jax.lax.axis_index(COEXEC_AXIS) == 0
            # padded heads see zero inputs and zero initial state -> zero
            # outputs past each side's valid channel range, sliced below
            x_side = jnp.where(first, pad_x(xb[:, :n_fast]),
                               pad_x(xb[:, n_fast:]))
            b, c, dt, decay, state0 = _unpack_packed_side(w_l[0], op, h_pad)
            _, y = _ssd_scan_decay(x_side[None], b, c, dt, decay, state0)
            y2 = y[0].reshape(t, h_pad * hd)
            out = jnp.zeros((t, split.c_pad), y2.dtype).at[
                :, :h_pad * hd].set(y2)
            # each device computed the whole side; emit this lane's
            # channel shard so the global stack is the canonical
            # (2, T, c_pad) layout
            lane = jax.lax.axis_index(LANE_AXIS)
            out = jax.lax.dynamic_slice_in_dim(out, lane * c_loc, c_loc,
                                               axis=-1)
            return out[None]                     # (1, T, c_pad / lanes)

        x_spec = _stacked_spec(3) if x_plan is not None else P()
        kwargs = dict(mesh=mesh, in_specs=(x_spec, P(COEXEC_AXIS, None)),
                      out_specs=_stacked_spec(3))
        try:
            return _shard_map()(local, check_rep=False, **kwargs)
        except TypeError:       # jax versions without the check_rep knob
            return _shard_map()(local, **kwargs)

    key = ("ssm-state", op, n_fast, x_plan, mesh_fingerprint(mesh),
           tuple(x.shape), str(x.dtype), str(packed.dtype), tile)
    y = cached_coexec_program(key, build)(x, packed)
    if not gather:
        return y
    return gather_stacked(y, split, mesh)


registry.register_split_lowering("ssm", "ssm-state",
                                 pack=pack_state_split, run=run_state_split)
