"""Jitted wrapper for the chunked-SSD Pallas kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_chunk.ref import ssd_scan_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_scan


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_kernel"))
def ssd_chunk_op(x, b, c, dt, a, state0, *, chunk: int = 256,
                 interpret: bool = False, use_kernel: bool = True):
    if not use_kernel:
        return ssd_scan_ref(x, b, c, dt, a, state0)
    return ssd_chunk_scan(x, b, c, dt, a, state0, chunk=chunk,
                          interpret=interpret)
