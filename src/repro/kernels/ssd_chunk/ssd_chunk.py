"""Pallas TPU kernel: chunked Mamba2 SSD scan.

EXPERIMENTS.md §Perf iteration A replaced the per-timestep SSD scan with a
chunked matmul formulation (598x on the dominant memory term); this kernel
is the follow-on lever identified there: the per-chunk (L, L) decay-score
tile and the running (hd, N) state live in VMEM scratch for the whole
sequence, so HBM sees only the streaming x/B/C/dt inputs and the y output.

Grid: (B, H, T/L) — the chunk dimension is innermost and sequential; the
state carries across chunk steps in scratch (same pattern as the K loop of
split_matmul).  Per-(batch, head) working set at L=256, hd=64, N=64 is
~0.6 MB — comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import check_chunk as _check_chunk


def _ssd_chunk_kernel(a_ref, x_ref, b_ref, c_ref, dt_ref, s0_ref,
                      y_ref, sf_ref, state_ref, *, n_chunks: int, L: int):
    nc = pl.program_id(2)

    @pl.when(nc == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    a = a_ref[0, 0]                                   # scalar decay coeff
    x = x_ref[0, 0, 0].astype(jnp.float32)            # (L, hd)
    b = b_ref[0, 0, 0].astype(jnp.float32)            # (L, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)            # (L, N)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)          # (L, 1)

    logd = dt * a                                     # (L, 1), <= 0
    l = jnp.cumsum(logd, axis=0)                      # (L, 1)

    h0 = state_ref[...]                               # (hd, N)
    # inter-chunk: y_t += exp(l_t) * C_t . h0
    y_inter = jnp.exp(l) * jnp.dot(c, h0.T,
                                   preferred_element_type=jnp.float32)
    # intra-chunk: W_{tj} = (C_t.B_j) exp(l_t - l_j), j <= t
    s_cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (L, L)
    ldiff = l - l.reshape(1, L)                       # l_t - l_j
    causal = (jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
              >= jax.lax.broadcasted_iota(jnp.int32, (L, L), 1))
    w = jnp.where(causal, jnp.exp(ldiff) * s_cb, 0.0)
    xdt = x * dt                                      # (L, hd)
    y_ref[0, 0, 0] = (y_inter + jnp.dot(
        w, xdt, preferred_element_type=jnp.float32)).astype(y_ref.dtype)

    # state update: h' = exp(l_L) h0 + sum_j exp(l_L - l_j) dt_j x_j B_j^T
    decay_end = jnp.exp(l[L - 1] - l)                 # (L, 1)
    state_ref[...] = jnp.exp(l[L - 1]) * h0 + jnp.dot(
        (xdt * decay_end).T, b, preferred_element_type=jnp.float32)

    @pl.when(nc == n_chunks - 1)
    def _store():
        sf_ref[0, 0] = state_ref[...].astype(sf_ref.dtype)


def ssd_chunk_scan(x: jax.Array, b: jax.Array, c: jax.Array,
                   dt: jax.Array, a: jax.Array, state0: jax.Array, *,
                   chunk: int = None, interpret: bool = False):
    """Chunked SSD scan.

    x: (B,T,H,hd) f32; b/c: (B,T,N); dt: (B,T,H); a: (H,) negative;
    state0: (B,H,hd,N).  Returns (final_state (B,H,hd,N), y (B,T,H,hd)).
    ``chunk=None`` takes the default chunk clamped to T; an explicit chunk
    must divide T exactly and not exceed it, else ValueError (see
    kernels.tiles.check_chunk).
    """
    bsz, t, h, hd = x.shape
    n = b.shape[-1]
    L = _check_chunk("chunk", chunk, 256, t)
    nch = t // L

    # layouts: leading (B, H) program dims, chunked time
    xc = x.transpose(0, 2, 1, 3).reshape(bsz, h, nch, L, hd)
    bc = jnp.broadcast_to(b[:, None], (bsz, h, t, n)) \
        .reshape(bsz, h, nch, L, n)
    cc = jnp.broadcast_to(c[:, None], (bsz, h, t, n)) \
        .reshape(bsz, h, nch, L, n)
    dtc = dt.transpose(0, 2, 1).reshape(bsz, h, nch, L, 1)
    a2 = jnp.broadcast_to(a[None, :], (bsz, h))

    grid = (bsz, h, nch)
    y, sf = pl.pallas_call(
        functools.partial(_ssd_chunk_kernel, n_chunks=nch, L=L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (i, j)),            # a
            pl.BlockSpec((1, 1, 1, L, hd),
                         lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, n),
                         lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, n),
                         lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, 1, L, 1),
                         lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, hd, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, L, hd),
                         lambda i, j, k: (i, j, k, 0, 0)),
            pl.BlockSpec((1, 1, hd, n), lambda i, j, k: (i, j, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nch, L, hd), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, hd, n), state0.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((hd, n), jnp.float32)],
        interpret=interpret,
    )(a2, xc, bc, cc, dtc, state0)
    y = y.reshape(bsz, h, t, hd).transpose(0, 2, 1, 3)
    return sf, y
