from repro.kernels.ssd_chunk.ops import ssd_chunk_op
from repro.kernels.ssd_chunk.ref import ssd_scan_ref
from repro.kernels.ssd_chunk.ssd_chunk import ssd_chunk_scan

__all__ = ["ssd_chunk_op", "ssd_scan_ref", "ssd_chunk_scan"]
