"""Pure-jnp oracle for the chunked SSD kernel: the per-timestep scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, b, c, dt, a, state0):
    """x: (B,T,H,hd); b/c: (B,T,N); dt: (B,T,H); a: (H,); state0:
    (B,H,hd,N) -> (final_state, y)."""
    decay = jnp.exp(dt * a)

    def step(s, inp):
        x_t, b_t, c_t, dec_t, dt_t = inp
        upd = dt_t[..., None, None] * (x_t[..., :, None]
                                       * b_t[:, None, None, :])
        s = dec_t[..., None, None] * s + upd
        return s, jnp.einsum("bhdn,bn->bhd", s, c_t)

    seq = (x.swapaxes(0, 1), b.swapaxes(0, 1), c.swapaxes(0, 1),
           decay.swapaxes(0, 1), dt.swapaxes(0, 1))
    sf, ys = jax.lax.scan(step, state0, seq)
    return sf, ys.swapaxes(0, 1)
