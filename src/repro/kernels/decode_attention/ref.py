"""Pure-jnp oracle for decode attention (GQA, causal, optional window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         pos, *, window: int = 0) -> jax.Array:
    """q: (H, hd); k/v: (S, kv, hd); pos scalar. Returns (H, hd)."""
    h, hd = q.shape
    s, kv, _ = k.shape
    g = h // kv
    qg = q.reshape(kv, g, hd).astype(jnp.float32)
    kf = jnp.swapaxes(k, 0, 1).astype(jnp.float32)      # (kv, S, hd)
    vf = jnp.swapaxes(v, 0, 1).astype(jnp.float32)
    scores = jnp.einsum("hgd,hsd->hgs", qg, kf) / np.sqrt(hd)
    k_pos = jnp.arange(s)
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > pos - window
    scores = jnp.where(mask[None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hgs,hsd->hgd", probs, vf)
    return out.reshape(h, hd).astype(q.dtype)
