"""Jitted batched wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret",
                                             "use_kernel"))
def decode_attention_op(q, k, v, pos, *, window: int = 0, bs: int = 512,
                        interpret: bool = False, use_kernel: bool = True):
    """Batched decode attention.

    q: (B, H, hd); k/v: (B, S, kv, hd); pos scalar (shared write position).
    """
    if not use_kernel:
        fn = functools.partial(decode_attention_ref, window=window)
        return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)
    fn = functools.partial(decode_attention, window=window, bs=bs,
                           interpret=interpret)
    return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)
