"""Jitted batched wrapper for the decode-attention kernel, plus the
registry lowering that lets graph-IR "attention" nodes execute through the
shared `(x, w, op)` unit contract (see kernels/registry.py)."""
from __future__ import annotations

import functools

import jax

from repro.kernels import registry
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret",
                                             "use_kernel"))
def decode_attention_op(q, k, v, pos, *, window: int = 0, bs: int = 512,
                        interpret: bool = False, use_kernel: bool = True):
    """Batched decode attention.

    q: (B, H, hd); k/v: (B, S, kv, hd); pos scalar (shared write position).
    """
    if not use_kernel:
        fn = functools.partial(decode_attention_ref, window=window)
        return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)
    fn = functools.partial(decode_attention, window=window, bs=bs,
                           interpret=interpret)
    return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)


# ------------------------------------------------- registry unit lowering

def _unit_attention(x, w, op, *, use_kernel: bool, interpret: bool = False):
    """`(x, w, op)` unit contract of an AttnOp node: `x` is the flattened
    (1, H*hd) query block, `w` the stacked (2, S, KV, hd) KV cache."""
    q = x.reshape(op.H, op.hd)
    k, v = w[0], w[1]
    pos = op.S - 1                   # attend to the whole recorded cache
    if use_kernel:
        out = decode_attention_op(q[None], k[None], v[None], pos,
                                  window=op.window, bs=min(512, op.S),
                                  interpret=interpret)[0]
    else:
        out = decode_attention_ref(q, k, v, pos, window=op.window)
    return out.reshape(1, op.H * op.hd)


def attention_unit_pallas(x, w, op, *, interpret: bool = False):
    return _unit_attention(x, w, op, use_kernel=True, interpret=interpret)


def attention_unit_oracle(x, w, op):
    return _unit_attention(x, w, op, use_kernel=False)


registry.register_lowering("attention", pallas=attention_unit_pallas,
                           oracle=attention_unit_oracle)
