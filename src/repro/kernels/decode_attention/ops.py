"""Jitted batched wrapper for the decode-attention kernel, plus the
registry lowerings that let graph-IR "attention" nodes execute through the
shared `(x, w, op)` unit contract (see kernels/registry.py) — exclusive,
head-split, and kv-block-split co-execution."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.coexec import (COEXEC_AXIS, LANE_AXIS, _merge_stacked,
                               _shard_map, _stacked_spec,
                               cached_coexec_program, gather_stacked,
                               mesh_fingerprint, split_for_mesh)
from repro.kernels import registry
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("window", "bs", "interpret",
                                             "use_kernel"))
def decode_attention_op(q, k, v, pos, *, window: int = 0, bs: int = None,
                        interpret: bool = False, use_kernel: bool = True):
    """Batched decode attention.

    q: (B, H, hd); k/v: (B, S, kv, hd); pos scalar (shared write position).
    """
    if not use_kernel:
        fn = functools.partial(decode_attention_ref, window=window)
        return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)
    fn = functools.partial(decode_attention, window=window, bs=bs,
                           interpret=interpret)
    return jax.vmap(lambda qq, kk, vv: fn(qq, kk, vv, pos))(q, k, v)


# ------------------------------------------------- registry unit lowering

def _unit_attention(x, w, op, *, use_kernel: bool, interpret: bool = False,
                    tile=None):
    """`(x, w, op)` unit contract of an AttnOp node: `x` is the flattened
    (1, H*hd) query block, `w` the stacked (2, S, KV, hd) KV cache."""
    q = x.reshape(op.H, op.hd)
    k, v = w[0], w[1]
    pos = op.S - 1                   # attend to the whole recorded cache
    if use_kernel:
        # the tile-less default keeps the historical min(512, S) block so
        # untuned plans stay bit-identical with pre-tile builds
        bs = (min(512, op.S) if tile is None
              else registry.resolve_tile(op, tile).get("bs"))
        out = decode_attention_op(q[None], k[None], v[None], pos,
                                  window=op.window, bs=bs,
                                  interpret=interpret)[0]
    else:
        out = decode_attention_ref(q, k, v, pos, window=op.window)
    return out.reshape(1, op.H * op.hd)


def attention_unit_pallas(x, w, op, *, interpret: bool = False, tile=None):
    return _unit_attention(x, w, op, use_kernel=True, interpret=interpret,
                           tile=tile)


def attention_unit_oracle(x, w, op):
    return _unit_attention(x, w, op, use_kernel=False)


registry.register_lowering("attention", pallas=attention_unit_pallas,
                           oracle=attention_unit_oracle)


# ------------------------------------------------ head-split co-execution
#
# Heads are KV-major (ref.py reshapes q to (kv, g, hd)), so a split at a
# GQA-group boundary owns a *contiguous* output-channel range — exactly the
# channel-split layout coexec.py's gather/chaining machinery expects.  Each
# side attends its own KV heads over the full cache; per-head softmax is
# independent, so the split is bit-identical to the unsplit oracle.

def _head_split_sides(op, n_fast):
    g = op.H // op.KV
    kv_fast = n_fast // g
    kv_pad = max(kv_fast, op.KV - kv_fast)
    return g, kv_fast, kv_pad


def pack_head_split(w, op, n_fast, mesh):
    """(2, S, KV, hd) stacked KV cache -> (split, (2, 2, S, kv_pad, hd)):
    per-side KV-head slices, zero-padded to the wider side (SPMD uniform
    shapes) and stacked on the co-execution group axis."""
    registry.validate_axis_split(op, "head", n_fast)
    _, kv_fast, kv_pad = _head_split_sides(op, n_fast)

    def side(lo, n):
        buf = jnp.zeros((2, op.S, kv_pad, op.hd), w.dtype)
        return buf.at[:, :, :n].set(w[:, :, lo:lo + n])

    packed = jnp.stack([side(0, kv_fast), side(kv_fast, op.KV - kv_fast)])
    packed = jax.device_put(                     # consumption sharding:
        packed, NamedSharding(mesh, P(COEXEC_AXIS, None, None, None, None)))
    split = split_for_mesh(op.H * op.hd, n_fast * op.hd, mesh)
    return split, packed


def run_head_split(x, packed, split, mesh, op, n_fast, *, gather=True,
                   x_plan=None, use_pallas=False, interpret=False,
                   tile=None):
    """Head-split decode attention over the two-group mesh.

    x: (1, H*hd) replicated query block — or, with `x_plan`, a producer's
    group-local (2, 1, c_pad) stack (chained input, gather elided).
    Returns (1, H*hd) if gather else the group-local (2, 1, c_pad) stack.
    Numerics are mode-independent (`op.mode` picks a latency profile, not
    a different math), so the oracle math serves both modes.
    """
    g, _, kv_pad = _head_split_sides(op, n_fast)
    h_pad = kv_pad * g
    pos = op.S - 1
    c_loc = split.c_pad // int(mesh.shape[LANE_AXIS])

    def build():
        def local(x_l, w_l):
            x_full = (_merge_stacked(x_l, x_plan) if x_plan is not None
                      else x_l)
            q = x_full.reshape(op.H, op.hd)

            def pad_q(qs):
                return jnp.zeros((h_pad, op.hd),
                                 q.dtype).at[:qs.shape[0]].set(qs)

            first = jax.lax.axis_index(COEXEC_AXIS) == 0
            # padded q heads hit zero-padded KV heads -> zero outputs,
            # which sit past each side's valid channel range and are
            # sliced off
            q_side = jnp.where(first, pad_q(q[:n_fast]), pad_q(q[n_fast:]))
            k_l, v_l = w_l[0][0], w_l[0][1]      # (S, kv_pad, hd) each
            out = decode_attention_ref(q_side, k_l, v_l, pos,
                                       window=op.window)
            y = out.reshape(1, h_pad * op.hd)
            y = jnp.zeros((1, split.c_pad),
                          y.dtype).at[:, :h_pad * op.hd].set(y)
            # each device computed the whole side; emit this lane's
            # channel shard so the global stack is the canonical
            # (2, 1, c_pad) layout
            lane = jax.lax.axis_index(LANE_AXIS)
            y = jax.lax.dynamic_slice_in_dim(y, lane * c_loc, c_loc,
                                             axis=-1)
            return y[None]                       # (1, 1, c_pad / lanes)

        x_spec = _stacked_spec(3) if x_plan is not None else P()
        kwargs = dict(mesh=mesh,
                      in_specs=(x_spec,
                                P(COEXEC_AXIS, None, None, None, None)),
                      out_specs=_stacked_spec(3))
        try:
            return _shard_map()(local, check_rep=False, **kwargs)
        except TypeError:       # jax versions without the check_rep knob
            return _shard_map()(local, **kwargs)

    key = ("attn-head", op, n_fast, x_plan, mesh_fingerprint(mesh),
           tuple(x.shape), str(x.dtype), str(packed.dtype), tile)
    y = cached_coexec_program(key, build)(x, packed)
    if not gather:
        return y
    return gather_stacked(y, split, mesh)


registry.register_split_lowering("attention", "head",
                                 pack=pack_head_split, run=run_head_split)


# -------------------------------------------- kv-block-split co-execution
#
# For long caches each side computes *all* H heads over its slice of cache
# positions, producing flash-style softmax partials (running max m, weight
# sum l, unnormalized output o) that merge inside the program via an
# all-gather over the group axis.  The merged output is always
# materialized (replicated) — this axis never chains group-local — and is
# tolerance-exact, not bit-exact (the log-sum-exp merge reassociates the
# softmax reduction), which is why the registry gates it to S >=
# KV_BLOCK_MIN_S and window == 0.

def pack_kv_block_split(w, op, n_fast, mesh):
    """(2, S, KV, hd) stacked KV cache -> (split, (2, 2, s_pad, KV, hd)):
    per-side cache-position slices, fast side owning rows [0, n_fast)."""
    registry.validate_axis_split(op, "kv-block", n_fast)
    s_pad = max(n_fast, op.S - n_fast)

    def side(lo, n):
        buf = jnp.zeros((2, s_pad, op.KV, op.hd), w.dtype)
        return buf.at[:, :n].set(w[:, lo:lo + n])

    packed = jnp.stack([side(0, n_fast), side(n_fast, op.S - n_fast)])
    packed = jax.device_put(
        packed, NamedSharding(mesh, P(COEXEC_AXIS, None, None, None, None)))
    # degenerate channel plan: both sides contribute every output channel;
    # the executor keys on the materialized (1, H*hd) result, not on it
    split = split_for_mesh(op.H * op.hd, op.H * op.hd, mesh)
    return split, packed


def run_kv_block_split(x, packed, split, mesh, op, n_fast, *, gather=True,
                       x_plan=None, use_pallas=False, interpret=False,
                       tile=None):
    """kv-block-split decode attention: returns the materialized (1, H*hd)
    output regardless of `gather` (the merge happens inside the program)."""
    s_pad = max(n_fast, op.S - n_fast)
    g = op.H // op.KV

    def build():
        return _build_kv_block_program(x_plan, mesh, op, n_fast, s_pad, g)

    key = ("attn-kv-block", op, n_fast, x_plan, mesh_fingerprint(mesh),
           tuple(x.shape), str(x.dtype), str(packed.dtype), tile)
    return cached_coexec_program(key, build)(x, packed)


def _build_kv_block_program(x_plan, mesh, op, n_fast, s_pad, g):
    def local(x_l, w_l):
        x_full = _merge_stacked(x_l, x_plan) if x_plan is not None else x_l
        q = x_full.reshape(op.KV, g, op.hd).astype(jnp.float32)
        k_l = jnp.swapaxes(w_l[0][0], 0, 1).astype(jnp.float32)
        v_l = jnp.swapaxes(w_l[0][1], 0, 1).astype(jnp.float32)
        first = jax.lax.axis_index(COEXEC_AXIS) == 0
        valid = jnp.where(first, n_fast, op.S - n_fast)
        # registry gates this axis to window == 0 and decode reads the
        # whole cache (pos == S-1), so the only mask is the side boundary
        mask = jnp.arange(s_pad) < valid
        scores = jnp.einsum("hgd,hsd->hgs", q, k_l) / jnp.sqrt(
            jnp.float32(op.hd))
        scores = jnp.where(mask[None, None, :], scores, -1e30)
        m = jnp.max(scores, axis=-1)                        # (kv, g)
        e = jnp.exp(scores - m[..., None]) * mask[None, None, :]
        l = jnp.sum(e, axis=-1)                             # (kv, g)
        o = jnp.einsum("hgs,hsd->hgd", e, v_l)              # unnormalized
        ms = jax.lax.all_gather(m, COEXEC_AXIS, axis=0)     # (2, kv, g)
        ls = jax.lax.all_gather(l, COEXEC_AXIS, axis=0)
        os_ = jax.lax.all_gather(o, COEXEC_AXIS, axis=0)
        mg = jnp.max(ms, axis=0)
        scale = jnp.exp(ms - mg[None])                      # (2, kv, g)
        den = jnp.sum(ls * scale, axis=0)
        num = jnp.sum(os_ * scale[..., None], axis=0)
        out = num / den[..., None]
        return out.reshape(1, op.H * op.hd).astype(x_full.dtype)

    x_spec = _stacked_spec(3) if x_plan is not None else P()
    kwargs = dict(mesh=mesh,
                  in_specs=(x_spec, P(COEXEC_AXIS, None, None, None, None)),
                  out_specs=P())
    try:
        return _shard_map()(local, check_rep=False, **kwargs)
    except TypeError:
        return _shard_map()(local, **kwargs)


registry.register_split_lowering("attention", "kv-block",
                                 pack=pack_kv_block_split,
                                 run=run_kv_block_split)
