"""Pallas TPU kernel: flash-style single-token decode attention.

The long-context decode workhorse (decode_32k / long_500k input shapes):
one query token attends to a KV cache of S positions without ever
materializing the (H, S) score matrix in HBM.  Online-softmax running
(max, sum, acc) state lives in VMEM scratch; the cache is streamed through
VMEM in (bs, head_dim) blocks.

Grid: (n_kv_heads, S/bs) — S innermost/sequential.  GQA is handled by
processing all `group = n_heads // n_kv_heads` query heads of one KV head
together as the row dimension of the MXU ops.

Causality/window masking is positional: positions > pos (and, for sliding
windows, <= pos - window) are masked.  `pos` arrives as a (1,1) scalar
input; the window is static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import check_tile as _check_tile

_NEG_INF = -1e30


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref,
                        acc_ref, m_ref, l_ref, *,
                        bs: int, n_s: int, window: int):
    s_idx = pl.program_id(1)

    @pl.when(s_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)              # (g, hd)
    k = k_ref[0].astype(jnp.float32)              # (bs, hd)
    v = v_ref[0].astype(jnp.float32)              # (bs, hd)
    hd = q.shape[-1]

    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) \
        * (hd ** -0.5)                             # (g, bs)

    pos = pos_ref[0, 0]
    k_pos = s_idx * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    mask = k_pos <= pos
    if window > 0:
        mask &= k_pos > pos - window
    scores = jnp.where(mask, scores, _NEG_INF)

    m_prev = m_ref[:, :1]                          # (g, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                    # (g, bs)
    l_ref[...] = l_ref[...] * alpha + p.sum(-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha \
        + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(s_idx == n_s - 1)
    def _store():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: int = 0, bs: int = None,
                     interpret: bool = False) -> jax.Array:
    """q: (n_heads, hd); k/v: (S, n_kv, hd); pos: scalar int32.

    Returns (n_heads, hd).  Single-sequence; vmap over batch in ops.py.
    ``bs=None`` takes the default cache block clamped to the lane-padded
    cache length; an explicit ``bs`` past that cap raises (see
    kernels.tiles.check_tile).
    """
    h, hd = q.shape
    s, kv, _ = k.shape
    g = h // kv
    g_pad = max(8, -(-g // 8) * 8)
    bs = _check_tile("bs", bs, 512, s, 1, lim_align=128)

    # (kv, g_pad, hd) query layout; (kv, S_pad, hd) cache layout
    qg = q.reshape(kv, g, hd)
    if g_pad != g:
        qg = jnp.pad(qg, ((0, 0), (0, g_pad - g), (0, 0)))
    kt = jnp.swapaxes(k, 0, 1)
    vt = jnp.swapaxes(v, 0, 1)
    s_pad = (-s) % bs
    if s_pad:
        # padded positions carry k_pos > pos and are masked out
        kt = jnp.pad(kt, ((0, 0), (0, s_pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, s_pad), (0, 0)))
    sp = kt.shape[1]
    grid = (kv, sp // bs)
    pos_arr = jnp.asarray(pos, jnp.int32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_decode_attn_kernel, bs=bs, n_s=grid[1],
                          window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda hh, ss: (0, 0)),
            pl.BlockSpec((1, g_pad, hd), lambda hh, ss: (hh, 0, 0)),
            pl.BlockSpec((1, bs, hd), lambda hh, ss: (hh, ss, 0)),
            pl.BlockSpec((1, bs, hd), lambda hh, ss: (hh, ss, 0)),
        ],
        out_specs=pl.BlockSpec((1, g_pad, hd), lambda hh, ss: (hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((kv, g_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g_pad, hd), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
            pltpu.VMEM((g_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, qg, kt, vt)
    return out[:, :g, :].reshape(h, hd)
