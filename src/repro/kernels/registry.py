"""Shared kernel registry: one dispatch table for planner and executor.

The planner and the execution runtime used to hold separate, drifting ideas
of what an op *kind* means: the predictors dispatched linear-vs-conv with
`isinstance` checks, the plan codec hardcoded kind strings, and the Pallas
kernels (`split_matmul`, `winograd_conv`) were wired to nothing.  This
module is the single table that maps an op kind to

  * its **shape contract** (input / weight / output shapes, weight init) —
    what `repro.runtime.executor.PlanExecutor` needs to materialize and
    chain activations,
  * its **base feature extractor** — what the latency predictors featurize
    (`core/predictor/features.py` routes through here),
  * its **lowering** — the Pallas op and the pure-jnp oracle that actually
    compute it (registered lazily by `kernels/*/ops.py` so importing the
    registry never drags in Pallas).

`op_kind(op)` is the one place the LinearOp/ConvOp distinction is made;
everything else (plan JSON codecs, MuxPredictor routing, executor
dispatch) looks the kind up here.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from repro.core.types import AttnOp, ConvOp, LinearOp, Op, SSMOp

# ------------------------------------------------------------------ kinds

#: op kind -> module that registers its lowering on import
_LOWERING_MODULES = {
    "linear": "repro.kernels.split_matmul.ops",
    "conv": "repro.kernels.winograd_conv.ops",
    "attention": "repro.kernels.decode_attention.ops",
    "ssm": "repro.kernels.ssd_chunk.ops",
}

_KIND_BY_TYPE = {LinearOp: "linear", ConvOp: "conv",
                 AttnOp: "attention", SSMOp: "ssm"}


def op_kind(op: Op) -> str:
    """The registry kind of an op — the one isinstance check in the repo."""
    try:
        return _KIND_BY_TYPE[type(op)]
    except KeyError:
        raise TypeError(f"unregistered op type {type(op).__name__}") \
            from None


# ------------------------------------------------------------- op codecs

def op_to_json(op: Op) -> Dict[str, Any]:
    """JSON codec of an op, keyed by registry kind.  Lives here (not in
    runtime/plan.py, which re-exports it) so every layer that serializes
    ops — plan schedules, measurement records — shares one leaf encoding."""
    kind = op_kind(op)
    if kind == "linear":
        return {"kind": "linear", "L": op.L, "C_in": op.C_in,
                "C_out": op.C_out}
    if kind == "conv":
        return {"kind": "conv", "H_in": op.H_in, "W_in": op.W_in,
                "C_in": op.C_in, "C_out": op.C_out, "K": op.K, "S": op.S}
    if kind == "attention":
        return {"kind": "attention", "H": op.H, "S": op.S, "KV": op.KV,
                "hd": op.hd, "window": op.window}
    return {"kind": "ssm", "T": op.T, "H": op.H, "hd": op.hd, "N": op.N}


def op_from_json(d: Dict[str, Any]) -> Op:
    if d["kind"] == "linear":
        return LinearOp(L=d["L"], C_in=d["C_in"], C_out=d["C_out"])
    if d["kind"] == "conv":
        return ConvOp(H_in=d["H_in"], W_in=d["W_in"], C_in=d["C_in"],
                      C_out=d["C_out"], K=d["K"], S=d["S"])
    if d["kind"] == "attention":
        return AttnOp(H=d["H"], S=d["S"], KV=d["KV"], hd=d["hd"],
                      window=d.get("window", 0))
    if d["kind"] == "ssm":
        return SSMOp(T=d["T"], H=d["H"], hd=d["hd"], N=d["N"])
    raise ValueError(f"unknown op kind {d['kind']!r}")


def op_label(op: Op) -> str:
    """Human-readable label of an op — the one format shared by plan
    explain tables, executor timings, and measurement records."""
    kind = op_kind(op)
    if kind == "linear":
        return f"linear {op.L}x{op.C_in}->{op.C_out}"
    if kind == "conv":
        return (f"conv {op.H_in}x{op.W_in}x{op.C_in}->{op.C_out} "
                f"K{op.K} S{op.S}")
    if kind == "attention":
        win = f" W{op.window}" if op.window else ""
        return f"attention H{op.H}/kv{op.KV} hd{op.hd} S{op.S}{win}"
    return f"ssm T{op.T} H{op.H} hd{op.hd} N{op.N}"


# ------------------------------------------------------- shape contracts

def _linear_input_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.L, op.C_in)


def _linear_weight_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.C_in, op.C_out)


def _linear_output_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.L, op.C_out)


def _conv_input_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.H_in, op.W_in, op.C_in)


def _conv_weight_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.K, op.K, op.C_in, op.C_out)


def _conv_output_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.H_out, op.W_out, op.C_out)


def _linear_base_features(op: LinearOp) -> List[float]:
    return [op.L, op.C_in, op.C_out,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _conv_base_features(op: ConvOp) -> List[float]:
    return [op.H_in, op.W_in, op.C_in, op.C_out, op.K, op.S,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _attn_input_shape(op: AttnOp) -> Tuple[int, ...]:
    return (1, op.H * op.hd)


def _attn_weight_shape(op: AttnOp) -> Tuple[int, ...]:
    return (2, op.S, op.KV, op.hd)                   # stacked K/V cache


def _attn_output_shape(op: AttnOp) -> Tuple[int, ...]:
    return (1, op.H * op.hd)


def _attn_base_features(op: AttnOp) -> List[float]:
    return [op.H, op.S, op.KV, op.hd, op.window,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _ssm_input_shape(op: SSMOp) -> Tuple[int, ...]:
    return (op.T, op.H * op.hd)


def _ssm_weight_shape(op: SSMOp) -> Tuple[int, ...]:
    # flat parameter vector: b, c (T, N) each + dt (T, H) + a (H,) +
    # state0 (H, hd, N); the lowering unpacks (see kernels/ssd_chunk/ops.py)
    return (2 * op.T * op.N + op.T * op.H + op.H + op.H * op.hd * op.N,)


def _ssm_output_shape(op: SSMOp) -> Tuple[int, ...]:
    return (op.T, op.H * op.hd)


def _ssm_base_features(op: SSMOp) -> List[float]:
    return [op.T, op.H, op.hd, op.N,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _fan_in(op: Op) -> int:
    if isinstance(op, LinearOp):
        return op.C_in
    if isinstance(op, ConvOp):
        return op.K * op.K * op.C_in
    if isinstance(op, AttnOp):
        return op.hd                    # keeps qk scores O(1) pre-softmax
    return op.N


# --------------------------------------------------------------- entries

@dataclasses.dataclass(frozen=True)
class KernelLowering:
    """How an op kind actually computes: Pallas path + jnp oracle.

    Both callables take ``(x, w, op, ...)``; the Pallas path additionally
    accepts ``interpret=`` for CPU-container validation.  Registered by the
    kernel package's ops.py (`register_lowering`), resolved lazily.
    """

    pallas: Callable[..., object]
    oracle: Callable[..., object]


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """Everything the planner and the executor need to know about a kind."""

    kind: str
    input_shape: Callable[[Op], Tuple[int, ...]]
    weight_shape: Callable[[Op], Tuple[int, ...]]
    output_shape: Callable[[Op], Tuple[int, ...]]
    base_features: Callable[[Op], List[float]]
    #: whether the partitioner may split the op's output channels across
    #: CPU and GPU (the paper's conv/linear domain); non-splittable kinds
    #: (attention, ssm) are scheduled exclusively and charged analytically
    splittable: bool = True

    def init_weight(self, op: Op, rng: np.random.Generator) -> np.ndarray:
        """Seeded fan-in-scaled weights (keeps deep chains O(1) magnitude,
        which is what lets bf16 equivalence tests use sane tolerances)."""
        shape = self.weight_shape(op)
        return (rng.standard_normal(shape) /
                np.sqrt(max(1, _fan_in(op)))).astype(np.float32)

    @property
    def lowering(self) -> KernelLowering:
        return get_lowering(self.kind)


_ENTRIES: Dict[str, KernelEntry] = {
    "linear": KernelEntry(
        kind="linear",
        input_shape=_linear_input_shape,
        weight_shape=_linear_weight_shape,
        output_shape=_linear_output_shape,
        base_features=_linear_base_features,
    ),
    "conv": KernelEntry(
        kind="conv",
        input_shape=_conv_input_shape,
        weight_shape=_conv_weight_shape,
        output_shape=_conv_output_shape,
        base_features=_conv_base_features,
    ),
    "attention": KernelEntry(
        kind="attention",
        input_shape=_attn_input_shape,
        weight_shape=_attn_weight_shape,
        output_shape=_attn_output_shape,
        base_features=_attn_base_features,
        splittable=False,
    ),
    "ssm": KernelEntry(
        kind="ssm",
        input_shape=_ssm_input_shape,
        weight_shape=_ssm_weight_shape,
        output_shape=_ssm_output_shape,
        base_features=_ssm_base_features,
        splittable=False,
    ),
}

_LOWERINGS: Dict[str, KernelLowering] = {}


def kinds() -> List[str]:
    return sorted(_ENTRIES)


def get(kind: str) -> KernelEntry:
    try:
        return _ENTRIES[kind]
    except KeyError:
        raise KeyError(f"unregistered op kind {kind!r}; "
                       f"known: {kinds()}") from None


def entry_for(op: Op) -> KernelEntry:
    return get(op_kind(op))


def is_splittable(op: Op) -> bool:
    """Whether the partitioner may channel-split this op (see KernelEntry)."""
    return entry_for(op).splittable


def register_lowering(kind: str, *, pallas: Callable, oracle: Callable
                      ) -> KernelLowering:
    """Called by kernels/*/ops.py at import time to hook its kernels in."""
    if kind not in _ENTRIES:
        raise KeyError(f"cannot register lowering for unknown kind {kind!r}")
    low = KernelLowering(pallas=pallas, oracle=oracle)
    _LOWERINGS[kind] = low
    return low


def get_lowering(kind: str) -> KernelLowering:
    """Resolve a kind's lowering, importing its kernel package on demand."""
    if kind not in _LOWERINGS:
        get(kind)                              # raise on unknown kinds
        importlib.import_module(_LOWERING_MODULES[kind])
        if kind not in _LOWERINGS:             # pragma: no cover - wiring bug
            raise RuntimeError(
                f"{_LOWERING_MODULES[kind]} did not register a lowering "
                f"for {kind!r}")
    return _LOWERINGS[kind]
