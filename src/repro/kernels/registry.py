"""Shared kernel registry: one dispatch table for planner and executor.

The planner and the execution runtime used to hold separate, drifting ideas
of what an op *kind* means: the predictors dispatched linear-vs-conv with
`isinstance` checks, the plan codec hardcoded kind strings, and the Pallas
kernels (`split_matmul`, `winograd_conv`) were wired to nothing.  This
module is the single table that maps an op kind to

  * its **shape contract** (input / weight / output shapes, weight init) —
    what `repro.runtime.executor.PlanExecutor` needs to materialize and
    chain activations,
  * its **base feature extractor** — what the latency predictors featurize
    (`core/predictor/features.py` routes through here),
  * its **lowering** — the Pallas op and the pure-jnp oracle that actually
    compute it (registered lazily by `kernels/*/ops.py` so importing the
    registry never drags in Pallas).

`op_kind(op)` is the one place the LinearOp/ConvOp distinction is made;
everything else (plan JSON codecs, MuxPredictor routing, executor
dispatch) looks the kind up here.
"""
from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Any, Callable, Dict, List, Tuple

import numpy as np

from repro.core.types import AttnOp, ConvOp, LinearOp, Op, SSMOp

# ------------------------------------------------------------------ kinds

#: op kind -> module that registers its lowering on import
_LOWERING_MODULES = {
    "linear": "repro.kernels.split_matmul.ops",
    "conv": "repro.kernels.winograd_conv.ops",
    "attention": "repro.kernels.decode_attention.ops",
    "ssm": "repro.kernels.ssd_chunk.ops",
}

_KIND_BY_TYPE = {LinearOp: "linear", ConvOp: "conv",
                 AttnOp: "attention", SSMOp: "ssm"}


def op_kind(op: Op) -> str:
    """The registry kind of an op — the one isinstance check in the repo."""
    try:
        return _KIND_BY_TYPE[type(op)]
    except KeyError:
        raise TypeError(f"unregistered op type {type(op).__name__}") \
            from None


# ------------------------------------------------------------- op codecs

def op_to_json(op: Op) -> Dict[str, Any]:
    """JSON codec of an op, keyed by registry kind.  Lives here (not in
    runtime/plan.py, which re-exports it) so every layer that serializes
    ops — plan schedules, measurement records — shares one leaf encoding."""
    kind = op_kind(op)
    if kind == "linear":
        return {"kind": "linear", "L": op.L, "C_in": op.C_in,
                "C_out": op.C_out}
    if kind == "conv":
        return {"kind": "conv", "H_in": op.H_in, "W_in": op.W_in,
                "C_in": op.C_in, "C_out": op.C_out, "K": op.K, "S": op.S}
    if kind == "attention":
        d = {"kind": "attention", "H": op.H, "S": op.S, "KV": op.KV,
             "hd": op.hd, "window": op.window}
    else:
        d = {"kind": "ssm", "T": op.T, "H": op.H, "hd": op.hd, "N": op.N}
    # mode is omitted at its default so pre-mode plan JSON stays byte-stable
    if op.mode != default_mode(kind):
        d["mode"] = op.mode
    return d


def op_from_json(d: Dict[str, Any]) -> Op:
    if d["kind"] == "linear":
        return LinearOp(L=d["L"], C_in=d["C_in"], C_out=d["C_out"])
    if d["kind"] == "conv":
        return ConvOp(H_in=d["H_in"], W_in=d["W_in"], C_in=d["C_in"],
                      C_out=d["C_out"], K=d["K"], S=d["S"])
    if d["kind"] == "attention":
        return AttnOp(H=d["H"], S=d["S"], KV=d["KV"], hd=d["hd"],
                      window=d.get("window", 0),
                      mode=d.get("mode", default_mode("attention")))
    if d["kind"] == "ssm":
        return SSMOp(T=d["T"], H=d["H"], hd=d["hd"], N=d["N"],
                     mode=d.get("mode", default_mode("ssm")))
    raise ValueError(f"unknown op kind {d['kind']!r}")


def op_label(op: Op) -> str:
    """Human-readable label of an op — the one format shared by plan
    explain tables, executor timings, and measurement records."""
    kind = op_kind(op)
    if kind == "linear":
        return f"linear {op.L}x{op.C_in}->{op.C_out}"
    if kind == "conv":
        return (f"conv {op.H_in}x{op.W_in}x{op.C_in}->{op.C_out} "
                f"K{op.K} S{op.S}")
    if kind == "attention":
        win = f" W{op.window}" if op.window else ""
        tail = "" if op.mode == default_mode(kind) else f" [{op.mode}]"
        return f"attention H{op.H}/kv{op.KV} hd{op.hd} S{op.S}{win}{tail}"
    tail = "" if op.mode == default_mode(kind) else f" [{op.mode}]"
    return f"ssm T{op.T} H{op.H} hd{op.hd} N{op.N}{tail}"


# ------------------------------------------------------- shape contracts

def _linear_input_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.L, op.C_in)


def _linear_weight_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.C_in, op.C_out)


def _linear_output_shape(op: LinearOp) -> Tuple[int, ...]:
    return (op.L, op.C_out)


def _conv_input_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.H_in, op.W_in, op.C_in)


def _conv_weight_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.K, op.K, op.C_in, op.C_out)


def _conv_output_shape(op: ConvOp) -> Tuple[int, ...]:
    return (op.H_out, op.W_out, op.C_out)


def _linear_base_features(op: LinearOp) -> List[float]:
    return [op.L, op.C_in, op.C_out,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _conv_base_features(op: ConvOp) -> List[float]:
    return [op.H_in, op.W_in, op.C_in, op.C_out, op.K, op.S,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1))]


def _attn_input_shape(op: AttnOp) -> Tuple[int, ...]:
    return (1, op.H * op.hd)


def _attn_weight_shape(op: AttnOp) -> Tuple[int, ...]:
    return (2, op.S, op.KV, op.hd)                   # stacked K/V cache


def _attn_output_shape(op: AttnOp) -> Tuple[int, ...]:
    return (1, op.H * op.hd)


def _attn_base_features(op: AttnOp) -> List[float]:
    return [op.H, op.S, op.KV, op.hd, op.window,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1)),
            float(_ATTN_MODES.index(op.mode))]


def _ssm_input_shape(op: SSMOp) -> Tuple[int, ...]:
    return (op.T, op.H * op.hd)


def _ssm_weight_shape(op: SSMOp) -> Tuple[int, ...]:
    # flat parameter vector: b, c (T, N) each + dt (T, H) + a (H,) +
    # state0 (H, hd, N); the lowering unpacks (see kernels/ssd_chunk/ops.py)
    return (2 * op.T * op.N + op.T * op.H + op.H + op.H * op.hd * op.N,)


def _ssm_output_shape(op: SSMOp) -> Tuple[int, ...]:
    return (op.T, op.H * op.hd)


def _ssm_base_features(op: SSMOp) -> List[float]:
    return [op.T, op.H, op.hd, op.N,
            math.log(max(op.flops, 1)), math.log(max(op.weight_bytes, 1)),
            float(_SSM_MODES.index(op.mode))]


def _fan_in(op: Op) -> int:
    if isinstance(op, LinearOp):
        return op.C_in
    if isinstance(op, ConvOp):
        return op.K * op.K * op.C_in
    if isinstance(op, AttnOp):
        return op.hd                    # keeps qk scores O(1) pre-softmax
    return op.N


# ------------------------------------------------------- partition axes

#: per-kind kernel modes; the first entry is the default (and the one
#: implied by mode-less plan JSON, keeping pre-mode caches byte-stable)
_ATTN_MODES = ("streaming", "materialized")
_SSM_MODES = ("chunked", "recurrent")

#: minimum cache length before a kv-block split is offered — short caches
#: stay on the bit-identical head-split/unsplit paths (the log-sum-exp
#: merge of a kv-block split is only tolerance-exact)
KV_BLOCK_MIN_S = 256

#: minimum output-channel count before the Winograd F(2x2,3x3) lowering is
#: dispatched — below this the transform overhead loses to direct conv.
#: Lives here (not in kernels/winograd_conv/ops.py) so planner availability
#: predicates and kernel dispatch share one threshold and cannot drift.
WINOGRAD_MIN_COUT = 128

#: SSM head slices must land the output-channel boundary (h * hd) on the
#: lane tile, or the stacked two-group layout can't align its halves
SSM_LANE_ALIGN = 8


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """A typed partition axis of an op kind.

    ``size`` counts the natural units along the axis (query heads, cache
    positions, state heads); splits place ``n`` units on the fast side and
    ``size - n`` on the slow side, and must be multiples of
    ``granularity`` (e.g. whole GQA groups).  ``sub`` builds the sub-op a
    side computes.  ``stackable`` axes produce contiguous output-channel
    blocks and reuse the channel-split gather/chaining machinery; a
    non-stackable axis (kv-block) merges partial results inside its own
    lowering and is always materialized.
    """

    axis: str
    size: Callable[[Op], int]
    granularity: Callable[[Op], int]
    sub: Callable[[Op, int], Op]
    stackable: bool = True
    #: output channels contributed per axis unit (stackable axes only)
    unit_channels: Callable[[Op], int] = lambda op: 0
    #: whether the axis is offered for this op at all
    available: Callable[[Op], bool] = lambda op: True


def _attn_head_axis() -> AxisSpec:
    return AxisSpec(
        axis="head",
        size=lambda op: op.H,
        granularity=lambda op: op.H // op.KV,        # whole GQA groups
        sub=lambda op, n: op.with_heads(n),
        stackable=True,
        unit_channels=lambda op: op.hd,
        available=lambda op: op.KV >= 2,             # need >=2 GQA groups
    )


def _attn_kv_block_axis() -> AxisSpec:
    return AxisSpec(
        axis="kv-block",
        size=lambda op: op.S,
        granularity=lambda op: max(16, op.S // 8),
        sub=lambda op, n: op.with_cache(n),
        stackable=False,
        # sliding-window masks depend on absolute cache positions and do
        # not slice cleanly into blocks; keep windowed ops off this axis
        available=lambda op: op.S >= KV_BLOCK_MIN_S and op.window == 0,
    )


def _ssm_state_axis() -> AxisSpec:
    return AxisSpec(
        axis="ssm-state",
        size=lambda op: op.H,
        granularity=lambda op: 1,
        sub=lambda op, n: op.with_heads(n),
        stackable=True,
        unit_channels=lambda op: op.hd,
        available=lambda op: op.H >= 2 and op.hd % SSM_LANE_ALIGN == 0,
    )


def axes_for(op: Op) -> List[AxisSpec]:
    """The partition axes offered for this specific op (availability
    predicates applied — e.g. no kv-block axis for short caches)."""
    return [a for a in entry_for(op).axes if a.available(op)]


def axis_spec(kind: str, axis: str) -> AxisSpec:
    for a in get(kind).axes:
        if a.axis == axis:
            return a
    raise KeyError(f"kind {kind!r} has no partition axis {axis!r}")


def default_mode(kind: str) -> str:
    modes = get(kind).modes
    return modes[0] if modes else ""


def validate_axis_split(op: Op, axis: str, n_fast: int) -> AxisSpec:
    """Reject splits the executor cannot lower — GQA-group-violating head
    splits, misaligned SSM state splits, out-of-range boundaries.  Raises
    ValueError; the planner's candidate enumeration and the plan codec both
    route through here so an illegal split can never reach a schedule."""
    spec = axis_spec(op_kind(op), axis)
    size = spec.size(op)
    if not 0 <= n_fast <= size:
        raise ValueError(f"{axis} split {n_fast} out of range 0..{size} "
                         f"for {op_label(op)}")
    if 0 < n_fast < size:
        if not spec.available(op):
            raise ValueError(f"axis {axis!r} unavailable for {op_label(op)}")
        g = spec.granularity(op)
        if n_fast % g:
            raise ValueError(
                f"{axis} split {n_fast} breaks granularity {g} "
                f"(GQA groups / block size) for {op_label(op)}")
        if (axis == "ssm-state" and op.hd % SSM_LANE_ALIGN):
            raise ValueError(
                f"ssm-state split needs hd % {SSM_LANE_ALIGN} == 0, "
                f"got hd={op.hd}")
    return spec


# ---------------------------------------------------------- tile configs

#: fp32 minimum (sublane, lane) tile — tile params aligned below these
#: cannot be laid out by Mosaic (see the Pallas TPU tiling rules)
TILE_SUBLANE = 8
TILE_LANE = 128

#: per-core VMEM budget a candidate's working set must fit in (bytes)
TILE_VMEM_BUDGET = 16 * 1024 * 1024

#: version of the kernels' blocking logic; folded into TuneCache digests so
#: cached tile choices are invalidated when the kernels change shape
KERNEL_TILE_VERSION = 1


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One concrete blocking choice for a kind's Pallas kernel.

    ``values`` is an ordered tuple of ``(param, value)`` pairs in the
    kind's TileSpec order — frozen and hashable so configs key
    ``cached_coexec_program`` memos and jit static arguments directly.
    """

    kind: str
    values: Tuple[Tuple[str, int], ...]

    def get(self, name: str) -> int:
        for k, v in self.values:
            if k == name:
                return v
        raise KeyError(f"tile config for {self.kind!r} has no {name!r}")

    def as_dict(self) -> Dict[str, int]:
        return dict(self.values)

    def label(self) -> str:
        return "/".join(f"{k}{v}" for k, v in self.values)


@dataclasses.dataclass(frozen=True)
class TileParam:
    """One tunable blocking parameter of a kind's kernel.

    ``extent`` names the key in :func:`tile_extents` the param blocks
    over; ``align`` is the legal multiple (sublane/lane tile).  A
    ``reduction`` param changes the accumulation grouping when varied, so
    it is pinned to its default under numerics-preserving search.  A
    ``divides`` param must divide its (clamped) extent exactly.
    """

    name: str
    extent: str
    align: int
    default: int
    candidates: Tuple[int, ...]
    reduction: bool = False
    divides: bool = False


@dataclasses.dataclass(frozen=True)
class TileSpec:
    """The legal tile-config space of one op kind.

    This is the *validator* the kernels defer to: `clamp_tile` reproduces
    the (previously silent, in-kernel) clamping of oversize tiles to the
    padded problem extents, explicitly and in one place; `validate_tile`
    rejects misaligned / oversize / over-budget configs with ValueError.
    Kernels then assert the values they receive are already legal.
    """

    kind: str
    params: Tuple[TileParam, ...]
    #: approximate per-grid-step VMEM working set (bytes) of a config
    vmem_bytes: Callable[[Dict[str, int], Dict[str, int]], int]

    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def param(self, name: str) -> TileParam:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(f"kind {self.kind!r} has no tile param {name!r}")

    def config(self, **values: int) -> TileConfig:
        """Build a TileConfig in spec order; unknown names raise, missing
        params take their (unclamped) declared defaults."""
        unknown = set(values) - set(self.names())
        if unknown:
            raise ValueError(f"unknown tile param(s) {sorted(unknown)} "
                             f"for kind {self.kind!r}; "
                             f"legal: {list(self.names())}")
        return TileConfig(self.kind, tuple(
            (p.name, int(values.get(p.name, p.default)))
            for p in self.params))

    def default_config(self, op: Op = None) -> TileConfig:
        """The hardcoded-default config; clamped to ``op``'s extents when
        an op is given (exactly what the kernels used to do silently)."""
        cfg = self.config()
        return cfg if op is None else self.clamp_tile(cfg, tile_extents(op))

    def clamp_tile(self, tile: TileConfig,
                   extents: Dict[str, int]) -> TileConfig:
        """Clamp oversize params down to the padded problem extent, then
        validate.  This is the registry home of the clamp that used to be
        silently applied inside the kernels."""
        clamped = {}
        for name, v in tile.values:
            p = self.param(name)
            lim = _round_up(max(1, extents[p.extent]), p.align)
            clamped[name] = min(int(v), lim)
        cfg = self.config(**clamped)
        self.validate_tile(cfg, extents)
        return cfg

    def validate_tile(self, tile: TileConfig,
                      extents: Dict[str, int] = None) -> TileConfig:
        """Strict legality check — raises ValueError instead of rewriting.

        Checks: positive, aligned to the min tile, under the VMEM budget,
        and (when extents are given) not exceeding the padded extent plus
        any divides-extent constraint.
        """
        if tile.kind != self.kind:
            raise ValueError(f"tile config kind {tile.kind!r} does not "
                             f"match spec kind {self.kind!r}")
        vals = tile.as_dict()
        if set(vals) != set(self.names()):
            raise ValueError(
                f"tile config params {sorted(vals)} != spec params "
                f"{sorted(self.names())} for kind {self.kind!r}")
        for p in self.params:
            v = vals[p.name]
            if v <= 0:
                raise ValueError(f"{self.kind} tile {p.name}={v} must be "
                                 f"positive")
            if v % p.align:
                raise ValueError(
                    f"{self.kind} tile {p.name}={v} breaks the minimum "
                    f"tile: must be a multiple of {p.align}")
            if extents is not None:
                lim = _round_up(max(1, extents[p.extent]), p.align)
                if v > lim:
                    raise ValueError(
                        f"{self.kind} tile {p.name}={v} exceeds the padded "
                        f"{p.extent} extent {lim}; clamp via "
                        f"TileSpec.clamp_tile instead of relying on the "
                        f"kernel to rewrite it")
                if p.divides and extents[p.extent] % v:
                    raise ValueError(
                        f"{self.kind} tile {p.name}={v} must divide "
                        f"{p.extent}={extents[p.extent]}")
        if extents is not None:
            budget = self.vmem_bytes(vals, extents)
            if budget > TILE_VMEM_BUDGET:
                raise ValueError(
                    f"{self.kind} tile {tile.label()} working set "
                    f"{budget} B exceeds the VMEM budget "
                    f"{TILE_VMEM_BUDGET} B")
        return tile

    def configs(self, op: Op, *,
                preserve_numerics: bool = True) -> List[TileConfig]:
        """The legal, deduplicated candidate grid for ``op``.

        With ``preserve_numerics`` (the default, and the only mode the
        autotuner selects from unless explicitly told otherwise) every
        reduction-axis param is pinned to its default-resolved value, so
        each candidate computes bit-identical fp32 results to the default
        config — varying only how the *output* space is tiled.  With
        ``preserve_numerics=False`` the reduction params are searched too;
        those candidates are tolerance-exact, not bit-identical.
        """
        extents = tile_extents(op)
        default = self.default_config(op)
        grids: List[List[int]] = []
        for p in self.params:
            if p.reduction and preserve_numerics:
                grids.append([default.get(p.name)])
            else:
                grids.append(sorted(set(p.candidates) | {p.default}))
        out: List[TileConfig] = []
        seen = set()
        for combo in _product(grids):
            try:
                cfg = self.clamp_tile(
                    self.config(**dict(zip(self.names(), combo))), extents)
            except ValueError:
                continue
            if cfg not in seen:
                seen.add(cfg)
                out.append(cfg)
        if default not in seen:                  # pragma: no cover - safety
            out.insert(0, default)
        return out


def _product(grids: List[List[int]]) -> List[Tuple[int, ...]]:
    combos: List[Tuple[int, ...]] = [()]
    for grid in grids:
        combos = [c + (v,) for c in combos for v in grid]
    return combos


def _linear_vmem(v: Dict[str, int], extents: Dict[str, int]) -> int:
    # x block + w block + fp32 acc scratch + out block
    return 4 * (v["bm"] * v["bk"] + v["bk"] * v["bn"] + 2 * v["bm"] * v["bn"])


def _conv_vmem(v: Dict[str, int], extents: Dict[str, int]) -> int:
    # 16 Winograd points share the (bm, bn) tile: u + w + acc + out per point
    return 16 * 4 * (v["bm"] * v["bk"] + v["bk"] * v["bn"] +
                     2 * v["bm"] * v["bn"])


def _attn_vmem(v: Dict[str, int], extents: Dict[str, int]) -> int:
    # k + v cache blocks dominate; heads/hd are bounded small
    return 2 * 4 * v["bs"] * TILE_LANE


def _ssm_vmem(v: Dict[str, int], extents: Dict[str, int]) -> int:
    # decay matrix (L, L) + chunk-local b/c/x blocks
    return 4 * (v["chunk"] * v["chunk"] + 4 * v["chunk"] * TILE_LANE)


_TILE_SPECS: Dict[str, TileSpec] = {
    "linear": TileSpec(
        kind="linear",
        params=(
            TileParam("bm", "m", TILE_SUBLANE, 128, (8, 64, 128, 256)),
            TileParam("bn", "n", TILE_LANE, 128, (128, 256, 512)),
            TileParam("bk", "k", TILE_LANE, 512, (128, 256, 512, 1024),
                      reduction=True),
        ),
        vmem_bytes=_linear_vmem,
    ),
    "conv": TileSpec(
        kind="conv",
        params=(
            TileParam("bm", "m", TILE_SUBLANE, 128, (8, 64, 128, 256)),
            TileParam("bn", "n", TILE_LANE, 128, (128, 256)),
            TileParam("bk", "k", TILE_LANE, 256, (128, 256, 512),
                      reduction=True),
        ),
        vmem_bytes=_conv_vmem,
    ),
    "attention": TileSpec(
        kind="attention",
        params=(
            TileParam("bs", "s", TILE_LANE, 512, (128, 256, 512, 1024, 2048),
                      reduction=True),
        ),
        vmem_bytes=_attn_vmem,
    ),
    "ssm": TileSpec(
        kind="ssm",
        params=(
            TileParam("chunk", "t", 1, 256, (64, 128, 256, 512),
                      reduction=True, divides=True),
        ),
        vmem_bytes=_ssm_vmem,
    ),
}


def tile_spec(kind: str) -> TileSpec:
    get(kind)                                    # raise on unknown kinds
    return _TILE_SPECS[kind]


def tile_extents(op: Op) -> Dict[str, int]:
    """The problem extents each tile param blocks over, from the op's
    declared shapes (batch-1; runtime extents can only be larger)."""
    kind = op_kind(op)
    if kind == "linear":
        return {"m": op.L, "n": op.C_out, "k": op.C_in}
    if kind == "conv":
        th = -(-op.H_out // 2)
        tw = -(-op.W_out // 2)
        return {"m": th * tw, "n": op.C_out, "k": op.C_in}
    if kind == "attention":
        return {"s": op.S}
    return {"t": op.T}


def default_tile(op: Op) -> TileConfig:
    """The default-resolved (clamped) config — what an untuned plan runs."""
    return tile_spec(op_kind(op)).default_config(op)


def resolve_tile(op: Op, tile: TileConfig = None) -> TileConfig:
    """The config an executor/adapter should actually run: the clamped
    default when ``tile`` is None, else ``tile`` strictly validated
    against the op's declared extents."""
    spec = tile_spec(op_kind(op))
    if tile is None:
        return spec.default_config(op)
    return spec.validate_tile(tile, tile_extents(op))


def tile_to_json(tile: TileConfig) -> Dict[str, int]:
    """JSON codec of a tile config — plain param->value mapping; the kind
    is implied by the enclosing decision's op."""
    return {k: v for k, v in tile.values}


def tile_from_json(kind: str, d: Dict[str, int]) -> TileConfig:
    spec = tile_spec(kind)
    if set(d) != set(spec.names()):
        raise ValueError(f"tile JSON params {sorted(d)} != spec params "
                         f"{sorted(spec.names())} for kind {kind!r}")
    return spec.config(**{k: int(v) for k, v in d.items()})


# --------------------------------------------------------------- entries

@dataclasses.dataclass(frozen=True)
class KernelLowering:
    """How an op kind actually computes: Pallas path + jnp oracle.

    Both callables take ``(x, w, op, ...)``; the Pallas path additionally
    accepts ``interpret=`` for CPU-container validation.  Registered by the
    kernel package's ops.py (`register_lowering`), resolved lazily.
    """

    pallas: Callable[..., object]
    oracle: Callable[..., object]


@dataclasses.dataclass(frozen=True)
class KernelEntry:
    """Everything the planner and the executor need to know about a kind."""

    kind: str
    input_shape: Callable[[Op], Tuple[int, ...]]
    weight_shape: Callable[[Op], Tuple[int, ...]]
    output_shape: Callable[[Op], Tuple[int, ...]]
    base_features: Callable[[Op], List[float]]
    #: whether the partitioner may split the op's output channels across
    #: CPU and GPU (the paper's conv/linear domain); kinds with
    #: ``splittable=False`` partition along their typed ``axes`` instead
    splittable: bool = True
    #: typed partition axes beyond the channel axis (attention: head /
    #: kv-block; ssm: ssm-state); empty for the channel-split kinds
    axes: Tuple[AxisSpec, ...] = ()
    #: kernel modes the planner may choose between; first entry is the
    #: default (empty for kinds without a mode dimension)
    modes: Tuple[str, ...] = ()

    def init_weight(self, op: Op, rng: np.random.Generator) -> np.ndarray:
        """Seeded fan-in-scaled weights (keeps deep chains O(1) magnitude,
        which is what lets bf16 equivalence tests use sane tolerances)."""
        shape = self.weight_shape(op)
        return (rng.standard_normal(shape) /
                np.sqrt(max(1, _fan_in(op)))).astype(np.float32)

    @property
    def lowering(self) -> KernelLowering:
        return get_lowering(self.kind)


_ENTRIES: Dict[str, KernelEntry] = {
    "linear": KernelEntry(
        kind="linear",
        input_shape=_linear_input_shape,
        weight_shape=_linear_weight_shape,
        output_shape=_linear_output_shape,
        base_features=_linear_base_features,
    ),
    "conv": KernelEntry(
        kind="conv",
        input_shape=_conv_input_shape,
        weight_shape=_conv_weight_shape,
        output_shape=_conv_output_shape,
        base_features=_conv_base_features,
    ),
    "attention": KernelEntry(
        kind="attention",
        input_shape=_attn_input_shape,
        weight_shape=_attn_weight_shape,
        output_shape=_attn_output_shape,
        base_features=_attn_base_features,
        splittable=False,
        axes=(_attn_head_axis(), _attn_kv_block_axis()),
        modes=_ATTN_MODES,
    ),
    "ssm": KernelEntry(
        kind="ssm",
        input_shape=_ssm_input_shape,
        weight_shape=_ssm_weight_shape,
        output_shape=_ssm_output_shape,
        base_features=_ssm_base_features,
        splittable=False,
        axes=(_ssm_state_axis(),),
        modes=_SSM_MODES,
    ),
}

_LOWERINGS: Dict[str, KernelLowering] = {}


def kinds() -> List[str]:
    return sorted(_ENTRIES)


def get(kind: str) -> KernelEntry:
    try:
        return _ENTRIES[kind]
    except KeyError:
        raise KeyError(f"unregistered op kind {kind!r}; "
                       f"known: {kinds()}") from None


def entry_for(op: Op) -> KernelEntry:
    return get(op_kind(op))


def is_splittable(op: Op) -> bool:
    """Whether the partitioner may channel-split this op (see KernelEntry)."""
    return entry_for(op).splittable


def register_lowering(kind: str, *, pallas: Callable, oracle: Callable
                      ) -> KernelLowering:
    """Called by kernels/*/ops.py at import time to hook its kernels in."""
    if kind not in _ENTRIES:
        raise KeyError(f"cannot register lowering for unknown kind {kind!r}")
    low = KernelLowering(pallas=pallas, oracle=oracle)
    _LOWERINGS[kind] = low
    return low


def get_lowering(kind: str) -> KernelLowering:
    """Resolve a kind's lowering, importing its kernel package on demand."""
    if kind not in _LOWERINGS:
        get(kind)                              # raise on unknown kinds
        importlib.import_module(_LOWERING_MODULES[kind])
        if kind not in _LOWERINGS:             # pragma: no cover - wiring bug
            raise RuntimeError(
                f"{_LOWERING_MODULES[kind]} did not register a lowering "
                f"for {kind!r}")
    return _LOWERINGS[kind]


# ----------------------------------------------------- split lowerings

@dataclasses.dataclass(frozen=True)
class SplitLowering:
    """How a (kind, axis) pair co-executes across the two-group mesh.

    ``pack(w, op, n_fast, mesh)`` -> (split_plan, packed_weights): the
    per-side parameter layout (a channel-style SplitPlan for stackable
    axes, so the executor's gather/chaining machinery applies unchanged).

    ``run(x, packed, split, mesh, op, n_fast, *, gather, x_plan,
    use_pallas, interpret)`` -> output (stacked or gathered, mirroring
    coexec_matmul's contract).
    """

    pack: Callable[..., object]
    run: Callable[..., object]


_SPLIT_LOWERINGS: Dict[Tuple[str, str], SplitLowering] = {}


def register_split_lowering(kind: str, axis: str, *, pack: Callable,
                            run: Callable) -> SplitLowering:
    """Called by kernels/*/ops.py at import time, next to its lowering."""
    axis_spec(kind, axis)                      # raise on unknown (kind, axis)
    low = SplitLowering(pack=pack, run=run)
    _SPLIT_LOWERINGS[(kind, axis)] = low
    return low


def get_split_lowering(kind: str, axis: str) -> SplitLowering:
    """Resolve a (kind, axis) split lowering, importing on demand."""
    if (kind, axis) not in _SPLIT_LOWERINGS:
        axis_spec(kind, axis)                  # raise on unknown (kind, axis)
        importlib.import_module(_LOWERING_MODULES[kind])
        if (kind, axis) not in _SPLIT_LOWERINGS:   # pragma: no cover
            raise RuntimeError(
                f"{_LOWERING_MODULES[kind]} did not register a split "
                f"lowering for {kind!r}/{axis!r}")
    return _SPLIT_LOWERINGS[(kind, axis)]
