"""Jitted wrapper for the Winograd conv kernel with TFLite-style selection.

`conv2d_op` mirrors the paper's kernel-selection logic (Section 3.2): 3x3
stride-1 convs with enough channels take the Winograd path; everything else
falls back to the direct reference convolution.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.winograd_conv.ref import conv2d_ref
from repro.kernels.winograd_conv.winograd_conv import winograd_conv2d


@functools.partial(jax.jit, static_argnames=("interpret", "use_kernel"))
def conv2d_op(x, w, *, interpret: bool = False, use_kernel: bool = True):
    kh, kw, cin, cout = w.shape
    winograd_eligible = (kh == 3 and kw == 3 and cout >= 128
                         and x.shape[1] * x.shape[2] >= 1024 and cin >= 32)
    if use_kernel and winograd_eligible:
        return winograd_conv2d(x, w, interpret=interpret)
    return conv2d_ref(x, w)
