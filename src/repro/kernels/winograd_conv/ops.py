"""Jitted wrapper for the Winograd conv kernel with TFLite-style selection.

`conv2d_op` mirrors the paper's kernel-selection logic (Section 3.2): 3x3
stride-1 convs with enough channels take the Winograd path; everything else
falls back to the direct reference convolution.

This module also registers the "conv" lowering in the shared kernel
registry (repro.kernels.registry), which is how the plan executor reaches
these kernels: the Pallas path goes through `conv2d_op` (Winograd when
eligible) and the oracle is the direct lax.conv reference.  The op's
declared output shape uses floor division (`ConvOp.H_out`), while SAME
convolution produces ceil(H/S) rows — the registry lowering crops to the
declared shape so executed activations chain exactly like planned ones.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import registry
from repro.kernels.winograd_conv.ref import conv2d_ref
from repro.kernels.winograd_conv.winograd_conv import winograd_conv2d


@functools.partial(jax.jit, static_argnames=("stride", "bm", "bn", "bk",
                                             "interpret", "use_kernel"))
def conv2d_op(x, w, *, stride: int = 1, bm: int = None, bn: int = None,
              bk: int = None, interpret: bool = False,
              use_kernel: bool = True):
    kh, kw, cin, cout = w.shape
    # the registry owns the Winograd-selection threshold so planner
    # availability predicates and this dispatch cannot drift apart
    winograd_eligible = (kh == 3 and kw == 3 and stride == 1
                         and cout >= registry.WINOGRAD_MIN_COUT
                         and x.shape[1] * x.shape[2] >= 1024 and cin >= 32)
    if use_kernel and winograd_eligible:
        return winograd_conv2d(x, w, bm=bm, bn=bn, bk=bk,
                               interpret=interpret)
    return conv2d_ref(x, w, stride=stride)


# ------------------------------------------------------- registry hookup

def _crop_to_declared(y, op):
    return y[:, :op.H_out, :op.W_out, :]


def _conv_pallas(x, w, op, *, interpret: bool = False, tile=None):
    if tile is None:
        return _crop_to_declared(
            conv2d_op(x, w, stride=op.S, interpret=interpret), op)
    v = registry.resolve_tile(op, tile).as_dict()
    return _crop_to_declared(
        conv2d_op(x, w, stride=op.S, bm=v["bm"], bn=v["bn"], bk=v["bk"],
                  interpret=interpret), op)


def _conv_oracle(x, w, op):
    return _crop_to_declared(conv2d_ref(x, w, stride=op.S), op)


registry.register_lowering("conv", pallas=_conv_pallas, oracle=_conv_oracle)
