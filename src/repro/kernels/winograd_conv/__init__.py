from repro.kernels.winograd_conv.ops import conv2d_op
from repro.kernels.winograd_conv.ref import conv2d_ref
from repro.kernels.winograd_conv.winograd_conv import (hadamard_matmul,
                                                       winograd_conv2d)

__all__ = ["conv2d_op", "conv2d_ref", "hadamard_matmul", "winograd_conv2d"]
