"""Pallas TPU kernel: Winograd F(2x2, 3x3) convolution.

This is the paper's kernel-selection case study (Fig. 6b): TFLite switches
3x3 convolutions to a Winograd kernel above C_out >= 128, producing the
latency discontinuity the white-box predictor captures.  Here the same
algorithm is adapted to TPU: input/output tile transforms are cheap
elementwise/small-matrix work done in jnp, and the hot spot — 16
independent (P, C_in) x (C_in, C_out) matmuls in the Hadamard domain — runs
as one Pallas kernel with the Hadamard point as the leading grid dimension.

Layout: U (16, P, C_in) transformed input tiles, V (16, C_in, C_out)
transformed filters; the kernel computes M[g] = U[g] @ V[g] with MXU-aligned
(bm, bn, bk) VMEM blocks, then jnp applies the inverse transform A^T M A.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.tiles import check_tile as _check_tile

# F(2x2, 3x3) transform matrices (Lavin & Gray 2016)
_BT = np.array([[1, 0, -1, 0],
                [0, 1, 1, 0],
                [0, -1, 1, 0],
                [0, 1, 0, -1]], np.float32)
_G = np.array([[1, 0, 0],
               [0.5, 0.5, 0.5],
               [0.5, -0.5, 0.5],
               [0, 0, 1]], np.float32)
_AT = np.array([[1, 1, 1, 0],
                [0, 1, -1, -1]], np.float32)


def _hadamard_matmul_kernel(u_ref, v_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(3)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(u_ref[0], v_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k_idx == n_k - 1)
    def _store():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def hadamard_matmul(u: jax.Array, v: jax.Array, *, bm: int = None,
                    bn: int = None, bk: int = None,
                    interpret: bool = False) -> jax.Array:
    """M[g] = U[g] @ V[g] for g in [0, 16).  u: (16,P,K); v: (16,K,N).

    None tile params resolve to the default blocking clamped to the
    problem extents; explicit values must already be legal (see
    kernels.tiles.check_tile) or ValueError is raised.
    """
    g, p, k = u.shape
    _, _, n = v.shape
    bm = _check_tile("bm", bm, 128, p, 8)
    bn = _check_tile("bn", bn, 128, n, 128)
    bk = _check_tile("bk", bk, 256, k, 128)
    pp, kp, np_ = (-p) % bm, (-k) % bk, (-n) % bn
    if pp or kp:
        u = jnp.pad(u, ((0, 0), (0, pp), (0, kp)))
    if kp or np_:
        v = jnp.pad(v, ((0, 0), (0, kp), (0, np_)))
    grid = (g, u.shape[1] // bm, v.shape[2] // bn, u.shape[2] // bk)

    out = pl.pallas_call(
        functools.partial(_hadamard_matmul_kernel, n_k=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda gg, i, j, kk: (gg, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda gg, i, j, kk: (gg, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda gg, i, j, kk: (gg, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, u.shape[1], v.shape[2]), u.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(u, v)
    return out[:, :p, :n]


def winograd_conv2d(x: jax.Array, w: jax.Array, *, interpret: bool = False,
                    bm: int = None, bn: int = None, bk: int = None
                    ) -> jax.Array:
    """3x3 stride-1 SAME conv via F(2x2,3x3).

    x: (B, H, W, C_in); w: (3, 3, C_in, C_out) -> (B, H, W, C_out).
    """
    b, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    assert (kh, kw) == (3, 3)
    ho, wo = h, wdt                       # SAME, stride 1
    th, tw = -(-ho // 2), -(-wo // 2)     # 2x2 output tiles

    # pad input: 1 halo + tile remainder
    xp = jnp.pad(x, ((0, 0), (1, 2 * th - ho + 1), (1, 2 * tw - wo + 1),
                     (0, 0)))
    # gather 4x4 input tiles at stride 2: (B, th, tw, 4, 4, C)
    tiles = jnp.stack(
        [jnp.stack([xp[:, i:i + 2 * th:2, j:j + 2 * tw:2, :]
                    for j in range(4)], axis=3) for i in range(4)], axis=3)
    # input transform: U = B^T d B  over the 4x4 dims
    bt = jnp.asarray(_BT, x.dtype)
    u = jnp.einsum("ij,bhwjkc,lk->bhwilc", bt, tiles, bt)
    p = b * th * tw
    u = u.reshape(p, 16, cin).transpose(1, 0, 2)          # (16, P, Cin)

    # filter transform: V = G g G^T
    gm = jnp.asarray(_G, w.dtype)
    v = jnp.einsum("ij,jkcn,lk->ilcn", gm, w, gm)          # (4,4,Cin,Cout)
    v = v.reshape(16, cin, cout)

    m = hadamard_matmul(u, v, bm=bm, bn=bn, bk=bk, interpret=interpret)

    # inverse transform: y = A^T M A
    m = m.transpose(1, 0, 2).reshape(b, th, tw, 4, 4, cout)
    at = jnp.asarray(_AT, x.dtype)
    y = jnp.einsum("ij,bhwjkc,lk->bhwilc", at, m, at)      # (B,th,tw,2,2,C)
    y = y.transpose(0, 1, 3, 2, 4, 5).reshape(b, 2 * th, 2 * tw, cout)
    return y[:, :ho, :wo, :]
