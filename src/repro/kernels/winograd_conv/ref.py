"""Pure-jnp oracle: direct 3x3 SAME convolution via lax.conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B,H,W,Cin); w: (3,3,Cin,Cout) -> (B,H,W,Cout), stride 1, SAME."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)
