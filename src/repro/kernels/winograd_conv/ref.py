"""Pure-jnp oracle: direct SAME convolution via lax.conv."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_ref(x: jax.Array, w: jax.Array, *, stride: int = 1) -> jax.Array:
    """x: (B,H,W,Cin); w: (K,K,Cin,Cout) -> (B,ceil(H/S),ceil(W/S),Cout),
    SAME padding."""
    return jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(x.dtype)
