"""Kernel-side tile-param validation shared by the four Pallas kernels.

The kernels used to clamp oversize tile requests silently (``bm = min(bm,
round_up(m, 8))``); that rewrite now lives explicitly in
``registry.TileSpec.clamp_tile``, and the kernels *validate* instead: a
tile param left as None resolves to the default blocking clamped to the
problem extents (the pre-tile behaviour for every existing caller), while
an explicitly requested value that is misaligned or oversize raises
ValueError rather than being quietly rewritten.

This module is dependency-free on purpose (no registry import) so the
kernel files stay importable without dragging in the op-type layer.
"""
from __future__ import annotations


def round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def check_tile(name: str, v, default: int, extent: int, align: int,
               lim_align: int = None) -> int:
    """Default-or-validate one tile param against a problem extent.

    None -> ``min(default, round_up(extent, lim_align))`` (the legal
    clamped default).  An explicit value must be a positive multiple of
    ``align`` no larger than the padded extent, else ValueError.
    ``lim_align`` (default ``align``) sets the padding granularity of the
    extent cap separately from the value's own alignment — decode
    attention caps ``bs`` at the lane-padded cache length while accepting
    any positive block size.
    """
    lim = round_up(max(1, extent), lim_align if lim_align else align)
    if v is None:
        return min(default, lim)
    v = int(v)
    if v <= 0 or v % align or v > lim:
        raise ValueError(
            f"illegal tile {name}={v} for extent {extent}: must be a "
            f"positive multiple of {align} and <= {lim} (clamp via "
            f"kernels.registry.TileSpec.clamp_tile)")
    return v


def check_chunk(name: str, v, default: int, extent: int) -> int:
    """Default-or-validate a chunk-style param that must divide its extent.

    None -> ``min(default, extent)``; explicit values must be positive,
    <= extent and divide it exactly, else ValueError.
    """
    if v is None:
        v = min(default, extent)
    v = int(v)
    if v <= 0 or v > extent:
        raise ValueError(
            f"illegal tile {name}={v} for extent {extent}: must be in "
            f"1..{extent} (clamp via kernels.registry.TileSpec.clamp_tile)")
    if extent % v:
        raise ValueError(
            f"illegal tile {name}={v}: must divide extent {extent} exactly")
    return v
