"""Pallas TPU kernels for the perf-critical compute paths.

  split_matmul/      channel-partitioned matmul (co-execution primitive)
  winograd_conv/     F(2x2,3x3) convolution (the paper's kernel-switch case)
  decode_attention/  flash-style 1-token decode vs a long KV cache
  ssd_chunk/         chunked Mamba2 SSD scan, state resident in VMEM

Each package has <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper), ref.py (pure-jnp oracle); tests validate interpret=True against
the oracle over shape/dtype sweeps.

registry.py is the shared dispatch table (op kind -> shapes, features,
Pallas op, jnp oracle) used by both the planner and the plan executor;
split_matmul/ops.py and winograd_conv/ops.py register their lowerings
there at import.
"""
