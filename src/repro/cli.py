"""`python -m repro` — one CLI over the compile→run facade.

Subcommands:

  * `plan`      — compile (or fetch from cache) a co-execution plan; can
                  also write the plan JSON (`--out`) and the shippable
                  `CompiledNetwork` artifact (`--save`).
  * `execute`   — compile (or load an artifact) and run the plan end to
                  end, reporting executed-vs-predicted fidelity per op.
  * `calibrate` — close the loop: execute + record measurements, fit a
                  `Calibrator`, replan with corrected predictors, and
                  print the plan diff.
  * `tune`      — measured Pallas tile-config search for a network's ops,
                  cached in the on-disk `TuneCache`; `plan/execute
                  --tune` attach the winners to compiled plans.
  * `verify`    — statically verify plan/portfolio/bench/tune artifacts
                  (`repro.analysis`): schema discipline, axis/tile
                  legality, segment invariants, provenance digests —
                  without importing jax or executing anything.
  * `lint`      — run the repo-contract linter over `src/repro`
                  (import-light modules, registry completeness,
                  no-silent-clamp).
  * `bench`     — forward to the paper benchmark driver (`benchmarks.run`).
  * `serve`     — forward to the serving launcher (`repro.launch.serve`):
                  the fixed-batch engine, or — with `--arrivals poisson
                  --portfolio ...` — the continuous scheduler over a
                  bucketed plan portfolio with drift-triggered replanning.

`plan` and `execute` are thin clients of `repro.compile`; their provenance
(and therefore their on-disk cache entries) is bit-identical to the
retired `python -m repro.runtime.plan` / `python -m repro.runtime.executor`
CLIs, which now forward here with a DeprecationWarning.
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path
from typing import Optional, Sequence


def _add_compile_args(ap: argparse.ArgumentParser) -> None:
    from repro.core.simulator.devices import DEVICES
    from repro.core.sync import SyncMechanism

    # no choices= here: unknown names surface repro.api's ValueError, which
    # lists both registries (unit networks + model graphs) in one message
    ap.add_argument("--network", default="resnet18",
                    help="unit network (vgg16, resnet18, ...) or any name "
                         "--model accepts")
    ap.add_argument("--model", default=None,
                    help="decoder-block model graph via graph.from_model "
                         "(tiny_decoder, tiny_ssm, gemma3-12b, ...); "
                         "overrides --network")
    ap.add_argument("--cache-len", type=int, default=128,
                    help="KV-cache length of --model attention nodes")
    ap.add_argument("--tokens", type=int, default=1,
                    help="tokens per decode step of --model graphs "
                         "(chunked prefill; pure-SSM configs only)")
    ap.add_argument("--blocks", type=int, default=1,
                    help="decoder blocks to chain for --model graphs")
    ap.add_argument("--device", default="moto2022", choices=sorted(DEVICES))
    ap.add_argument("--threads", type=int, default=3)
    ap.add_argument("--mechanism", default="svm_poll",
                    choices=[m.value for m in SyncMechanism])
    ap.add_argument("--step", type=int, default=8,
                    help="candidate-grid step (channels)")
    ap.add_argument("--mode", default="predicted",
                    choices=["predicted", "grid"],
                    help="predicted = GBDT planning (deployable); "
                         "grid = measurement-driven oracle")
    ap.add_argument("--cache-dir", default="reports/plans",
                    help="on-disk PlanCache directory")
    ap.add_argument("--samples", type=int, default=400,
                    help="training ops per predictor (simulator-measured)")
    ap.add_argument("--estimators", type=int, default=60,
                    help="GBDT trees per predictor")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--predictor-cache", default=None,
                    help="optional directory to cache trained predictors "
                         "(a load is checksum-identical to a retrain)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune kernel tile configs on a plan-cache "
                         "miss and attach the winners to the plan "
                         "(tuned plans get their own cache entries)")
    ap.add_argument("--tune-cache-dir", default="reports/tune",
                    help="on-disk TuneCache directory (measured tile "
                         "choices, content-addressed)")


class _UserInputError(Exception):
    """A bad CLI input (unknown name, invalid target, ...) — printed as a
    clean one-line error; internal failures keep their tracebacks."""


def _network_arg(args):
    """The compile() input: model names — via --model or --network — build
    a decoder-block graph honoring the CLI's blocks/cache-len knobs;
    everything else resolves by name inside `repro.compile`."""
    name = args.model or args.network
    if args.model or _is_model_name(name):
        from repro.graph import from_model
        return from_model(name, blocks=args.blocks,
                          cache_len=args.cache_len,
                          tokens=getattr(args, "tokens", 1))
    return name


def _is_model_name(name: str) -> bool:
    from repro.core.networks import NETWORKS
    if name in NETWORKS:
        return False
    from repro.graph.frontends import model_names
    return name in model_names()


def _compile(args):
    from repro.api import Target, compile as _api_compile
    t0 = time.time()
    # ValueErrors up to and including compile() are user-input problems
    # (unknown name/device/mechanism, bad mode, predictor/target mismatch)
    # and print as one-line errors; later failures keep their tracebacks
    try:
        target = Target(device=args.device, threads=args.threads,
                        mechanism=args.mechanism, step=args.step,
                        seed=args.seed)
        compiled = _api_compile(_network_arg(args), target, mode=args.mode,
                                cache=args.cache_dir, samples=args.samples,
                                estimators=args.estimators,
                                predictor_cache=args.predictor_cache,
                                tune=getattr(args, "tune", False),
                                tune_cache=getattr(args, "tune_cache_dir",
                                                   None))
    except ValueError as e:
        raise _UserInputError(str(e)) from e
    return compiled, time.time() - t0


def _cache_status(compiled) -> str:
    return "HIT" if compiled.from_cache else "MISS (compiled)"


def _cmd_plan(args) -> int:
    from repro.runtime.cache import PlanCache
    compiled, dt = _compile(args)
    plan = compiled.plan
    n_co = sum(1 for d in plan.decisions if not d.exclusive)
    name = args.model or args.network
    print(f"plan {name} on {args.device} (cpu{args.threads}, "
          f"{args.mechanism}, {args.mode}): cache {_cache_status(compiled)}")
    print(f"  compiled in {dt:.1f}s (predictors + planning; a warm hit is "
          f"a pure JSON read)")
    print(f"  key {plan.key} -> "
          f"{PlanCache(Path(args.cache_dir)).path_for(plan.provenance)}")
    if plan.end_to_end_us is not None:
        print(f"  baseline (GPU only): {plan.baseline_us / 1e3:.1f} ms | "
              f"end-to-end co-exec: {plan.end_to_end_us / 1e3:.1f} ms "
              f"({plan.baseline_us / plan.end_to_end_us:.2f}x)")
    print(f"  {n_co}/{len(plan.decisions)} ops co-executed")
    # write artifacts before the explain dump: a consumer closing the pipe
    # early (`... | head`) must not be able to skip the requested writes
    if args.out:
        plan.save(Path(args.out))
        print(f"  wrote plan {args.out}")
    if args.save:
        compiled.save(args.save)
        print(f"  wrote artifact {args.save}")
    if args.explain:
        print(compiled.explain())
    if args.verbose:
        from repro.analysis import rejections
        print(f"  {rejections.summary()}")
        for digest, rule, detail in rejections.entries():
            why = f": {detail}" if detail else ""
            print(f"    {digest} rejected by {rule}{why}")
    return 0


def _cmd_execute(args) -> int:
    if args.artifact:
        from repro.api import CompiledNetwork
        compiled = CompiledNetwork.load(args.artifact)
        print(f"execute artifact {args.artifact} "
              f"(device {compiled.target.device}, key {compiled.key})")
    else:
        compiled, _ = _compile(args)
        print(f"execute {args.model or args.network} on {args.device} plan "
              f"{compiled.key} (cache {_cache_status(compiled)})")
    exe = compiled.executor()
    groups = ("2-group split mesh" if exe.split_capable
              else "degraded single-group mesh (exclusive execution)")
    print(f"  {groups}")
    report = compiled.profile(chain=not args.no_chain,
                              warmup=not args.no_warmup)
    if args.fused:
        import numpy as np
        # differential spelling: run both walks on the same input and
        # compare outputs byte-for-byte (the harness CI greps this line)
        x = exe.input_template()
        y_unfused = compiled.run(x, warmup=True)
        rep_unfused = compiled.last_report
        y_fused = compiled.run(x, warmup=True, fused=True)
        rep_fused = compiled.last_report
        identical = (np.asarray(y_fused).tobytes()
                     == np.asarray(y_unfused).tobytes())
        n_seg = len(rep_fused.segment_wall_us)
        print(f"  fused: {n_seg} segments, {rep_fused.sync_points} syncs "
              f"(vs {rep_unfused.sync_points} unfused), outputs "
              f"{'bit-identical' if identical else 'DIVERGED'}")
        print(f"  fused wall {rep_fused.wall_us / 1e3:.1f} ms vs unfused "
              f"{rep_unfused.wall_us / 1e3:.1f} ms")
        report = rep_fused
        if not identical:
            return 1
    if args.per_op:
        for t in report.timings:
            extra = " chained" if t.chained_input else ""
            if t.segment >= 0:
                extra += f" seg={t.segment}"
            print(f"  [{t.index:02d}] {t.label:42s} {t.mode:9s} "
                  f"{t.c_fast}/{t.c_slow} wall {t.wall_us:9.0f}us "
                  f"pred {t.pred_us:8.1f}us{extra}")
    print(report.fidelity_summary())
    return 0


def _cmd_calibrate(args) -> int:
    from repro.measure import MeasurementStore, fidelity_error

    if args.mode != "predicted":
        print("error: calibrate needs mode='predicted' (grid plans are "
              "measurement-driven; there are no predictors to calibrate)",
              file=sys.stderr)
        return 2
    compiled, dt = _compile(args)
    print(f"calibrate {args.model or args.network} on {args.device} "
          f"(cpu{args.threads}, {args.mechanism}): plan {compiled.key} "
          f"(cache {_cache_status(compiled)}, {dt:.1f}s)")
    store = MeasurementStore(Path(args.store_dir))
    for i in range(args.runs):
        # the executor warms up once; later runs are already steady-state
        rep = compiled.record(store=store, warmup=not args.no_warmup)
        print(f"  run {i + 1}/{args.runs}: {rep.fidelity_summary()}")
    records = store.load(compiled.key)
    cal = compiled.recalibrate(store)
    pre = fidelity_error(records)
    post = cal.fidelity_error(records)
    print(f"  {cal.summary()}" if args.verbose else
          f"  calibrator {cal.version}: {len(cal.corrections)} corrections "
          f"from {cal.n_records} records")
    shrink = f" ({pre / post:.1f}x smaller)" if post > 0 else ""
    print(f"  fidelity error {pre:.2f} -> {post:.2f} "
          f"(sum |log wall/pred| over {cal.n_records} usable records)"
          f"{shrink}")
    if args.save_calibration:
        path = cal.save(Path(args.save_calibration))
        print(f"  wrote calibrator {path}")
    recompiled, diff = compiled.replan(cal, store=store,
                                       cache=args.cache_dir)
    print(diff.summary())
    from repro.runtime.cache import PlanCache
    print(f"  new plan cached at "
          f"{PlanCache(Path(args.cache_dir)).path_for(recompiled.provenance)}")
    print(f"  measurements {store.path_for(compiled.key)} "
          f"({len(records)} records)")
    return 0


def _cmd_tune(args) -> int:
    """Measured tile search for every unique op of a network, through the
    on-disk TuneCache (warm entries are returned without measuring)."""
    from repro.api import _resolve_graph
    from repro.kernels import registry
    from repro.runtime.autotune import (TuneCache, autotune, measure_device,
                                        tune_cache_version)
    try:
        graph_or_ops, is_graph = _resolve_graph(_network_arg(args))
    except ValueError as e:
        raise _UserInputError(str(e)) from e
    ops = ([n.op for n in graph_or_ops if n.op is not None] if is_graph
           else list(graph_or_ops))
    unique = list(dict.fromkeys(ops))
    cache = TuneCache(Path(args.tune_cache_dir))
    device, backend = measure_device()
    print(f"tune {args.model or args.network}: {len(unique)} unique ops on "
          f"{device}/{backend} ({tune_cache_version()}) -> {cache.root}")
    tuned = 0
    for op in unique:
        spec = registry.tile_spec(registry.op_kind(op))
        n_cand = len(spec.configs(op))
        t0 = time.time()
        hits = cache.hits
        best = autotune(op, cache=cache, device=device, backend=backend,
                        reps=args.reps)
        warm = cache.hits > hits
        default = spec.default_config(op)
        if best == default:
            verdict = f"default {best.label()}"
        else:
            tuned += 1
            verdict = f"{default.label()} -> {best.label()}"
        src = "cache" if warm else f"measured {n_cand} candidates"
        print(f"  {registry.op_label(op):42s} {verdict:28s} "
              f"({src}, {time.time() - t0:.1f}s)")
    print(f"  {tuned}/{len(unique)} ops tuned away from the default "
          f"blocking ({cache.hits} cache hits)")
    return 0


def _cmd_verify(args) -> int:
    """Statically verify artifacts on disk; exit 1 on error-severity
    diagnostics (warnings and info never fail the run)."""
    from repro.analysis import SEV_ERROR, SEV_INFO, SEV_WARNING, verify_path
    paths = [Path(p) for p in args.paths]
    if args.all_artifacts:
        for d in ("reports/plans", "reports/tune", "reports/bench"):
            paths.extend(sorted(Path(d).glob("*.json")))
    if not paths:
        print("error: nothing to verify (pass artifact paths or "
              "--all-artifacts)", file=sys.stderr)
        return 2
    n_err = n_warn = 0
    for p in paths:
        kind, diags = verify_path(p, stats=args.verbose)
        errs = [d for d in diags if d.severity == SEV_ERROR]
        warns = [d for d in diags if d.severity == SEV_WARNING]
        n_err += len(errs)
        n_warn += len(warns)
        print(f"{'FAIL' if errs else 'ok':4s} {kind:9s} {p}")
        shown = errs + warns
        if args.verbose:
            shown += [d for d in diags if d.severity == SEV_INFO]
        for d in shown:
            print(f"       {d}")
    print(f"verified {len(paths)} artifact(s): {n_err} error(s), "
          f"{n_warn} warning(s)")
    return 1 if n_err else 0


def _cmd_lint(args) -> int:
    """Run the repo-contract linter; exit 1 on any finding."""
    from repro.analysis.lint import LINT_RULES, lint_repo, package_root
    pkg = Path(args.src) if args.src else package_root()
    diags = lint_repo(pkg)
    for d in diags:
        print(d)
    rules = ", ".join(sorted(LINT_RULES))
    print(f"lint {pkg}: {len(diags)} finding(s) across [{rules}]")
    return 1 if diags else 0


def _cmd_bench(rest: Sequence[str]) -> int:
    # benchmarks/ lives at the repo root (it is not an installed package);
    # running from the checkout works directly, an installed interpreter
    # needs the cwd fallback
    try:
        from benchmarks.run import main as bench_main
    except ImportError:
        sys.path.insert(0, str(Path.cwd()))
        try:
            from benchmarks.run import main as bench_main
        except ImportError:
            print("error: cannot import benchmarks.run — run `python -m "
                  "repro bench` from the repository root", file=sys.stderr)
            return 2
    return bench_main(list(rest)) or 0


def _cmd_serve(rest: Sequence[str]) -> int:
    from repro.launch.serve import serve_main
    return serve_main(list(rest)) or 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # bench/serve forward their whole tail verbatim; dispatch before
    # argparse so leading options (`serve --arch ...`) survive (argparse
    # REMAINDER refuses option-looking tokens in first position)
    if argv[:1] == ["bench"]:
        return _cmd_bench(argv[1:])
    if argv[:1] == ["serve"]:
        return _cmd_serve(argv[1:])
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="Fine-grained CPU-GPU co-execution: compile, run, "
                    "benchmark, serve.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_plan = sub.add_parser(
        "plan", help="compile (or fetch from cache) a co-execution plan")
    _add_compile_args(p_plan)
    p_plan.add_argument("--out", default=None,
                        help="also write the plan JSON to this path")
    p_plan.add_argument("--save", default=None,
                        help="write the shippable CompiledNetwork artifact "
                             "(plan + target + checksum) to this path")
    p_plan.add_argument("--explain", action="store_true",
                        help="print the per-op decision table")
    p_plan.add_argument("-v", "--verbose", action="store_true",
                        help="also print cache-rejection counts (which "
                             "verifier rule each stale entry failed)")

    p_exec = sub.add_parser(
        "execute", help="execute a compiled plan end to end and report "
                        "executed-vs-predicted fidelity")
    _add_compile_args(p_exec)
    p_exec.add_argument("--artifact", default=None,
                        help="execute a saved CompiledNetwork artifact "
                             "instead of compiling")
    p_exec.add_argument("--no-chain", action="store_true",
                        help="gather after every co-executed op "
                             "(no elision)")
    p_exec.add_argument("--no-warmup", action="store_true",
                        help="skip the untimed warmup pass (timings then "
                             "include tracing + compilation)")
    p_exec.add_argument("--per-op", action="store_true",
                        help="print one line per executed unit")
    p_exec.add_argument("--fused", action="store_true",
                        help="also run the fused segment walk and compare "
                             "it byte-for-byte against the per-node walk")

    p_cal = sub.add_parser(
        "calibrate", help="record executions, fit a latency calibrator, "
                          "replan with corrected predictors, and show the "
                          "plan diff")
    _add_compile_args(p_cal)
    p_cal.add_argument("--runs", type=int, default=2,
                       help="timed executions to record before fitting")
    p_cal.add_argument("--store-dir", default="reports/measurements",
                       help="measurement store directory (append-only "
                            "JSONL per plan)")
    p_cal.add_argument("--save-calibration", default=None,
                       help="also write the fitted calibrator JSON here")
    p_cal.add_argument("--no-warmup", action="store_true",
                       help="skip the untimed warmup before the first "
                            "recorded run")
    p_cal.add_argument("--verbose", action="store_true",
                       help="print per-(kind, mode) correction lines")

    p_tune = sub.add_parser(
        "tune", help="autotune kernel tile configs for a network's ops and "
                     "store the winners in the on-disk TuneCache")
    _add_compile_args(p_tune)
    p_tune.add_argument("--reps", type=int, default=2,
                        help="timed repetitions per candidate (median)")

    p_verify = sub.add_parser(
        "verify", help="statically verify plan/portfolio/bench/tune "
                       "artifacts without importing jax or executing "
                       "anything")
    p_verify.add_argument("paths", nargs="*",
                          help="artifact JSON files (plan, CompiledNetwork "
                               "artifact, portfolio, bench report, tune "
                               "entry — dispatched by document shape)")
    p_verify.add_argument("--all-artifacts", action="store_true",
                          help="scan reports/plans, reports/tune and "
                               "reports/bench")
    p_verify.add_argument("-v", "--verbose", action="store_true",
                          help="also print info diagnostics (static "
                               "resource accounting)")

    p_lint = sub.add_parser(
        "lint", help="run the repo-contract linter (import-light, "
                     "registry completeness, no-silent-clamp)")
    p_lint.add_argument("--src", default=None,
                        help="package directory to lint (default: the "
                             "installed repro package)")

    # bench/serve exist here only so `python -m repro --help` lists them;
    # their real dispatch is the verbatim-forward intercept above
    sub.add_parser("bench",
                   help="run paper benchmark suites (forwards to "
                        "benchmarks.run; e.g. --only tab3)")
    sub.add_parser("serve",
                   help="serve requests: fixed-batch engine, or continuous "
                        "scheduler with a plan portfolio (--arrivals "
                        "poisson --rate ... --portfolio ...); forwards to "
                        "repro.launch.serve")

    args = ap.parse_args(argv)
    try:
        if args.cmd == "plan":
            return _cmd_plan(args)
        if args.cmd == "calibrate":
            return _cmd_calibrate(args)
        if args.cmd == "tune":
            return _cmd_tune(args)
        if args.cmd == "verify":
            return _cmd_verify(args)
        if args.cmd == "lint":
            return _cmd_lint(args)
        return _cmd_execute(args)
    except _UserInputError as e:
        # e.g. an unknown --network/--model: surface the registry listing
        # from repro.api instead of a traceback
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    raise SystemExit(main())
