from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw
__all__ = ["AdamWConfig", "AdamWState", "adamw_update", "init_adamw"]
