"""AdamW + cosine schedule with linear warmup (pure JAX pytree optimizer).

Optimizer state is a pytree congruent with the parameters, so it inherits
the parameter sharding under pjit (ZeRO-style: each device holds the
moments of its own weight shards).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_adamw(params) -> AdamWState:
    zeros = lambda: jax.tree.map(    # noqa: E731
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())


def _schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def adamw_update(cfg: AdamWConfig, params, grads,
                 state: AdamWState) -> Tuple[Any, AdamWState]:
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1, b2 = cfg.betas
    lr = _schedule(cfg, step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                      state.nu, grads)

    def upd(p, m, v):
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)
