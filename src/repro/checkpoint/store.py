"""Minimal dependency-free checkpointing of JAX pytrees.

Layout: <dir>/<step>/manifest.json + one .npy per leaf (flattened key path).
bfloat16 leaves are stored as uint16 views with a dtype tag (NumPy has no
native bf16 serialization).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(re.sub(r"[^\w.]", "_", str(p)) for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(directory, step: int, tree) -> Path:
    out = Path(directory) / str(step)
    out.mkdir(parents=True, exist_ok=True)
    manifest = {}
    for key, leaf in _flatten(tree).items():
        arr = np.asarray(leaf)
        tag = str(arr.dtype)
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            tag = "bfloat16"
        fname = f"{abs(hash(key)) % 10**12}.npy"
        np.save(out / fname, arr)
        manifest[key] = {"file": fname, "dtype": tag}
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def restore_checkpoint(directory, step: int, template):
    """Restore into the structure of `template` (same pytree shape)."""
    src = Path(directory) / str(step)
    with open(src / "manifest.json") as f:
        manifest = json.load(f)
    flat_template = _flatten(template)
    restored = {}
    for key in flat_template:
        meta = manifest[key]
        arr = np.load(src / meta["file"])
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        restored[key] = jnp.asarray(arr)
    # rebuild tree in template order
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    keys = ["/".join(re.sub(r"[^\w.]", "_", str(p)) for p in path)
            for path, _ in leaves_paths[0]]
    new_leaves = [restored[k] for k in keys]
    return jax.tree_util.tree_unflatten(leaves_paths[1], new_leaves)


def latest_step(directory) -> Optional[int]:
    d = Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name) for p in d.iterdir() if p.name.isdigit()]
    return max(steps) if steps else None
