"""Measurement, calibration & adaptive replanning — the feedback loop.

One schema (`MeasurementRecord`) for every timing the system produces:
executed plan runs (`runtime/executor`), simulator measurements
(`core/simulator/measure.measure_records`), and benchmark reports.  An
append-only `MeasurementStore` (JSONL under `reports/measurements/`,
keyed by the same provenance digests as the plan cache) accumulates them;
a `Calibrator` fits per-(op-kind, mode) affine corrections and wraps any
latency predictor without retraining (`CalibratedPredictor`); `replan`
re-runs the cached planners under the corrections and diffs the plans
(`PlanDiff`); a `DriftMonitor` watches windowed fidelity drift with
hysteresis and fires the in-place replan trigger the serving scheduler
consumes.  Facade spellings: `CompiledNetwork.record() /
recalibrate() / replan()` and `python -m repro calibrate`.

Exports resolve lazily (PEP 562), and nothing in this package imports
jax — recording, fitting, and replanning are all host-side bookkeeping.
"""
import importlib

_EXPORTS = {
    "MEASUREMENT_SCHEMA_VERSION": "repro.measure.record",
    "MeasurementRecord": "repro.measure.record",
    "SOURCE_EXECUTOR": "repro.measure.record",
    "SOURCE_FUSED": "repro.measure.record",
    "SOURCE_SIMULATOR": "repro.measure.record",
    "record_for_op": "repro.measure.record",
    "usable_for_fidelity": "repro.measure.record",
    "DEFAULT_STORE_DIR": "repro.measure.store",
    "MeasurementStore": "repro.measure.store",
    "AffineCorrection": "repro.measure.calibrate",
    "CalibratedPredictor": "repro.measure.calibrate",
    "Calibrator": "repro.measure.calibrate",
    "fidelity_error": "repro.measure.calibrate",
    "DriftMonitor": "repro.measure.drift",
    "windowed_drift": "repro.measure.drift",
    "DecisionChange": "repro.measure.replan",
    "PlanDiff": "repro.measure.replan",
    "diff_plans": "repro.measure.replan",
    "replan": "repro.measure.replan",
    "score_decisions": "repro.measure.replan",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
