"""Predictor calibration from accumulated measurement records.

The planner's latency predictors model a *phone*; execution happens on
whatever host runs the plan.  The two are related but offset — the paper's
companion work (*Inference Latency Prediction at the Edge*) closes exactly
this gap with measured-on-device feedback.  A `Calibrator` is that
feedback loop: it fits per-(op-kind, mode) **affine corrections in log
space**

    log(wall_us)  ≈  a * log(pred_us) + b

from the records a `MeasurementStore` accumulated, and applies them to any
latency predictor **without retraining** (`wrap` returns a
`CalibratedPredictor` with the same `predict` contract).

Fitting is deliberately conservative: per group it scores three candidate
corrections — identity (a=1, b=0), pure log-shift (a=1, b=median of the
log-residuals, the exact L1 minimizer for a shift model), and an affine
least-squares fit (only with ≥3 spread-out points) — and keeps whichever
minimizes the summed |log wall - log cal| on the fitted records.  Because
identity is always a candidate, calibration can never *increase* the
fidelity error on the records it was fit from.

A calibrator is JSON-persistable (`save`/`load`) and content-addressed:
`version` digests the fitted coefficients, and the cached planners fold it
into plan provenance (`PlanProvenance.calibration`), so a refit calibrator
invalidates dependent plans instead of aliasing them.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.types import Op
from repro.kernels.registry import op_kind
from repro.measure.record import MeasurementRecord, usable_for_fidelity

CALIBRATION_SCHEMA_VERSION = 1

#: aggregate pseudo-mode: the per-kind fit over records of every mode
#: (what `CalibratedPredictor` applies when the mode is unknown at
#: predict time, i.e. during planning)
MODE_ANY = "*"


@dataclasses.dataclass(frozen=True)
class AffineCorrection:
    """log(cal_us) = a * log(pred_us) + b, fit on n records."""

    a: float
    b: float
    n: int

    def apply_us(self, pred_us: np.ndarray) -> np.ndarray:
        p = np.asarray(pred_us, dtype=float)
        safe = np.maximum(p, 1e-9)
        out = np.exp(self.a * np.log(safe) + self.b)
        # zero predictions stay zero: the partitioner's zero-channel
        # candidates and pool units carry no latency to correct
        return np.where(p > 0.0, out, 0.0)


#: minimum spread of the log-predictions (≈ a 1.65x ratio between the
#: cheapest and dearest fitted op) before the affine candidate is allowed:
#: on a tighter cluster the slope is unidentifiable — least-squares can
#: beat the shift *on the fitted records* with an extreme slope that then
#: extrapolates catastrophically to the planner's unseen candidate splits
MIN_AFFINE_SPREAD = 0.5


def _fit_group(logp: np.ndarray, logw: np.ndarray) -> AffineCorrection:
    """Best of {identity, L1-optimal shift, least-squares affine} by summed
    absolute log-residual — never worse than no correction."""
    cands = [(1.0, 0.0), (1.0, float(np.median(logw - logp)))]
    if len(logp) >= 3 and float(np.ptp(logp)) > MIN_AFFINE_SPREAD:
        A = np.vstack([logp, np.ones_like(logp)]).T
        coef, *_ = np.linalg.lstsq(A, logw, rcond=None)
        cands.append((float(coef[0]), float(coef[1])))
    a, b = min(cands,
               key=lambda ab: float(np.sum(np.abs(logw - (ab[0] * logp
                                                          + ab[1])))))
    return AffineCorrection(a=a, b=b, n=len(logp))


def fidelity_error(records: Iterable[MeasurementRecord],
                   calibrator: Optional["Calibrator"] = None) -> float:
    """Σ |log(wall/pred)| over usable records — the executed-vs-predicted
    fidelity error the acceptance metric tracks.  With a calibrator, the
    predictions are corrected first."""
    err = 0.0
    for r in records:
        if not usable_for_fidelity(r):
            continue
        pred = r.pred_us
        if calibrator is not None:
            pred = float(calibrator.correct_us(r.unit, r.mode, pred))
        if pred <= 0.0:
            continue
        err += abs(float(np.log(r.wall_us / pred)))
    return err


class Calibrator:
    """Per-(op-kind, mode) affine latency corrections, fit from records."""

    def __init__(self,
                 corrections: Dict[Tuple[str, str], AffineCorrection],
                 n_records: int = 0):
        self.corrections = dict(corrections)
        self.n_records = n_records

    # ----------------------------------------------------------- fitting
    @staticmethod
    def fit(records: Iterable[MeasurementRecord]) -> "Calibrator":
        """Fit per-(kind, mode) corrections plus a per-kind aggregate
        (mode `*`) from every usable record.

        The aggregate is what `CalibratedPredictor` applies to *per-
        backend* predictions at planning time, so it is fit only on
        records whose (pred, wall) pair describes an unsplit full-op
        execution (`exclusive`, `simulated`).  Co-executed records are
        unit totals — max-of-shards + sync overhead + deferred gather —
        and pairing them with per-shard predictions would encode that
        overhead into every candidate split; they still get their own
        (kind, "coexec") correction for fidelity accounting.
        """
        groups: Dict[Tuple[str, str], list] = {}
        usable = 0
        for r in records:
            if not usable_for_fidelity(r):
                continue
            usable += 1
            pair = (float(np.log(r.pred_us)), float(np.log(r.wall_us)))
            groups.setdefault((r.unit, r.mode), []).append(pair)
            if r.mode != "coexec":
                groups.setdefault((r.unit, MODE_ANY), []).append(pair)
        if usable == 0:
            raise ValueError("cannot fit a Calibrator from zero usable "
                             "records (need wall_us > 0 and pred_us > 0)")
        corrections = {}
        for key, pairs in groups.items():
            arr = np.asarray(pairs, dtype=float)
            corrections[key] = _fit_group(arr[:, 0], arr[:, 1])
        return Calibrator(corrections, n_records=usable)

    # ---------------------------------------------------------- applying
    def correction_for(self, kind: str, mode: str
                       ) -> Optional[AffineCorrection]:
        """The (kind, mode) correction, falling back to the per-kind
        aggregate; None when the kind was never measured."""
        return (self.corrections.get((kind, mode))
                or self.corrections.get((kind, MODE_ANY)))

    def correct_us(self, kind: str, mode: str, pred_us) -> np.ndarray:
        corr = self.correction_for(kind, mode)
        if corr is None:
            return np.asarray(pred_us, dtype=float)
        return corr.apply_us(pred_us)

    def fidelity_error(self, records: Iterable[MeasurementRecord]) -> float:
        """Calibrated fidelity error of `records` (see `fidelity_error`)."""
        return fidelity_error(records, self)

    def compose(self, inner: Optional["Calibrator"]) -> "Calibrator":
        """`self ∘ inner`: the calibrator equivalent to applying `inner`
        first, then `self` — affine-in-log corrections compose to affine.

        This is what *re*-calibration needs: records measured under a
        plan that already embeds `inner` carry `pred_us = inner(raw)`, so
        a calibrator fit from them maps inner-corrected predictions to
        walls.  Applying that fit to the raw predictors (which is what
        `wrap`/replanning does) silently drops `inner`; composing first
        yields corrections valid on raw predictions.  `inner=None` is the
        identity (a first calibration)."""
        if inner is None:
            return self
        out: Dict[Tuple[str, str], AffineCorrection] = {}
        for key in set(self.corrections) | set(inner.corrections):
            o = self.corrections.get(key, AffineCorrection(1.0, 0.0, 0))
            i = inner.corrections.get(key, AffineCorrection(1.0, 0.0, 0))
            out[key] = AffineCorrection(a=o.a * i.a, b=o.a * i.b + o.b,
                                        n=o.n or i.n)
        return Calibrator(out, n_records=self.n_records)

    def wrap(self, predictor) -> "CalibratedPredictor":
        """Wrap any latency predictor (LatencyPredictor or MuxPredictor)
        with these corrections — no retraining.  Wrapping an already
        calibrated predictor re-wraps the inner one (corrections never
        stack)."""
        if isinstance(predictor, CalibratedPredictor):
            predictor = predictor.inner
        return CalibratedPredictor(inner=predictor, calibration=self)

    # ------------------------------------------------------------ codecs
    @property
    def version(self) -> str:
        """Content digest of the fitted coefficients — what plan-cache
        provenance records (`PlanProvenance.calibration`)."""
        blob = json.dumps(self.to_json(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.blake2b(blob.encode(), digest_size=12).hexdigest()

    def to_json(self) -> Dict[str, object]:
        return {"schema_version": CALIBRATION_SCHEMA_VERSION,
                "n_records": self.n_records,
                "corrections": [
                    {"unit": k[0], "mode": k[1], "a": c.a, "b": c.b,
                     "n": c.n}
                    for k, c in sorted(self.corrections.items())]}

    @staticmethod
    def from_json(d: Dict[str, object]) -> "Calibrator":
        if d.get("schema_version") != CALIBRATION_SCHEMA_VERSION:
            raise ValueError(f"unsupported calibration schema "
                             f"{d.get('schema_version')!r}")
        corrections = {
            (e["unit"], e["mode"]): AffineCorrection(a=e["a"], b=e["b"],
                                                     n=e["n"])
            for e in d["corrections"]}
        return Calibrator(corrections, n_records=int(d.get("n_records", 0)))

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path

    @staticmethod
    def load(path: Union[str, Path]) -> "Calibrator":
        return Calibrator.from_json(json.loads(Path(path).read_text()))

    def summary(self) -> str:
        lines = [f"calibrator {self.version}: "
                 f"{len(self.corrections)} corrections from "
                 f"{self.n_records} records"]
        for (kind, mode), c in sorted(self.corrections.items()):
            lines.append(f"  {kind}/{mode}: log_wall ~= {c.a:.3f}*log_pred "
                         f"{c.b:+.3f}  (n={c.n})")
        return "\n".join(lines)


@dataclasses.dataclass
class CalibratedPredictor:
    """A latency predictor with measured-on-host corrections applied.

    Same `predict`/`device` contract as the wrapped predictor, so it drops
    into the batched planners unchanged; `runtime.plan.predictor_checksum`
    unwraps it (the calibration invalidates plans via the provenance
    `calibration` field instead).
    """

    inner: object                 # LatencyPredictor | MuxPredictor
    calibration: Calibrator

    @property
    def device(self) -> str:
        return self.inner.device

    def member(self, kind: str):
        """Per-kind member lookup, forwarded from the wrapped bundle —
        calibrating a `MuxPredictor` must not strip its ability to price
        attention/SSM typed-axis candidates (the planner gates those on
        `member(kind)`); returns None for plain per-kind predictors."""
        inner_member = getattr(self.inner, "member", None)
        if inner_member is None:
            return None
        return inner_member(kind)

    def predict(self, ops: Sequence[Op],
                tiles: Optional[Sequence] = None) -> np.ndarray:
        ops = list(ops)
        out = np.asarray(self.inner.predict(ops, tiles)
                         if tiles is not None else self.inner.predict(ops),
                         dtype=float).copy()
        kinds = np.array([op_kind(op) for op in ops])
        for kind in np.unique(kinds):
            sel = kinds == kind
            # the mode is unknown at predict time (planning scores every
            # candidate split); apply the per-kind aggregate fit
            out[sel] = self.calibration.correct_us(str(kind), MODE_ANY,
                                                   out[sel])
        return out
