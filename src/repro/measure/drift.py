"""Windowed fidelity drift and the replan trigger.

A serving fleet watches one scalar per plan execution: the mean
log(wall/pred) fidelity ratio.  Comparing the latest value against the
first (the pre-PR-8 `ServingEngine.drift`) is fragile — a single noisy
first run poisons the baseline forever, and a single noisy latest run
fires a false trigger.  `windowed_drift` compares a trailing-window
*median* against a baseline-window *median*, so isolated outliers on
either end are absorbed.

`DriftMonitor` turns the scalar into an actionable replan trigger with
hysteresis (re-arms only after drift falls back below
``threshold - hysteresis``) and a cooldown (minimum observations between
triggers), so a plan oscillating around the threshold cannot thrash the
planner.  The serving scheduler keeps one monitor per (batch, seq)
bucket and calls `measure.replan()` when a monitor fires.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import List, Optional, Sequence


def windowed_drift(values: Sequence[float], *, window: int = 4,
                   baseline: int = 4) -> Optional[float]:
    """Median of the trailing `window` values minus the median of the
    first `baseline` values (the latest value never enters the baseline,
    so two observations reproduce a latest-vs-first comparison at half
    scale).  None until two values exist.

    Units are whatever the inputs are — for fidelity logs, mean
    log(wall/pred), so 0.0 = stable and log(1.5) ~= 0.405 = "the plan
    runs 1.5x slower than it was priced"."""
    if len(values) < 2:
        return None
    base = statistics.median(list(values[:-1])[:baseline])
    trail = statistics.median(values[-window:])
    return trail - base


@dataclasses.dataclass
class DriftMonitor:
    """Hysteresis-and-cooldown wrapper around `windowed_drift`.

    `observe(value)` appends one fidelity observation and returns True
    when a replan should fire: drift above `threshold` while armed and
    out of cooldown.  After firing the monitor disarms until drift falls
    below ``threshold - hysteresis``; callers that replan in place should
    instead call `reset()` — the new plan starts a fresh baseline.
    """

    threshold: float = 0.35       # log-ratio units: ~1.4x slower
    hysteresis: float = 0.15
    window: int = 4
    baseline: int = 4
    cooldown: int = 6             # min observations between triggers
    values: List[float] = dataclasses.field(default_factory=list)
    armed: bool = True
    _last_trigger: int = -10**9

    @property
    def drift(self) -> Optional[float]:
        return windowed_drift(self.values, window=self.window,
                              baseline=self.baseline)

    def observe(self, value: float) -> bool:
        self.values.append(value)
        d = self.drift
        if d is None:
            return False
        if not self.armed:
            if d < self.threshold - self.hysteresis:
                self.armed = True
            return False
        if d > self.threshold and \
                len(self.values) - self._last_trigger >= self.cooldown:
            self.armed = False
            self._last_trigger = len(self.values)
            return True
        return False

    def reset(self) -> None:
        """Start a fresh baseline (call after an in-place replan: the new
        plan's fidelity history begins empty and the monitor re-arms)."""
        self.values.clear()
        self.armed = True
        self._last_trigger = -10**9
