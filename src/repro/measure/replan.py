"""Adaptive replanning: repair a compiled plan with calibrated predictors.

`replan(plan, cpu_pred, gpu_pred, calibrator, cache=...)` re-runs the
*cached* batch planners with calibration-wrapped predictors and returns
the new `CoexecPlan` plus a `PlanDiff` against the old one.  Because the
calibrator's version is folded into plan provenance, the new plan lands
under a **new** cache key — the old entry is untouched, and recompiling
with the same calibrator is a warm hit.

The diff scores *both* schedules under the calibrated predictors (the
best cost model available after measurement), so `predicted_gain_us` is
apples-to-apples: the old decisions are re-priced on the same grid the
new ones were chosen from, which also guarantees the gain is never
negative — the new schedule is the per-op argmin of that grid.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import numpy as np

from repro.core.partitioner import PartitionDecision
from repro.core.sync import SyncMechanism, sync_overhead_us
from repro.kernels import registry
from repro.measure.calibrate import Calibrator
from repro.runtime.cache import (PlanCache, partition_ops_plan_cached,
                                 plan_graph_cached)
from repro.runtime.plan import PLANNER_PREDICTOR, CoexecPlan, op_label


def score_decisions(decisions: List[PartitionDecision], cpu_pred, gpu_pred,
                    *, mechanism: SyncMechanism) -> np.ndarray:
    """Price a decision list under (possibly calibrated) predictors —
    the partitioner's objective, evaluated at fixed splits.

    Channel decisions (conv/linear) are priced at their `with_cout`
    sub-ops; typed-axis decisions (head / kv-block / ssm-state, and
    exclusive `none` placements) at their `axis_side_ops` sub-ops, with
    the same non-stackable merge surcharge `_axis_decide` charges — so a
    replanned attention/SSM schedule is re-priced on the grid it was
    chosen from."""
    if not decisions:
        return np.empty(0)
    from repro.core.partitioner import axis_side_ops
    from repro.core.simulator.devices import DEVICES
    gpu_ops, cpu_ops, extra = [], [], []
    for d in decisions:
        if d.axis == "channel":
            gpu_ops.append(d.op.with_cout(d.c_gpu))
            cpu_ops.append(d.op.with_cout(d.c_cpu))
            extra.append(0.0)
        else:
            g, c = axis_side_ops(d)
            gpu_ops.append(g)
            cpu_ops.append(c)
            stackable = d.exclusive or d.axis == "none" or registry.axis_spec(
                registry.op_kind(d.op), d.axis).stackable
            extra.append(0.0 if stackable else 2.0 * d.op.output_bytes)
    c_gpu = np.array([d.c_gpu for d in decisions])
    c_cpu = np.array([d.c_cpu for d in decisions])
    t_gpu = np.where(c_gpu > 0, gpu_pred.predict(gpu_ops), 0.0)
    t_cpu = np.where(c_cpu > 0, cpu_pred.predict(cpu_ops), 0.0)
    device = gpu_pred.device
    overhead = sync_overhead_us(device, mechanism)
    extra = np.asarray(extra)
    merge_us = extra / (DEVICES[device].cpu_mem_gbps * 1e3)
    merge_us = merge_us + np.where(extra > 0.0, overhead, 0.0)
    coexec = (c_gpu > 0) & (c_cpu > 0)
    return np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead + merge_us,
                                               0.0)


@dataclasses.dataclass(frozen=True)
class DecisionChange:
    """One op whose split moved between the old and the new plan."""

    index: int                   # schedule position
    label: str
    old_c_cpu: int
    old_c_gpu: int
    new_c_cpu: int
    new_c_gpu: int
    old_pred_us: float           # calibrated score of the old split
    new_pred_us: float           # calibrated score of the new split
    node_id: str = ""            # graph node id of the changed op

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class PlanDiff:
    """What replanning changed, priced under the calibrated predictors."""

    old_key: str
    new_key: str
    calibration: str             # calibrator version the new plan embeds
    n_ops: int
    changes: List[DecisionChange]
    old_total_us: float          # calibrated score of the old schedule
    new_total_us: float          # calibrated score of the new schedule

    @property
    def predicted_gain_us(self) -> float:
        return self.old_total_us - self.new_total_us

    def to_json(self) -> Dict[str, Any]:
        return {"old_key": self.old_key, "new_key": self.new_key,
                "calibration": self.calibration, "n_ops": self.n_ops,
                "old_total_us": self.old_total_us,
                "new_total_us": self.new_total_us,
                "predicted_gain_us": self.predicted_gain_us,
                "changes": [c.to_json() for c in self.changes]}

    def summary(self) -> str:
        head = (f"plan diff: {len(self.changes)}/{self.n_ops} ops changed, "
                f"predicted {self.old_total_us / 1e3:.2f} ms -> "
                f"{self.new_total_us / 1e3:.2f} ms "
                f"(gain {self.predicted_gain_us / 1e3:.2f} ms) "
                f"under calibration {self.calibration or '<none>'}")
        lines = [head,
                 f"  key {self.old_key} -> {self.new_key}"]
        for c in self.changes:
            tag = c.node_id or str(c.index)
            lines.append(
                f"  [{tag:>3}] {c.label:<42} cpu/gpu "
                f"{c.old_c_cpu}/{c.old_c_gpu} -> "
                f"{c.new_c_cpu}/{c.new_c_gpu} "
                f"(pred {c.old_pred_us:.1f} -> {c.new_pred_us:.1f} us)")
        return "\n".join(lines)


def diff_plans(old: CoexecPlan, new: CoexecPlan, cpu_pred, gpu_pred, *,
               mechanism: SyncMechanism,
               calibration: str = "") -> PlanDiff:
    """Per-op decision diff of two plans over the same network, priced
    under the given (typically calibrated) predictors."""
    if (old.provenance.network_fingerprint
            != new.provenance.network_fingerprint):
        raise ValueError("cannot diff plans over different networks "
                         f"({old.provenance.network_fingerprint} != "
                         f"{new.provenance.network_fingerprint})")
    old_dec, new_dec = old.decisions, new.decisions
    old_us = score_decisions(old_dec, cpu_pred, gpu_pred,
                             mechanism=mechanism)
    new_us = score_decisions(new_dec, cpu_pred, gpu_pred,
                             mechanism=mechanism)
    changes: List[DecisionChange] = []
    op_i = 0
    for idx, (nid, entry) in enumerate(zip(old.node_ids(), old.schedule)):
        if "decision" not in entry:      # pool/add: never partitioned
            continue
        o, n = old_dec[op_i], new_dec[op_i]
        if (o.c_cpu, o.c_gpu) != (n.c_cpu, n.c_gpu):
            changes.append(DecisionChange(
                index=idx, label=op_label(o.op),
                old_c_cpu=o.c_cpu, old_c_gpu=o.c_gpu,
                new_c_cpu=n.c_cpu, new_c_gpu=n.c_gpu,
                old_pred_us=float(old_us[op_i]),
                new_pred_us=float(new_us[op_i]),
                node_id=nid))
        op_i += 1
    return PlanDiff(old_key=old.key, new_key=new.key,
                    calibration=calibration, n_ops=len(old_dec),
                    changes=changes,
                    old_total_us=float(np.sum(old_us)),
                    new_total_us=float(np.sum(new_us)))


def replan(plan: CoexecPlan, cpu_pred, gpu_pred, calibrator: Calibrator, *,
           cache: PlanCache) -> Tuple[CoexecPlan, PlanDiff]:
    """Re-run the cached planner that produced `plan` with calibrated
    predictors; returns (new_plan, diff).

    The plan's own provenance selects the planning path: network/graph
    plans (threads > 0, pool units, or a non-chain graph) go through
    `plan_graph_cached` over the plan's own graph, bare-op plans through
    `partition_ops_plan_cached` — same mechanism, step and seed as the
    original, so the *only* provenance deltas are the calibration version
    (and any decision changes it causes).
    """
    prov = plan.provenance
    if prov.planner != PLANNER_PREDICTOR:
        raise ValueError(
            f"can only replan predictor-driven plans (planner="
            f"{prov.planner!r}); grid plans are measurement-driven")
    cp = calibrator.wrap(cpu_pred)
    gp = calibrator.wrap(gpu_pred)
    mech = SyncMechanism(prov.mechanism)
    graph = plan.graph_ir()
    is_chain = graph.is_unit_chain()
    has_pool = any(n.kind == "pool" for n in graph)
    if not is_chain or prov.threads > 0 or has_pool:
        # the bucket tag survives replanning: a portfolio entry's repaired
        # plan still answers for the same (batch, seq) bucket
        new = plan_graph_cached(graph, cp, gp, threads=prov.threads,
                                mechanism=mech, step=prov.step,
                                seed=prov.seed, bucket=prov.bucket,
                                cache=cache)
    else:
        new = partition_ops_plan_cached([n.op for n in graph], cp, gp,
                                        mechanism=mech, step=prov.step,
                                        cache=cache)
    diff = diff_plans(plan, new, cp, gp, mechanism=mech,
                      calibration=calibrator.version)
    return new, diff
