"""On-disk measurement store: append-only JSONL, keyed like the plan cache.

`MeasurementStore` persists `MeasurementRecord`s under one directory
(default `reports/measurements/`), one file per plan provenance digest —
the *same* keys `runtime/cache.PlanCache` uses, so a plan's file of
recorded executions sits next to (and is found from) its cached plan.

Files are append-only JSONL: every measured run appends one compact JSON
line per record, and nothing ever rewrites history — the accumulated log
is what the `Calibrator` fits on.  Corrupt lines are skipped on load,
never trusted (same policy as the plan cache).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Union

from repro.measure.record import MeasurementRecord

DEFAULT_STORE_DIR = "reports/measurements"

#: store key for records that carry no plan provenance
UNKEYED = "unkeyed"


class MeasurementStore:
    """Append-only JSONL store of measurement records, one file per key."""

    def __init__(self, root: Union[str, Path] = DEFAULT_STORE_DIR):
        self.root = Path(root)

    def path_for(self, key: str) -> Path:
        return self.root / f"{key}.jsonl"

    def append(self, records, key: Optional[str] = None) -> List[Path]:
        """Append records (or an object with `.timings`, e.g. an
        `ExecutionReport`) to the store.

        Without an explicit `key`, each record lands in the file of its
        own `plan_key` (records from different plans may be appended in
        one call).  Returns the paths written to.
        """
        if hasattr(records, "timings"):
            records = records.timings
        by_key: Dict[str, List[MeasurementRecord]] = {}
        for r in records:
            k = key if key is not None else (r.plan_key or UNKEYED)
            by_key.setdefault(k, []).append(r)
        paths = []
        self.root.mkdir(parents=True, exist_ok=True)
        for k, recs in by_key.items():
            path = self.path_for(k)
            with open(path, "a") as f:
                for r in recs:
                    f.write(json.dumps(r.to_json(),
                                       separators=(",", ":")) + "\n")
            paths.append(path)
        return paths

    def load(self, key: str) -> List[MeasurementRecord]:
        """All records appended under `key`, in append order (corrupt
        lines are skipped, never trusted)."""
        path = self.path_for(key)
        if not path.exists():
            return []
        out: List[MeasurementRecord] = []
        for line in path.read_text().splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                out.append(MeasurementRecord.from_json(json.loads(line)))
            except (ValueError, KeyError, TypeError):
                continue
        return out

    def load_all(self) -> List[MeasurementRecord]:
        """Every record in the store, across all keys."""
        out: List[MeasurementRecord] = []
        for key in self.keys():
            out.extend(self.load(key))
        return out

    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.jsonl"))

    def count(self, key: str) -> int:
        return len(self.load(key))
