"""The unified measurement schema: one record type for every timing.

Before this module, the repo had three disconnected timing formats: the
executor's per-op `OpTiming` (printed and dropped), the simulator's bare
floats (`measure_latency_us`), and the benchmark CSV rows.  None of them
could feed the others: an executed run could not become a predictor
training sample, and calibration had nothing stable to fit on.

`MeasurementRecord` is the one JSON-serializable schema they all share:

  * **what ran** — op kind + shape via the kernel registry codec
    (`op_to_json`/`op_from_json`), the split decision (`c_fast`/`c_slow`),
    the execution mode, and the chain/gather flags;
  * **the measurement** — `wall_us` (observed) vs `pred_us` (what the
    plan/oracle expected);
  * **provenance** — the measuring `source` ("executor" | "simulator"),
    the plan's simulated target `device`, the `backend` (simulator
    records), the measuring `host`, and the plan-cache digests
    (`plan_key`, `network_fingerprint`) that key the on-disk store.

Records round-trip bit-stably through JSON (`to_json` → `from_json` →
`to_json` is the identity; floats survive via repr-shortest encoding), so
an append-only JSONL store is a faithful log.  `features()` exposes the
registry's per-kind base features — the exact featurization the latency
predictors train on — which is what lets executed runs become training
samples with zero glue code (`core/predictor/dataset.training_from_records`).

This module is deliberately a leaf: it imports only the kernel registry
(itself jax-free), so the simulator, the predictors, the runtime, and the
benchmarks can all produce/consume records without import cycles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.types import Op
from repro.kernels.registry import op_from_json, op_kind, op_label, op_to_json

MEASUREMENT_SCHEMA_VERSION = 1

#: record sources
SOURCE_EXECUTOR = "executor"      # wall-clock timed plan execution
SOURCE_SIMULATOR = "simulator"    # analytic device-model measurement
SOURCE_FUSED = "fused"            # segment-walk execution: per-node wall is
                                  # the segment wall attributed pro-rata by
                                  # predicted latency

#: execution modes (executor) + the simulator's pseudo-mode
MODE_COEXEC = "coexec"
MODE_EXCLUSIVE = "exclusive"
MODE_POOL = "pool"
MODE_ADD = "add"                  # residual join of a graph plan
MODE_SIMULATED = "simulated"


@dataclasses.dataclass
class MeasurementRecord:
    """Executed(or simulated)-vs-predicted record for one measured unit.

    The first ten fields are the former executor `OpTiming` (same names,
    same order, so pre-refactor constructor calls keep working); the
    provenance tail is defaulted and filled in by whoever measures.
    """

    index: int                   # schedule position (or batch index)
    unit: str                    # registry op kind ("conv"|"linear"|
                                 # "attention"|"ssm") or "pool"|"add"
    label: str
    mode: str                    # coexec | exclusive | pool | add | simulated
    c_fast: int                  # GPU-analogue channel share (0 = unsplit)
    c_slow: int                  # CPU-analogue channel share
    chained_input: bool          # consumed the producer's group-local stack
    gathered_output: bool        # output materialized (reshard point)
    wall_us: float               # observed latency
    pred_us: float               # predicted/oracle latency (0 = none)
    op: Optional[Op] = None      # the measured op (None for pool units)
    source: str = SOURCE_EXECUTOR
    device: str = ""             # simulated target device of the plan
    backend: str = ""            # simulator records: "gpu" | "cpuN"
    host: str = ""               # platform.node() of the measuring host
    plan_key: str = ""           # PlanProvenance digest (the store key)
    network_fingerprint: str = ""
    node_id: str = ""            # graph node id ("" for bare-op records)
    segment: int = -1            # fused segment index (-1 = per-node walk)
    schema_version: int = MEASUREMENT_SCHEMA_VERSION

    def features(self) -> Optional[List[float]]:
        """The kernel registry's base features of the measured op — the
        predictors' training featurization (None for pool units)."""
        if self.op is None:
            return None
        from repro.kernels import registry
        return registry.entry_for(self.op).base_features(self.op)

    def to_json(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["op"] = None if self.op is None else op_to_json(self.op)
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "MeasurementRecord":
        d = dict(d)
        if d.get("op") is not None:
            d["op"] = op_from_json(d["op"])
        return MeasurementRecord(**d)


def usable_for_fidelity(record: MeasurementRecord) -> bool:
    """The one fidelity filter: a record contributes to Σ |log(wall/pred)|
    iff both sides are positive and it is not a pool unit (pools carry no
    prediction to compare against).  Shared by `ExecutionReport`
    (`fidelity_error`/`mean_log_ratio`) and `repro.measure.calibrate`
    (fitting + `fidelity_error`), so the acceptance metric cannot drift
    between the two."""
    return (record.wall_us > 0.0 and record.pred_us > 0.0
            and record.unit != "pool")


def record_for_op(op: Op, *, index: int = 0, wall_us: float, pred_us: float,
                  mode: str = MODE_SIMULATED, source: str = SOURCE_SIMULATOR,
                  device: str = "", backend: str = "", host: str = "",
                  plan_key: str = "", network_fingerprint: str = ""
                  ) -> MeasurementRecord:
    """Build a record for a bare op (kind/label via the registry)."""
    return MeasurementRecord(
        index=index, unit=op_kind(op), label=op_label(op), mode=mode,
        c_fast=0, c_slow=0, chained_input=False, gathered_output=True,
        wall_us=float(wall_us), pred_us=float(pred_us), op=op,
        source=source, device=device, backend=backend, host=host,
        plan_key=plan_key, network_fingerprint=network_fingerprint)
