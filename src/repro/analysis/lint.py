"""Repo-contract linter: machine-check the conventions the repo relies on.

Three contracts, accumulated over the PR history and until now enforced
only by subprocess tests or review:

  * ``lint.import-light``    — the planning/graph/measure/serving layers
    must not import jax at module top level.  Planning runs on the
    serving control plane and in CI containers without an accelerator;
    one stray top-level ``import jax`` there drags ~2s of backend init
    into every `repro plan` invocation and breaks the jax-free
    subprocess tests.  Function-local imports and ``if TYPE_CHECKING:``
    blocks are fine.
  * ``lint.registry-complete`` — every registered op kind must carry the
    full contract surface: shape/feature callables, a codec entry, a
    tile spec, a registered lowering module, and either channel
    splittability or declared typed axes.  A half-registered kind
    compiles plans the executor cannot lower.
  * ``lint.no-silent-clamp`` — kernel entry points must not
    ``min()``-clamp user-provided tile parameters.  An illegal tile is a
    caller bug; silently shrinking it makes autotune measurements lie
    about the config they claim to measure (the PR 9 rule — validation
    lives in `kernels.tiles.check_tile`, which raises).

Pure stdlib + the jax-free registry; `python -m repro lint` never
imports jax (subprocess-tested alongside the verifier).
"""
from __future__ import annotations

import ast
import fnmatch
from pathlib import Path
from typing import List, Optional, Set

from repro.analysis.verify import SEV_ERROR, Diagnostic

LINT_RULES = {
    "lint.import-light": "no top-level jax imports in planning/graph/"
                         "measure/serving modules",
    "lint.registry-complete": "every op kind has codec + features + "
                              "tiles + lowering + axes-or-splittable",
    "lint.no-silent-clamp": "kernel entry points never min()-clamp "
                            "user tile params",
}

#: modules (relative to the repro package) bound by the import-light
#: contract.  Execution layers (runtime/executor, runtime/segments,
#: core/coexec, kernels/*/ops, launch/, models/, serving is control-plane
#: so it IS bound) are exempt by omission.
IMPORT_LIGHT_GLOBS = (
    "__init__.py", "__main__.py", "api.py", "cli.py",
    "graph/*.py", "measure/*.py", "serving/*.py", "analysis/*.py",
    "core/*.py", "core/predictor/*.py", "core/simulator/*.py",
    "runtime/__init__.py", "runtime/plan.py", "runtime/cache.py",
    "runtime/autotune.py",
    "kernels/__init__.py", "kernels/registry.py", "kernels/tiles.py",
)

#: core/coexec.py is the execution sync layer — it owns the device
#: streams the paper's co-execution mechanisms synchronize, so it is
#: jax-bound by design even though it lives under core/.
IMPORT_LIGHT_EXEMPT = {"core/coexec.py"}

#: parameter names that carry user tile choices into kernel entry points
_TILE_PARAM_NAMES = {"tile", "tiles", "bm", "bn", "bk", "bs", "chunk"}


def _err(rule: str, node: str, message: str, hint: str = "") -> Diagnostic:
    return Diagnostic(SEV_ERROR, rule, node, message, hint)


def package_root() -> Path:
    """The repro package directory the default lint run scans."""
    return Path(__file__).resolve().parents[1]


# --------------------------------------------------------- import-light

def _is_type_checking(test: ast.expr) -> bool:
    return (isinstance(test, ast.Name) and test.id == "TYPE_CHECKING") or \
        (isinstance(test, ast.Attribute) and test.attr == "TYPE_CHECKING")


def _jax_imports(tree: ast.Module) -> List[int]:
    """Line numbers of module-scope jax imports (TYPE_CHECKING-guarded
    blocks excluded; function bodies are not module scope)."""
    lines: List[int] = []

    def visit(stmts, guarded: bool) -> None:
        for s in stmts:
            if isinstance(s, ast.Import):
                if not guarded and any(
                        a.name == "jax" or a.name.startswith("jax.")
                        for a in s.names):
                    lines.append(s.lineno)
            elif isinstance(s, ast.ImportFrom):
                mod = s.module or ""
                if not guarded and (mod == "jax" or
                                    mod.startswith("jax.")):
                    lines.append(s.lineno)
            elif isinstance(s, ast.If):
                visit(s.body, guarded or _is_type_checking(s.test))
                visit(s.orelse, guarded)
            elif isinstance(s, ast.Try):
                for blk in [s.body, s.orelse, s.finalbody,
                            *[h.body for h in s.handlers]]:
                    visit(blk, guarded)
            elif isinstance(s, (ast.With, ast.ClassDef)):
                visit(s.body, guarded)

    visit(tree.body, False)
    return lines


def lint_import_light(pkg: Path) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for path in sorted(pkg.rglob("*.py")):
        rel = path.relative_to(pkg).as_posix()
        if rel in IMPORT_LIGHT_EXEMPT:
            continue
        if not any(fnmatch.fnmatch(rel, g) for g in IMPORT_LIGHT_GLOBS):
            continue
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError as e:
            diags.append(_err("lint.import-light", f"{rel}:{e.lineno}",
                              f"does not parse: {e.msg}"))
            continue
        for lineno in _jax_imports(tree):
            diags.append(_err(
                "lint.import-light", f"{rel}:{lineno}",
                "top-level jax import in an import-light module",
                "move the import inside the functions that use it (or "
                "under `if TYPE_CHECKING:` for annotations)"))
    return diags


# -------------------------------------------------- registry completeness

def lint_registry(pkg: Path) -> List[Diagnostic]:
    from repro.kernels import registry
    diags: List[Diagnostic] = []
    kinds = registry.kinds()
    codec_kinds = set(registry._KIND_BY_TYPE.values())
    if codec_kinds != set(kinds):
        diags.append(_err(
            "lint.registry-complete", "registry",
            f"op codec covers {sorted(codec_kinds)} but the registry "
            f"declares {kinds}"))
    for kind in kinds:
        entry = registry.get(kind)
        loc = f"registry:{kind}"
        for field in ("input_shape", "weight_shape", "output_shape",
                      "base_features"):
            if not callable(getattr(entry, field, None)):
                diags.append(_err("lint.registry-complete", loc,
                                  f"kind lacks a callable {field!r}"))
        if not entry.splittable and not entry.axes:
            diags.append(_err(
                "lint.registry-complete", loc,
                "kind is neither channel-splittable nor declares typed "
                "axes — the planner can never co-execute or even place "
                "it deliberately",
                "declare AxisSpecs or set splittable=True"))
        try:
            registry.tile_spec(kind)
        except KeyError:
            diags.append(_err("lint.registry-complete", loc,
                              "kind has no TileSpec",
                              "register it in _TILE_SPECS"))
        if entry.modes and registry.default_mode(kind) != entry.modes[0]:
            diags.append(_err("lint.registry-complete", loc,
                              "default_mode disagrees with the entry's "
                              "declared mode order"))
        mod = registry._LOWERING_MODULES.get(kind)
        if mod is None:
            diags.append(_err("lint.registry-complete", loc,
                              "kind has no lowering module mapping",
                              "add it to _LOWERING_MODULES"))
            continue
        # the ops module imports jax, so check the registration call
        # textually instead of importing it
        ops_path = pkg / Path(*mod.split(".")[1:]).with_suffix(".py")
        if not ops_path.is_file():
            diags.append(_err("lint.registry-complete", loc,
                              f"lowering module {mod} has no source file"))
        elif f'register_lowering("{kind}"' not in ops_path.read_text():
            diags.append(_err(
                "lint.registry-complete", loc,
                f"lowering module {mod} never calls "
                f"register_lowering({kind!r})"))
    return diags


# --------------------------------------------------------- no-silent-clamp

def lint_silent_clamp(pkg: Path) -> List[Diagnostic]:
    diags: List[Diagnostic] = []
    for path in sorted((pkg / "kernels").rglob("*.py")):
        if path.name in ("registry.py", "tiles.py", "__init__.py"):
            continue
        rel = path.relative_to(pkg).as_posix()
        try:
            tree = ast.parse(path.read_text())
        except SyntaxError:
            continue                       # import-light pass reports these
        for fn in [n for n in ast.walk(tree)
                   if isinstance(n, (ast.FunctionDef,
                                     ast.AsyncFunctionDef))]:
            args = fn.args
            params: Set[str] = {a.arg for a in
                                [*args.posonlyargs, *args.args,
                                 *args.kwonlyargs]} & _TILE_PARAM_NAMES
            if not params:
                continue
            for call in ast.walk(fn):
                if not (isinstance(call, ast.Call)
                        and isinstance(call.func, ast.Name)
                        and call.func.id == "min"):
                    continue
                touched = {n.id for a in call.args
                           for n in ast.walk(a)
                           if isinstance(n, ast.Name)} & params
                if touched:
                    diags.append(_err(
                        "lint.no-silent-clamp",
                        f"{rel}:{call.lineno}",
                        f"{fn.name}() min()-clamps tile param(s) "
                        f"{sorted(touched)}",
                        "validate via kernels.tiles.check_tile (raise on "
                        "illegal) instead of silently shrinking"))
    return diags


# ----------------------------------------------------------------- driver

def lint_repo(pkg: Optional[Path] = None) -> List[Diagnostic]:
    """Run every repo-contract lint over the repro package tree."""
    pkg = package_root() if pkg is None else Path(pkg)
    diags: List[Diagnostic] = []
    diags.extend(lint_import_light(pkg))
    diags.extend(lint_registry(pkg))
    diags.extend(lint_silent_clamp(pkg))
    return diags
