"""Static analysis over plan artifacts and the repo's own contracts.

`analysis.verify` proves co-execution invariants over serialized plans
without importing jax or executing anything; `analysis.lint` enforces
the repo contracts (import-light modules, registry completeness,
no-silent-clamp) over the source tree.  Both back the `repro verify` /
`repro lint` CLI commands and the strict-load paths in `runtime.plan`,
`runtime.cache`, and `api`.
"""
import logging
from typing import Dict, List, Tuple

from repro.analysis.verify import (RULES, SEV_ERROR, SEV_INFO, SEV_WARNING,
                                   Diagnostic, PlanStats, VerificationError,
                                   errors, plan_stats, raise_on_error,
                                   verify_artifact, verify_bench_report,
                                   verify_path, verify_plan,
                                   verify_portfolio, verify_tune_entry)

__all__ = [
    "RULES", "SEV_ERROR", "SEV_INFO", "SEV_WARNING",
    "Diagnostic", "PlanStats", "VerificationError",
    "errors", "plan_stats", "raise_on_error",
    "verify_artifact", "verify_bench_report", "verify_path",
    "verify_plan", "verify_portfolio", "verify_tune_entry",
    "RejectionLog", "rejections",
]

_log = logging.getLogger("repro.analysis")


class RejectionLog:
    """Process-wide record of cache entries rejected by verification.

    PlanCache/TuneCache historically degraded corrupt or mismatched
    entries to a *silent* miss; this log records which rule (or which
    provenance/key field) failed, warns once per digest, and lets the
    CLI surface counts (`repro plan -v`, bench run summaries).
    """

    def __init__(self):
        self._seen: Dict[str, Tuple[str, str]] = {}   # digest -> (rule, why)
        self._counts: Dict[str, int] = {}             # rule -> rejections

    def record(self, digest: str, rule: str, detail: str = "") -> None:
        if digest in self._seen:
            return                         # warn once per digest
        self._seen[digest] = (rule, detail)
        self._counts[rule] = self._counts.get(rule, 0) + 1
        why = f": {detail}" if detail else ""
        _log.warning("cache entry %s rejected by %s%s", digest, rule, why)

    def counts(self) -> Dict[str, int]:
        return dict(self._counts)

    def total(self) -> int:
        return sum(self._counts.values())

    def entries(self) -> List[Tuple[str, str, str]]:
        return [(digest, rule, detail)
                for digest, (rule, detail) in sorted(self._seen.items())]

    def summary(self) -> str:
        if not self._counts:
            return "cache rejections: none"
        parts = ", ".join(f"{rule} x{n}"
                          for rule, n in sorted(self._counts.items()))
        return f"cache rejections: {self.total()} ({parts})"

    def clear(self) -> None:
        self._seen.clear()
        self._counts.clear()


#: process-wide singleton the cache layers report into
rejections = RejectionLog()
