"""Static plan/IR verifier: prove co-execution invariants without running.

Nine PRs of conventions — omitted-when-default plan JSON, registry-routed
axis/tile legality, the segment compiler's one-gather-per-fused-segment
contract, content-addressed provenance digests — are all enforced
dynamically today (the executor raises, or a differential test catches
the drift).  This module re-proves them *statically* over the serialized
artifact: `verify_plan` takes a plan (a `CoexecPlan` or its raw JSON
document) and returns structured `Diagnostic`s, so a plan compiled on one
host can be rejected on another before its first execution.

Everything here is pure Python over the jax-free planning layers
(`graph.ir`, `kernels.registry`, `runtime.plan`): `python -m repro
verify` never imports jax (subprocess-tested), matching the import-light
contract the companion linter (`analysis.lint`) enforces on the repo.

Checks, by rule family:

  * ``schema.*``       — document shape, schema versions, and the
    byte-compat discipline: keys that the codecs omit at their defaults
    (``axis`` at "channel", ``tile`` at the default blocking, op ``mode``
    at the kind default, empty provenance calibration/bucket/tune tags,
    ``id`` keys on unit-chain schedules) must not be present.
  * ``axis.*``         — split legality re-derived from the registry
    (`validate_axis_split`) plus share accounting (channel shares sum to
    C_out, typed-axis shares sum to the axis size).
  * ``tile.*``         — tile configs re-validated against the registry
    `TileSpec` (alignment, padded extents, VMEM budget).
  * ``graph.*``        — embedded graph validity, schedule/graph
    agreement, and recomputation of the content-addressed fingerprint
    against `provenance.network_fingerprint`.
  * ``segment.*``      — the embedded segment partition must cover the
    schedule, equal the re-derived `Graph.segments` partition, and
    independently satisfy convexity, the one-gather-per-fused-segment
    rule, and gather-elision soundness (sole-consumer rule).
  * ``provenance.*``   — the plan-cache digest recomputed from the
    embedded provenance fields must equal the expected key (the cache
    filename).
  * ``resource.*``     — info-severity static resource accounting:
    per-device peak activation liveness from a refcounted topological
    walk, sync-point count, and boundary traffic bytes.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.graph.ir import (SEGMENT_EXCLUSIVE, SEGMENT_FUSED, SEGMENT_POOL,
                            Graph, Node, Segment)
from repro.kernels import registry
from repro.runtime.plan import PLAN_SCHEMA_VERSION, PlanProvenance

SEV_ERROR = "error"
SEV_WARNING = "warning"
SEV_INFO = "info"

#: rule id -> one-line description (docs/ARCHITECTURE.md renders this)
RULES: Dict[str, str] = {
    "schema.version": "plan/artifact schema version is supported and "
                      "consistent with the embedded provenance",
    "schema.malformed": "document shape: required keys, entry arity, "
                        "op/decision field types parse",
    "schema.default-key": "omitted-when-default byte-compat: no key "
                          "serialized at its default value",
    "axis.legality": "partition axis legal for the op "
                     "(registry.validate_axis_split)",
    "axis.shares": "split shares account for the full axis "
                   "(c_cpu + c_gpu == axis size; exclusive = one side)",
    "tile.legality": "tile config legal for the op "
                     "(alignment, padded extents, VMEM budget)",
    "graph.invalid": "embedded graph validates (ids, arity, acyclicity, "
                     "single output)",
    "graph.schedule": "schedule entries agree with the graph "
                      "(ids, kinds, ops, pool bytes, topological order)",
    "graph.fingerprint": "recomputed graph fingerprint equals "
                         "provenance.network_fingerprint",
    "segment.cover": "embedded segments cover the schedule exactly, "
                     "in topological order",
    "segment.mismatch": "embedded segments equal the re-derived "
                        "Graph.segments partition",
    "segment.convexity": "every non-final node of a fused segment has all "
                         "consumers inside the segment",
    "segment.gather": "fused segments contain only co-executed or add "
                      "nodes (one gather, at the final node)",
    "segment.elision": "interior co-executed nodes satisfy the "
                       "sole-consumer gather-elision predicate",
    "provenance.digest": "recomputed provenance digest equals the "
                         "expected cache key",
    "provenance.mismatch": "cached plan's embedded provenance equals the "
                           "requested one (cache-layer rule)",
    "artifact.format": "artifact format/version markers are supported",
    "artifact.checksum": "recomputed artifact checksum matches",
    "portfolio.bucket": "portfolio entry bucket tag matches its plan's "
                        "provenance bucket",
    "bench.schema": "bench report carries the suite/metrics schema",
    "bench.metric": "bench metrics are finite non-negative numbers",
    "resource.accounting": "static resource accounting (info): peak "
                           "liveness, sync points, boundary traffic",
}


@dataclasses.dataclass(frozen=True)
class Diagnostic:
    """One verifier finding: severity + rule id + location + fix hint."""

    severity: str                  # error | warning | info
    rule: str                      # e.g. "axis.legality"
    node: str                      # node id / entry index ("" = plan-level)
    message: str
    hint: str = ""

    def __str__(self) -> str:
        loc = f" [{self.node}]" if self.node else ""
        tail = f" (hint: {self.hint})" if self.hint else ""
        return f"{self.severity}: {self.rule}{loc}: {self.message}{tail}"


def errors(diags: List[Diagnostic]) -> List[Diagnostic]:
    return [d for d in diags if d.severity == SEV_ERROR]


class VerificationError(ValueError):
    """Raised by strict loads on error-severity diagnostics; carries the
    full diagnostic list so cache layers can log *which* rule failed."""

    def __init__(self, context: str, diagnostics: List[Diagnostic]):
        self.diagnostics = list(diagnostics)
        errs = errors(self.diagnostics)
        head = "; ".join(str(d) for d in errs[:3])
        more = f" (+{len(errs) - 3} more)" if len(errs) > 3 else ""
        super().__init__(f"{context} failed static verification: "
                         f"{head}{more}")


def raise_on_error(diags: List[Diagnostic], context: str) -> None:
    if errors(diags):
        raise VerificationError(context, diags)


# -------------------------------------------------------------- the verifier

def _err(rule: str, node: str, message: str, hint: str = "") -> Diagnostic:
    return Diagnostic(SEV_ERROR, rule, node, message, hint)


def verify_plan(plan, *, graph: Optional[Graph] = None,
                expect_key: Optional[str] = None,
                stats: bool = True) -> List[Diagnostic]:
    """Statically verify one plan (a `CoexecPlan` or its raw JSON doc).

    Never raises on a bad plan — every violation becomes a `Diagnostic`
    (malformed documents yield ``schema.malformed`` errors rather than
    exceptions).  `expect_key` is the provenance digest the plan is filed
    under (the cache filename stem); when given, the digest is recomputed
    from the embedded fields and compared.  `graph` overrides the graph
    the structural checks run against (default: the embedded/derived
    one).  ``stats=False`` skips the info-severity resource accounting.
    """
    if hasattr(plan, "to_json") and hasattr(plan, "provenance"):
        doc = plan.to_json()
    elif isinstance(plan, dict):
        doc = plan
    else:
        return [_err("schema.malformed", "",
                     f"not a plan document: {type(plan).__name__}")]

    diags: List[Diagnostic] = []
    schedule = doc.get("schedule")
    if not isinstance(schedule, list) or "provenance" not in doc:
        diags.append(_err("schema.malformed", "",
                          "plan document needs 'provenance' and a "
                          "'schedule' list"))
        return diags

    prov = _check_provenance(doc, diags)
    entries = _check_schedule(doc, schedule, diags)
    g = graph if graph is not None else _plan_graph(doc, entries, diags)

    if g is not None:
        _check_graph(doc, g, prov, entries, diags)
        coexec = frozenset(e.node for e in entries if e.coexec)
        _check_segments(doc, g, coexec, entries, diags)
        if stats and not errors(diags):
            st = _stats_from(g, entries, coexec)
            diags.append(Diagnostic(SEV_INFO, "resource.accounting", "",
                                    st.summary()))
    if expect_key is not None and prov is not None and prov.key != expect_key:
        diags.append(_err(
            "provenance.digest", "",
            f"recomputed provenance digest {prov.key} != expected "
            f"{expect_key}",
            "the plan was edited after it was keyed, or filed under the "
            "wrong name; recompile instead of patching the JSON"))
    return diags


# ------------------------------------------------------------- provenance

def _check_provenance(doc: Dict[str, Any],
                      diags: List[Diagnostic]) -> Optional[PlanProvenance]:
    raw = doc.get("provenance")
    if not isinstance(raw, dict):
        diags.append(_err("schema.malformed", "",
                          "'provenance' must be an object"))
        return None
    for field in ("calibration", "bucket", "tune"):
        if field in raw and not raw[field]:
            diags.append(_err(
                "schema.default-key", "",
                f"provenance {field!r} serialized at its empty default",
                "PlanProvenance._canonical omits empty tags so legacy "
                "digests stay warm"))
    try:
        prov = PlanProvenance.from_json(
            {k: v for k, v in raw.items()})
    except TypeError as e:
        diags.append(_err("schema.malformed", "",
                          f"provenance does not parse: {e}"))
        return None
    if doc.get("schema_version") != prov.schema_version:
        diags.append(_err(
            "schema.version", "",
            f"document schema_version {doc.get('schema_version')!r} != "
            f"provenance schema_version {prov.schema_version!r}"))
    if prov.schema_version != PLAN_SCHEMA_VERSION:
        diags.append(_err(
            "schema.version", "",
            f"unsupported plan schema version {prov.schema_version!r} "
            f"(supported: {PLAN_SCHEMA_VERSION})"))
    return prov


# --------------------------------------------------------------- schedule

@dataclasses.dataclass
class _Entry:
    """One parsed schedule entry (raw dict + derived planning facts)."""

    index: int
    node: str                       # node id ("n{i}" when entries carry none)
    unit: str
    raw: Dict[str, Any]
    op: Any = None                  # parsed Op (None for pool/add/bad ops)
    coexec: bool = False            # channel-split co-executed (fusable)
    pool_bytes: int = 0


def _check_schedule(doc: Dict[str, Any], schedule: List[Any],
                    diags: List[Diagnostic]) -> List[_Entry]:
    has_graph = doc.get("graph") is not None
    entries: List[_Entry] = []
    for i, e in enumerate(schedule):
        if not isinstance(e, dict) or "unit" not in e:
            diags.append(_err("schema.malformed", f"#{i}",
                              "schedule entry needs a 'unit' key"))
            continue
        nid = e.get("id", f"n{i}")
        if "id" in e and not has_graph:
            diags.append(_err(
                "schema.default-key", nid,
                "unit-chain schedules omit 'id' keys (canonical n{i} "
                "positions)", "see runtime.plan.build_graph_schedule"))
        if "id" not in e and has_graph:
            diags.append(_err("schema.malformed", f"#{i}",
                              "graph plans carry explicit 'id' keys"))
        ent = _Entry(index=i, node=nid, unit=e["unit"], raw=e)
        entries.append(ent)
        if e["unit"] == "pool":
            if not isinstance(e.get("bytes"), int) or e["bytes"] <= 0:
                diags.append(_err("schema.malformed", nid,
                                  "pool entry needs a positive integer "
                                  "'bytes'"))
            else:
                ent.pool_bytes = e["bytes"]
            continue
        if e["unit"] == "add":
            continue
        if e["unit"] not in registry.kinds():
            diags.append(_err("schema.malformed", nid,
                              f"unknown unit kind {e['unit']!r} "
                              f"(known: {registry.kinds()})"))
            continue
        if "decision" in e:
            _check_decision(ent, e["decision"], diags)
        elif "op" in e:                      # legacy opaque exclusive node
            ent.op = _parse_op(e["unit"], e["op"], nid, diags)
        else:
            diags.append(_err("schema.malformed", nid,
                              "op entry needs a 'decision' (or legacy "
                              "'op' + 'pred_us')"))
    return entries


def _parse_op(unit: str, op_json: Any, nid: str,
              diags: List[Diagnostic]):
    if not isinstance(op_json, dict) or "kind" not in op_json:
        diags.append(_err("schema.malformed", nid,
                          "op JSON must be an object with a 'kind'"))
        return None
    if op_json["kind"] != unit:
        diags.append(_err("schema.malformed", nid,
                          f"entry unit {unit!r} != op kind "
                          f"{op_json['kind']!r}"))
        return None
    if op_json.get("mode") == registry.default_mode(unit):
        diags.append(_err(
            "schema.default-key", nid,
            f"op 'mode' serialized at its default "
            f"{registry.default_mode(unit)!r}",
            "registry.op_to_json omits the default mode"))
    try:
        return registry.op_from_json(op_json)
    except (ValueError, KeyError, TypeError) as e:
        diags.append(_err("schema.malformed", nid,
                          f"op does not parse: {e}"))
        return None


def _check_decision(ent: _Entry, d: Any, diags: List[Diagnostic]) -> None:
    nid = ent.node
    if not isinstance(d, dict) or "op" not in d:
        diags.append(_err("schema.malformed", nid,
                          "decision must be an object with an 'op'"))
        return
    op = _parse_op(ent.unit, d["op"], nid, diags)
    ent.op = op
    c_cpu, c_gpu = d.get("c_cpu"), d.get("c_gpu")
    if not (isinstance(c_cpu, int) and isinstance(c_gpu, int)
            and c_cpu >= 0 and c_gpu >= 0):
        diags.append(_err("schema.malformed", nid,
                          f"decision shares must be non-negative integers "
                          f"(c_cpu={c_cpu!r}, c_gpu={c_gpu!r})"))
        return
    for f in ("pred_cpu_us", "pred_gpu_us", "pred_total_us"):
        if not isinstance(d.get(f), (int, float)):
            diags.append(_err("schema.malformed", nid,
                              f"decision needs numeric {f!r}"))
    axis = d.get("axis", "channel")
    if d.get("axis") == "channel":
        diags.append(_err(
            "schema.default-key", nid,
            "'axis' serialized at its default \"channel\"",
            "decision_to_json omits the channel axis so pre-axis plan "
            "JSON stays byte-identical"))
    if op is None:
        return
    entry = registry.get(ent.unit)
    if axis == "channel":
        if not entry.splittable:
            diags.append(_err(
                "axis.legality", nid,
                f"kind {ent.unit!r} is not channel-splittable",
                f"use a typed axis "
                f"({[a.axis for a in entry.axes]}) or axis 'none'"))
        elif c_cpu + c_gpu != op.C_out:
            diags.append(_err(
                "axis.shares", nid,
                f"channel shares {c_cpu}+{c_gpu} != C_out {op.C_out}"))
        ent.coexec = c_cpu > 0 and c_gpu > 0
    elif axis == "none":
        if (c_cpu > 0) == (c_gpu > 0):
            diags.append(_err(
                "axis.shares", nid,
                f"axis 'none' is an exclusive placement: exactly one "
                f"side carries the op (got c_cpu={c_cpu}, c_gpu={c_gpu})"))
    else:
        try:
            spec = registry.validate_axis_split(op, axis, c_gpu)
        except (ValueError, KeyError) as e:
            diags.append(_err("axis.legality", nid, str(e)))
            spec = None
        if spec is not None and c_cpu + c_gpu != spec.size(op):
            diags.append(_err(
                "axis.shares", nid,
                f"{axis} shares {c_cpu}+{c_gpu} != axis size "
                f"{spec.size(op)}"))
    if "tile" in d:
        _check_tile(ent, d["tile"], diags)


def _check_tile(ent: _Entry, tile_json: Any,
                diags: List[Diagnostic]) -> None:
    nid = ent.node
    if not tile_json:
        diags.append(_err("schema.default-key", nid,
                          "'tile' serialized at its empty default",
                          "decision_to_json omits absent tiles"))
        return
    try:
        tile = registry.tile_from_json(ent.unit, tile_json)
        resolved = registry.resolve_tile(ent.op, tile) \
            if ent.op is not None else tile
    except (ValueError, KeyError, TypeError) as e:
        diags.append(_err("tile.legality", nid, str(e),
                          "clamp via registry.TileSpec.clamp_tile "
                          "instead of shipping an illegal tile"))
        return
    if ent.op is not None and \
            resolved == registry.default_tile(ent.op):
        diags.append(_err(
            "schema.default-key", nid,
            f"'tile' {resolved.label()} equals the default blocking",
            "annotate_plan_tiles attaches tiles only when the winner "
            "differs from the default"))


def _structural(op) -> Dict[str, Any]:
    """Op JSON modulo execution mode: the decision op carries the chosen
    kernel mode while the graph node holds the structural identity."""
    d = registry.op_to_json(op)
    d.pop("mode", None)
    return d


# ------------------------------------------------------------------- graph

def _plan_graph(doc: Dict[str, Any], entries: List[_Entry],
                diags: List[Diagnostic]) -> Optional[Graph]:
    if doc.get("graph") is not None:
        try:
            return Graph.from_json(doc["graph"])
        except (ValueError, KeyError, TypeError) as e:
            diags.append(_err("graph.invalid", "",
                              f"embedded graph does not validate: {e}"))
            return None
    # unit-chain plans: reconstruct the linear chain from the schedule
    nodes: List[Node] = []
    prev: Tuple[str, ...] = ()
    for ent in entries:
        try:
            if ent.unit == "pool":
                nodes.append(Node(id=ent.node, kind="pool",
                                  pool_bytes=ent.pool_bytes, inputs=prev))
            elif ent.op is not None:
                nodes.append(Node(id=ent.node, kind=ent.unit, op=ent.op,
                                  inputs=prev))
            else:                  # bad op already diagnosed: no graph
                return None
        except ValueError as e:
            diags.append(_err("graph.invalid", ent.node, str(e)))
            return None
        prev = (ent.node,)
    if not nodes:
        diags.append(_err("schema.malformed", "", "empty schedule"))
        return None
    return Graph(nodes)


def _check_graph(doc: Dict[str, Any], g: Graph,
                 prov: Optional[PlanProvenance], entries: List[_Entry],
                 diags: List[Diagnostic]) -> None:
    if doc.get("graph") is not None and g.is_unit_chain():
        diags.append(_err(
            "schema.default-key", "",
            "graph embedded for a unit chain",
            "unit-chain plans omit 'graph' (and 'id' keys) so the "
            "serialized format stays bit-identical to the pre-IR era"))
    ids = [e.node for e in entries]
    graph_ids = [n.id for n in g.nodes]
    if ids != graph_ids:
        diags.append(_err(
            "graph.schedule", "",
            f"schedule ids {ids[:6]}... do not match the graph's "
            f"topological order {graph_ids[:6]}..."))
        return
    for ent in entries:
        n = g.node(ent.node)
        if n.kind != ent.unit:
            diags.append(_err("graph.schedule", ent.node,
                              f"schedule unit {ent.unit!r} != graph node "
                              f"kind {n.kind!r}"))
        elif ent.unit == "pool" and n.pool_bytes != ent.pool_bytes:
            diags.append(_err("graph.schedule", ent.node,
                              f"pool bytes {ent.pool_bytes} != graph "
                              f"node's {n.pool_bytes}"))
        elif ent.op is not None and n.op is not None and \
                _structural(ent.op) != _structural(n.op):
            diags.append(_err(
                "graph.schedule", ent.node,
                f"schedule op {registry.op_label(ent.op)} != graph "
                f"node op {registry.op_label(n.op)}"))
    if prov is not None:
        fp = g.fingerprint()
        if fp != prov.network_fingerprint:
            diags.append(_err(
                "graph.fingerprint", "",
                f"recomputed graph fingerprint {fp} != provenance "
                f"network_fingerprint {prov.network_fingerprint}",
                "the schedule/graph was edited after planning; recompile"))


# ---------------------------------------------------------------- segments

def _check_segments(doc: Dict[str, Any], g: Graph, coexec,
                    entries: List[_Entry],
                    diags: List[Diagnostic]) -> None:
    derived = g.segments(coexec)
    parts: List[Segment] = derived
    if doc.get("segments") is not None:
        embedded = []
        for i, s in enumerate(doc["segments"]):
            try:
                embedded.append(Segment(kind=s["kind"],
                                        node_ids=tuple(s["nodes"])))
            except (ValueError, KeyError, TypeError) as e:
                diags.append(_err("schema.malformed", f"segment#{i}",
                                  f"segment does not parse: {e}"))
                return
        covered = [nid for s in embedded for nid in s.node_ids]
        if covered != [e.node for e in entries]:
            diags.append(_err(
                "segment.cover", "",
                "embedded segments do not cover the schedule exactly in "
                "topological order",
                "segment_partition() would silently re-derive; committed "
                "artifacts must carry consistent metadata"))
        elif embedded != derived:
            diags.append(_err(
                "segment.mismatch", "",
                f"embedded segments ({len(embedded)}) != re-derived "
                f"Graph.segments partition ({len(derived)})",
                "planners embed exactly graph.segments(coexec); the "
                "metadata went stale"))
        parts = embedded
    elided = g.elided(coexec)
    for k, seg in enumerate(parts):
        tag = f"segment#{k}"
        known = [nid for nid in seg.node_ids if nid in g._by_id]
        if len(known) != len(seg.node_ids):
            continue                        # cover diagnosis already covers
        if seg.kind == SEGMENT_POOL:
            if any(g.node(nid).kind != "pool" for nid in seg.node_ids):
                diags.append(_err("segment.gather", tag,
                                  "pool segment holds a non-pool node"))
            continue
        if seg.kind == SEGMENT_EXCLUSIVE:
            if any(nid in coexec for nid in seg.node_ids):
                diags.append(_err(
                    "segment.gather", tag,
                    "co-executed node in an exclusive segment",
                    "channel-split nodes fuse; typed-axis splits are "
                    "exclusive singletons"))
            continue
        assert seg.kind == SEGMENT_FUSED
        for nid in seg.node_ids:
            n = g.node(nid)
            if nid not in coexec and n.kind != "add":
                diags.append(_err(
                    "segment.gather", tag,
                    f"node {nid!r} ({n.kind}) is neither co-executed nor "
                    f"an add join: fusing it would force a sync inside "
                    f"one jitted program"))
        inside = set(seg.node_ids)
        for nid in seg.node_ids[:-1]:
            leaked = [c for c in g.consumers(nid) if c not in inside]
            if leaked:
                diags.append(_err(
                    "segment.convexity", tag,
                    f"interior node {nid!r} publishes to {leaked} outside "
                    f"the segment (a fused run has a single gathered "
                    f"output)"))
            elif nid in coexec and len(g.consumers(nid)) == 1:
                # interior split outputs stay group-local: either the
                # sole consumer is an add (joined split-wise inside the
                # fused program) or the elision predicate holds
                u = g.node(g.consumers(nid)[0])
                if u.kind != "add" and nid not in elided:
                    diags.append(_err(
                        "segment.elision", tag,
                        f"interior node {nid!r} fails the sole-consumer "
                        f"gather-elision predicate",
                        "its consumer is not a compatible co-executed "
                        "op, so its split output must be gathered — the "
                        "segment must cut here"))


# ------------------------------------------------------ resource accounting

@dataclasses.dataclass(frozen=True)
class PlanStats:
    """Static resource accounting of one plan (fp32 activation bytes)."""

    nodes: int
    coexec_nodes: int
    segments: int
    fused_segments: int
    sync_points: int                # gathers (materialization points)
    boundary_bytes: int             # bytes crossing the CPU/GPU boundary
    peak_live_bytes: int            # peak total activation liveness
    peak_fast_bytes: int            # GPU-analogue group's share of the peak
    peak_slow_bytes: int            # CPU-analogue group's share of the peak

    def summary(self) -> str:
        return (f"peak live {self.peak_live_bytes / 1e6:.2f} MB "
                f"(fast {self.peak_fast_bytes / 1e6:.2f} / slow "
                f"{self.peak_slow_bytes / 1e6:.2f}), "
                f"{self.sync_points} sync points, "
                f"{self.boundary_bytes / 1e6:.2f} MB boundary traffic, "
                f"{self.segments} segments ({self.fused_segments} fused), "
                f"{self.coexec_nodes}/{self.nodes} nodes co-executed")


def plan_stats(plan) -> PlanStats:
    """Static resource accounting for a verifiable plan (raises ValueError
    when the plan is too malformed to account — run `verify_plan` first)."""
    doc = plan.to_json() if hasattr(plan, "to_json") else plan
    diags: List[Diagnostic] = []
    schedule = doc.get("schedule")
    if not isinstance(schedule, list):
        raise ValueError("plan document has no schedule")
    entries = _check_schedule(doc, schedule, diags)
    g = _plan_graph(doc, entries, diags)
    if g is None or errors(diags):
        raise VerificationError("plan_stats", diags)
    coexec = frozenset(e.node for e in entries if e.coexec)
    return _stats_from(g, entries, coexec)


def _fast_fraction(ent: _Entry) -> float:
    """The GPU-analogue group's share of a node's output activation."""
    d = ent.raw.get("decision")
    if d is None:
        return 1.0                          # pool/add/opaque: GPU side
    c_cpu, c_gpu = int(d.get("c_cpu", 0)), int(d.get("c_gpu", 0))
    total = c_cpu + c_gpu
    if total <= 0:
        return 1.0
    if d.get("axis", "channel") == "none":
        return 1.0 if c_gpu else 0.0        # exclusive placement marker
    return c_gpu / total


def _stats_from(g: Graph, entries: List[_Entry], coexec) -> PlanStats:
    parts = g.segments(coexec)
    mat = g.materialization_points(coexec)

    def nbytes(nid: str) -> int:
        n = 4
        for dim in g.output_shape(nid):
            n *= int(dim)
        return n

    frac = {e.node: _fast_fraction(e) for e in entries}
    refs = {n.id: max(1, len(g.consumers(n.id))) for n in g.nodes}
    live: Dict[str, int] = {}
    peak = peak_fast = peak_slow = 0
    for n in g.nodes:
        live[n.id] = nbytes(n.id)
        total = sum(live.values())
        fast = sum(int(b * frac.get(nid, 1.0)) for nid, b in live.items())
        peak = max(peak, total)
        peak_fast = max(peak_fast, fast)
        peak_slow = max(peak_slow, total - fast)
        for src in n.inputs:
            refs[src] -= 1
            if refs[src] == 0:
                del live[src]
    return PlanStats(
        nodes=len(g),
        coexec_nodes=len(coexec),
        segments=len(parts),
        fused_segments=sum(1 for s in parts if s.kind == SEGMENT_FUSED),
        sync_points=len(mat),
        boundary_bytes=sum(nbytes(nid) for nid in mat),
        peak_live_bytes=peak,
        peak_fast_bytes=peak_fast,
        peak_slow_bytes=peak_slow)


# ------------------------------------------------------- artifacts on disk

def verify_artifact(doc: Dict[str, Any], *,
                    stats: bool = True) -> List[Diagnostic]:
    """Verify a `repro.compiled_network` artifact document."""
    from repro.api import (ARTIFACT_FORMAT, ARTIFACT_VERSION,
                           _artifact_checksum)
    diags: List[Diagnostic] = []
    if doc.get("format") != ARTIFACT_FORMAT:
        diags.append(_err("artifact.format", "",
                          f"not a {ARTIFACT_FORMAT} artifact "
                          f"(format={doc.get('format')!r})"))
        return diags
    if doc.get("version") != ARTIFACT_VERSION:
        diags.append(_err("artifact.format", "",
                          f"unsupported artifact version "
                          f"{doc.get('version')!r}"))
    if doc.get("checksum") != _artifact_checksum(doc):
        diags.append(_err("artifact.checksum", "",
                          "recomputed artifact checksum does not match",
                          "the file was modified after it was saved"))
    plan = doc.get("plan")
    if isinstance(plan, dict):
        diags.extend(verify_plan(plan, stats=stats))
    else:
        diags.append(_err("schema.malformed", "",
                          "artifact carries no plan document"))
    return diags


def verify_portfolio(doc: Dict[str, Any], *,
                     stats: bool = False) -> List[Diagnostic]:
    """Verify a `repro.plan_portfolio` artifact document."""
    from repro.api import (PORTFOLIO_FORMAT, PORTFOLIO_VERSION,
                           _portfolio_checksum)
    diags: List[Diagnostic] = []
    if doc.get("format") != PORTFOLIO_FORMAT:
        diags.append(_err("artifact.format", "",
                          f"not a {PORTFOLIO_FORMAT} artifact "
                          f"(format={doc.get('format')!r})"))
        return diags
    if doc.get("version") != PORTFOLIO_VERSION:
        diags.append(_err("artifact.format", "",
                          f"unsupported portfolio version "
                          f"{doc.get('version')!r}"))
    if doc.get("checksum") != _portfolio_checksum(doc):
        diags.append(_err("artifact.checksum", "",
                          "recomputed portfolio checksum does not match",
                          "the file was modified after it was saved"))
    for e in doc.get("entries", []):
        tag = f"b{e.get('batch')}s{e.get('seq')}"
        sub = verify_artifact(e.get("artifact", {}), stats=stats)
        diags.extend(dataclasses.replace(
            d, node=f"{tag}/{d.node}" if d.node else tag) for d in sub)
        prov = (e.get("artifact", {}).get("plan", {}) or {}) \
            .get("provenance", {})
        if isinstance(prov, dict) and prov.get("bucket", "") != tag:
            diags.append(_err(
                "portfolio.bucket", tag,
                f"entry bucket tag {tag!r} != plan provenance bucket "
                f"{prov.get('bucket', '')!r}"))
    return diags


def verify_tune_entry(doc: Dict[str, Any], *,
                      expect_key: Optional[str] = None) -> List[Diagnostic]:
    """Verify one on-disk TuneCache entry (tile legality + digest)."""
    from repro.runtime.autotune import TUNE_SCHEMA_VERSION, TuneKey
    diags: List[Diagnostic] = []
    key, tile = doc.get("key"), doc.get("tile")
    if not isinstance(key, dict) or not isinstance(tile, dict):
        diags.append(_err("schema.malformed", "",
                          "tune entry needs 'key' and 'tile' objects"))
        return diags
    if doc.get("schema_version") != TUNE_SCHEMA_VERSION:
        diags.append(_err("schema.version", "",
                          f"unsupported tune schema version "
                          f"{doc.get('schema_version')!r}"))
    op_json = key.get("op_json")
    try:
        op = registry.op_from_json(dict(op_json))
        cfg = registry.tile_from_json(registry.op_kind(op), tile)
        registry.resolve_tile(op, cfg)
    except (ValueError, KeyError, TypeError) as e:
        diags.append(_err("tile.legality", "",
                          f"cached tile does not validate: {e}"))
        return diags
    if expect_key is not None:
        try:
            tk = TuneKey(op_json=tuple(sorted(op_json.items())),
                         device=key["device"], backend=key["backend"],
                         kernel_version=key["kernel_version"],
                         schema_version=key["schema_version"],
                         preserve_numerics=key["preserve_numerics"])
        except (KeyError, TypeError) as e:
            diags.append(_err("schema.malformed", "",
                              f"tune key does not parse: {e}"))
            return diags
        if tk.key != expect_key:
            diags.append(_err("provenance.digest", "",
                              f"recomputed tune digest {tk.key} != "
                              f"expected {expect_key}"))
    return diags


def verify_bench_report(doc: Dict[str, Any]) -> List[Diagnostic]:
    """Verify one reports/bench suite JSON (shape + metric sanity)."""
    import math
    diags: List[Diagnostic] = []
    if not isinstance(doc.get("suite"), str) or \
            not isinstance(doc.get("metrics"), list):
        diags.append(_err("bench.schema", "",
                          "bench report needs a 'suite' string and a "
                          "'metrics' list"))
        return diags
    for i, m in enumerate(doc["metrics"]):
        if not isinstance(m, dict) or "name" not in m or \
                "us_per_call" not in m:
            diags.append(_err("bench.schema", f"metric#{i}",
                              "metric rows carry 'name' and "
                              "'us_per_call'"))
            continue
        try:
            us = float(m["us_per_call"])
        except (TypeError, ValueError):
            diags.append(_err("bench.metric", str(m["name"]),
                              f"us_per_call {m['us_per_call']!r} is not "
                              f"a number"))
            continue
        if not math.isfinite(us) or us < 0:
            diags.append(_err("bench.metric", str(m["name"]),
                              f"us_per_call {us!r} must be finite and "
                              f">= 0"))
    return diags


def verify_path(path: Path, *,
                stats: bool = False) -> Tuple[str, List[Diagnostic]]:
    """Verify one JSON file on disk, dispatching on its document shape.

    Returns ``(kind, diagnostics)`` where kind is one of "plan",
    "artifact", "portfolio", "tune", "bench", or "unknown".  Plan/tune
    cache files named by a 32-hex digest get their digest recomputed
    against the filename (`provenance.digest`).
    """
    path = Path(path)
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return "unknown", [_err("schema.malformed", "",
                                f"{path}: unreadable JSON: {e}")]
    stem = path.stem
    digest = stem if len(stem) == 32 and \
        all(c in "0123456789abcdef" for c in stem) else None
    if not isinstance(doc, dict):
        return "unknown", [_err("schema.malformed", "",
                                f"{path}: not a JSON object")]
    if doc.get("format") == "repro.plan_portfolio":
        return "portfolio", verify_portfolio(doc, stats=stats)
    if doc.get("format") == "repro.compiled_network":
        return "artifact", verify_artifact(doc, stats=stats)
    if "provenance" in doc and "schedule" in doc:
        return "plan", verify_plan(doc, expect_key=digest, stats=stats)
    if "key" in doc and "tile" in doc:
        return "tune", verify_tune_entry(doc, expect_key=digest)
    if "suite" in doc and "metrics" in doc:
        return "bench", verify_bench_report(doc)
    return "unknown", [Diagnostic(
        SEV_WARNING, "schema.malformed", "",
        f"{path}: unrecognized document shape (no known format markers)")]
