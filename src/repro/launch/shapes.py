"""The four assigned input shapes and per-(arch, shape) applicability."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str               # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# long_500k requires sub-quadratic attention: run for SSM/hybrid and for
# the sliding-window dense arch; skip for pure full-attention archs
# (documented in DESIGN.md §Arch-applicability).
_LONG_OK_FAMILIES = {"ssm", "hybrid"}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> Optional[str]:
    """None if runnable, else a skip reason (recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k":
        if cfg.family in _LONG_OK_FAMILIES:
            return None
        if cfg.sliding_window > 0:
            return None            # gemma3: local layers O(w), decode O(L)
        return ("full-attention architecture: 500k context has no "
                "sub-quadratic path (skip per assignment)")
    return None
