# NOTE: dryrun is intentionally NOT imported here — importing it sets
# XLA_FLAGS for 512 host devices, which must never leak into tests/benches.
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.shapes import INPUT_SHAPES, InputShape, shape_applicable
__all__ = ["make_host_mesh", "make_production_mesh", "INPUT_SHAPES",
           "InputShape", "shape_applicable"]
