"""Jittable train / prefill / decode steps + ShapeDtypeStruct input specs.

`input_specs(...)` produces weak-type-correct ShapeDtypeStruct stand-ins
for every model input at a given production shape — no device allocation —
which is what dryrun.py lowers against.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, AdamWState, adamw_update, init_adamw
from repro.launch.shapes import InputShape


# ------------------------------------------------------------------- train
def make_train_step(model, opt_cfg: AdamWConfig = AdamWConfig()):
    def train_step(params, opt_state: AdamWState, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, loss
    return train_step


# ------------------------------------------------------------------- serve
def make_prefill_step(model, cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        def prefill_step(params, tokens, cache, frames):
            return model.prefill(params, tokens, cache, frames)
    else:
        def prefill_step(params, tokens, cache):
            return model.prefill(params, tokens, cache)
    return prefill_step


def make_decode_step(model):
    def decode_step(params, tokens, cache, pos):
        return model.decode_step(params, tokens, cache, pos)
    return decode_step


# ------------------------------------------------------------- input specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    specs = {"tokens": _sds((batch, seq), jnp.int32),
             "labels": _sds((batch, seq), jnp.int32)}
    if cfg.is_encoder_decoder:
        specs["frames"] = _sds((batch, cfg.encoder_seq, cfg.d_model),
                               jnp.float32)
    return specs


def params_specs(cfg: ModelConfig, model):
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_specs(params_shape):
    return jax.eval_shape(init_adamw, params_shape)


def cache_specs(cfg: ModelConfig, model, batch: int, max_len: int):
    return jax.eval_shape(lambda: model.init_cache(batch, max_len))


def input_specs(cfg: ModelConfig, model, shape: InputShape
                ) -> Dict[str, Any]:
    """All abstract inputs needed to lower the step for this shape."""
    b, t = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {
        "params": params_specs(cfg, model),
    }
    if shape.kind == "train":
        out["opt_state"] = opt_specs(out["params"])
        out["batch"] = batch_specs(cfg, b, t)
    elif shape.kind == "prefill":
        out["tokens"] = _sds((b, t), jnp.int32)
        out["cache"] = cache_specs(cfg, model, b, t)
        if cfg.is_encoder_decoder:
            out["frames"] = _sds((b, cfg.encoder_seq, cfg.d_model),
                                 jnp.float32)
    else:  # decode
        out["tokens"] = _sds((b, 1), jnp.int32)
        out["cache"] = cache_specs(cfg, model, b, t)
        out["pos"] = _sds((), jnp.int32)
    return out
