"""Production mesh construction.

Target hardware: TPU v5e, 256 chips per pod (16 x 16), optionally 2 pods.
Defined as functions (NOT module-level constants) so importing this module
never touches jax device state — the 512-device override is applied only by
dryrun.py before its own first jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, multi_pod: bool = False):
    """Small-footprint mesh for CI (8 virtual host devices)."""
    shape = (2, 2, 2) if multi_pod else (2, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
