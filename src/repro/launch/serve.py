"""Serving launcher: batched requests through the ServingEngine.

    PYTHONPATH=src python -m repro serve --arch gemma3_12b --reduced \
        --requests 8 --max-new 12

`--compiled <artifact>` additionally ships a `repro.CompiledNetwork`
artifact (saved by `python -m repro plan --save ...`) with the engine and
executes it once after serving, printing the per-op fidelity summary.

`python -m repro.launch.serve` still works but is deprecated in favor of
the unified `python -m repro serve`.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.models import ARCH_IDS, build_model, get_config
from repro.serving import Request, ServingEngine


def serve_main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro serve")
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compiled", default=None,
                    help="CompiledNetwork artifact to ship with the engine "
                         "(executed once after serving; see `python -m "
                         "repro plan --save`)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    compiled = None
    if args.compiled:
        from repro.api import CompiledNetwork
        compiled = CompiledNetwork.load(args.compiled)
        print(f"shipping compiled plan {compiled.key} "
              f"(device {compiled.target.device})")

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 17)).astype(np.int32)
        frames = None
        if cfg.is_encoder_decoder:
            frames = rng.normal(size=(cfg.encoder_seq, cfg.d_model)
                                ).astype(np.float32) * 0.02
        reqs.append(Request(rid=rid, prompt=prompt,
                            max_new_tokens=args.max_new,
                            temperature=args.temperature, frames=frames))

    engine = ServingEngine(cfg, model, params, max_batch=args.max_batch,
                           max_len=64 + args.max_new, compiled=compiled)
    t0 = time.time()
    completions = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in completions)
    for c in completions[:4]:
        print(f"req {c.rid}: {c.tokens}")
    print(f"{len(completions)} completions, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s on host CPU)")

    if compiled is not None:
        _, report = engine.execute_plan()
        print(report.fidelity_summary())
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Deprecated CLI shim: forwards to `python -m repro serve`."""
    from repro.api import _warn_once
    _warn_once("python -m repro.launch.serve", "python -m repro serve")
    return serve_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
