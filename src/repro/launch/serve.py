"""Serving launcher: batched requests through the ServingEngine, or
Poisson traffic through the continuous scheduler.

Fixed-batch mode (the original engine):

    PYTHONPATH=src python -m repro serve --arch gemma3_12b --reduced \
        --requests 8 --max-new 12

Continuous-batching mode (`--arrivals poisson` selects the
`repro.serving.ContinuousScheduler`): synthetic Poisson traffic is
admitted per-step against a bucketed plan portfolio —

    PYTHONPATH=src python -m repro serve --arch codeqwen15_7b --reduced \
        --arrivals poisson --rate 200 --requests 50 \
        --portfolio reports/portfolio.json

`--portfolio <path>` loads the portfolio artifact if it exists and
otherwise compiles one there (`repro.compile_portfolio`; a loaded
artifact serves but cannot replan — it carries no predictors).
`--throttle-at`/`--throttle-scale` simulate a mid-run thermal throttle,
exercising the drift-triggered in-place replanning path.

`--compiled <artifact>` additionally ships a `repro.CompiledNetwork`
artifact (saved by `python -m repro plan --save ...`) with the engine and
executes it once after serving, printing the per-op fidelity summary.

`python -m repro.launch.serve` still works but is deprecated in favor of
the unified `python -m repro serve`.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Optional, Sequence

import jax
import numpy as np

from repro.models import ARCH_IDS, build_model, get_config
from repro.serving import Request, ServingEngine


def _parse_buckets(text: str):
    """"1x64,4x64,4x256" -> ((1, 64), (4, 64), (4, 256))."""
    out = []
    for part in text.split(","):
        b, _, s = part.strip().partition("x")
        out.append((int(b), int(s)))
    return tuple(out)


def _load_or_compile_portfolio(args, cfg):
    import repro

    path = Path(args.portfolio)
    if path.exists():
        pf = repro.PlanPortfolio.load(path)
        note = "" if pf.can_replan() else \
            " (loaded artifact: serves, cannot replan)"
        print(f"portfolio {path}: {pf}{note}")
        return pf
    buckets = _parse_buckets(args.buckets)
    print(f"compiling portfolio for {cfg.name} on {args.device} "
          f"(buckets {args.buckets}) ...")
    pf = repro.compile_portfolio(cfg, repro.Target(device=args.device),
                                 buckets=buckets, cache=args.cache_dir,
                                 samples=args.samples,
                                 estimators=args.estimators)
    pf.save(path)
    print(f"  wrote {path}: {pf}")
    return pf


def _serve_scheduler(args, cfg, model, params) -> int:
    from repro.serving import (ContinuousScheduler, SchedulerConfig,
                               ThrottleSim, poisson_requests)

    portfolio = None
    if args.portfolio:
        portfolio = _load_or_compile_portfolio(args, cfg)
    throttle = None
    if args.throttle_at is not None:
        throttle = ThrottleSim(at_s=args.throttle_at,
                               scale=args.throttle_scale)
        print(f"simulating throttle: x{args.throttle_scale} wall time "
              f"from t={args.throttle_at}s")
    store = args.store_dir if portfolio is not None else None
    sched = ContinuousScheduler(
        cfg, model, params, portfolio=portfolio, measurement_store=store,
        throttle=throttle, plan_cache=args.cache_dir,
        config=SchedulerConfig(max_batch=args.max_batch,
                               max_len=args.max_len,
                               fidelity_every=args.fidelity_every))
    reqs = poisson_requests(args.requests, rate=args.rate,
                            vocab_size=cfg.vocab_size,
                            max_new=(args.max_new // 2 or 1, args.max_new),
                            seed=args.seed)
    t0 = time.time()
    report = sched.run(reqs)
    dt = time.time() - t0
    for c in report.completions[:4]:
        print(f"req {c.rid}: {c.tokens}")
    print(report.summary())
    print(f"(host wall {dt:.1f}s, {report.total_tokens / dt:.1f} tok/s "
          f"on host CPU)")
    return 0


def serve_main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro serve")
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--compiled", default=None,
                    help="CompiledNetwork artifact to ship with the engine "
                         "(executed once after serving; see `python -m "
                         "repro plan --save`)")
    ap.add_argument("--arrivals", default="batch",
                    choices=["batch", "poisson"],
                    help="batch = fixed-batch ServingEngine; poisson = "
                         "continuous scheduler over Poisson traffic")
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate, requests/s (scheduler "
                         "virtual clock)")
    ap.add_argument("--portfolio", default=None,
                    help="plan-portfolio artifact path: loaded if present, "
                         "else compiled there (scheduler mode)")
    ap.add_argument("--buckets", default="1x64,4x64",
                    help="portfolio (batch x seq) buckets, e.g. "
                         "'1x64,4x64,4x256'")
    ap.add_argument("--device", default="moto2022",
                    help="simulated target device for portfolio compilation")
    ap.add_argument("--cache-dir", default="reports/plans",
                    help="plan cache directory (portfolio compilation and "
                         "in-place replans)")
    ap.add_argument("--store-dir", default="reports/measurements",
                    help="measurement store for per-bucket fidelity records")
    ap.add_argument("--max-len", type=int, default=128,
                    help="per-slot cache length (scheduler mode)")
    ap.add_argument("--fidelity-every", type=int, default=16,
                    help="plan-execution cadence in scheduler steps")
    ap.add_argument("--throttle-at", type=float, default=None,
                    help="simulate a thermal throttle from this time (s) on "
                         "the scheduler clock")
    ap.add_argument("--throttle-scale", type=float, default=1.8,
                    help="wall-time multiplier of the simulated throttle")
    ap.add_argument("--samples", type=int, default=400,
                    help="predictor training ops (portfolio compilation)")
    ap.add_argument("--estimators", type=int, default=60,
                    help="GBDT trees per predictor (portfolio compilation)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if args.arrivals == "poisson":
        return _serve_scheduler(args, cfg, model, params)

    compiled = None
    if args.compiled:
        from repro.api import CompiledNetwork
        compiled = CompiledNetwork.load(args.compiled)
        print(f"shipping compiled plan {compiled.key} "
              f"(device {compiled.target.device})")

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              size=rng.integers(4, 17)).astype(np.int32)
        frames = None
        if cfg.is_encoder_decoder:
            frames = rng.normal(size=(cfg.encoder_seq, cfg.d_model)
                                ).astype(np.float32) * 0.02
        reqs.append(Request(rid=rid, prompt=prompt,
                            max_new_tokens=args.max_new,
                            temperature=args.temperature, frames=frames))

    engine = ServingEngine(cfg, model, params, max_batch=args.max_batch,
                           max_len=64 + args.max_new, compiled=compiled)
    t0 = time.time()
    completions = engine.run(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in completions)
    for c in completions[:4]:
        print(f"req {c.rid}: {c.tokens}")
    print(f"{len(completions)} completions, {total_tokens} tokens "
          f"in {dt:.1f}s ({total_tokens/dt:.1f} tok/s on host CPU)")

    if compiled is not None:
        _, report = engine.execute_plan()
        print(report.fidelity_summary())
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Deprecated CLI shim: forwards to `python -m repro serve`."""
    from repro.api import _warn_once
    _warn_once("python -m repro.launch.serve", "python -m repro serve")
    return serve_main(argv)


if __name__ == "__main__":
    raise SystemExit(main())
