import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, with NO device allocation (ShapeDtypeStruct inputs).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Per combination this prints compiled.memory_analysis() (proves it fits) and
compiled.cost_analysis() (FLOPs/bytes for the roofline), parses collective
bytes from the post-SPMD HLO, and appends a JSON record to
reports/dryrun/<arch>_<shape>_<mesh>.json for EXPERIMENTS.md.

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init.  Do not import this module from processes
that need the real single-device CPU platform.
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.launch.shapes import INPUT_SHAPES, shape_applicable
from repro.launch.steps import (input_specs, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models import ARCH_IDS, build
from repro.roofline.analysis import build_report
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import (batch_shardings, cache_shardings,
                                  param_shardings, replicated)

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              verbose: bool = True, extra_tag: str = "",
              test_mesh: bool = False):
    """Lower+compile one (arch, shape, mesh); returns the roofline record."""
    cfg, model = build(arch)
    shape = INPUT_SHAPES[shape_name]
    skip = shape_applicable(cfg, shape)
    if test_mesh:
        mesh_name = "2x2x2" if multi_pod else "2x4"
    else:
        mesh_name = "2x16x16" if multi_pod else "16x16"
    if skip is not None:
        if verbose:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {skip}")
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "skipped", "reason": skip}
        REPORT_DIR.mkdir(parents=True, exist_ok=True)
        with open(REPORT_DIR / f"{arch}_{shape_name}_{mesh_name}.json",
                  "w") as f:
            json.dump(rec, f, indent=1)
        return rec

    mesh = make_test_mesh(multi_pod=multi_pod) if test_mesh \
        else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    specs = input_specs(cfg, model, shape)
    t0 = time.time()

    with mesh, activation_mesh(mesh):
        p_sh = param_shardings(specs["params"], mesh)
        if shape.kind == "train":
            step = make_train_step(model)
            opt_sh = jax.tree.map(lambda _: replicated(mesh),
                                  specs["opt_state"])
            opt_sh = opt_sh._replace(mu=p_sh, nu=p_sh)
            b_sh = batch_shardings(specs["batch"], mesh)
            lowered = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh)) \
                .lower(specs["params"], specs["opt_state"], specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(model, cfg)
            c_sh = cache_shardings(specs["cache"], mesh,
                                   batch=shape.global_batch)
            t_sh = batch_shardings({"t": specs["tokens"]}, mesh)["t"]
            args = [specs["params"], specs["tokens"], specs["cache"]]
            shardings = [p_sh, t_sh, c_sh]
            if cfg.is_encoder_decoder:
                f_sh = batch_shardings({"f": specs["frames"]}, mesh)["f"]
                args.append(specs["frames"])
                shardings.append(f_sh)
            lowered = jax.jit(step, in_shardings=tuple(shardings)) \
                .lower(*args)
        else:  # decode
            step = make_decode_step(model)
            seq_shard = shape.global_batch < mesh.shape["data"]
            c_sh = cache_shardings(specs["cache"], mesh,
                                   batch=shape.global_batch,
                                   seq_shard=seq_shard)
            t_sh = batch_shardings({"t": specs["tokens"]}, mesh)["t"]
            lowered = jax.jit(step, in_shardings=(
                p_sh, t_sh, c_sh, replicated(mesh))) \
                .lower(specs["params"], specs["tokens"], specs["cache"],
                       specs["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    report = build_report(arch, shape, mesh_name, chips, cost, mem, hlo,
                          cfg)
    rec = report.to_dict()
    rec.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))

    if verbose:
        print(f"[dryrun] {arch} x {shape_name} @ {mesh_name}: OK "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops/dev={cost.get('flops', 0):.3e} "
              f"bytes/dev={cost.get('bytes accessed', 0):.3e}")
        print(f"  roofline: compute={report.t_compute*1e3:.2f}ms "
              f"memory={report.t_memory*1e3:.2f}ms "
              f"collective={report.t_collective*1e3:.2f}ms "
              f"-> {report.bottleneck}-bound "
              f"(useful {report.useful_flops_ratio:.2f})")

    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    tag = f"_{extra_tag}" if extra_tag else ""
    out = REPORT_DIR / f"{arch}_{shape_name}_{mesh_name}{tag}.json"
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"],
                    default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    lower_one(arch, shape, multi_pod=mp,
                              extra_tag=args.tag)
                except Exception as e:            # noqa: BLE001
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"[dryrun] FAIL {arch} x {shape} "
                          f"multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n[dryrun] {len(failures)} FAILURES")
        raise SystemExit(1)
    print("\n[dryrun] all combinations lowered and compiled")


if __name__ == "__main__":
    main()
