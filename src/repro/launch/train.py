"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6_1b6 \
        --reduced --steps 50 --batch 8 --seq 128

On the CPU container use --reduced (the tiny same-family variant); on real
hardware the full config trains on the production mesh with the same code
path (pjit over make_production_mesh()).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.data import DataConfig, SyntheticTokenStream, make_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import ARCH_IDS, build_model, get_config
from repro.optim import AdamWConfig, init_adamw
from repro.sharding.ctx import activation_mesh
from repro.sharding.rules import param_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    mesh = make_production_mesh() if args.production_mesh \
        else make_host_mesh()

    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 10))
    step_fn = make_train_step(model, opt_cfg)

    with mesh, activation_mesh(mesh):
        params = model.init(jax.random.PRNGKey(0))
        p_sh = param_shardings(params, mesh)
        params = jax.device_put(params, p_sh)
        opt_state = init_adamw(params)
        train = jax.jit(step_fn, donate_argnums=(0, 1))

        stream = iter(SyntheticTokenStream(
            cfg.vocab_size, DataConfig(args.batch, args.seq, seed=0)))
        losses = []
        t0 = time.time()
        for step in range(args.steps):
            raw = next(stream)
            batch = {k: jnp.asarray(v) for k, v in raw.items()}
            if cfg.is_encoder_decoder:
                batch["frames"] = jnp.asarray(make_batch(
                    cfg, args.batch, args.seq, seed=step)["frames"])
            params, opt_state, loss = train(params, opt_state, batch)
            losses.append(float(loss))
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                print(f"step {step:5d} loss {losses[-1]:.4f} "
                      f"({dt/(step+1):.2f}s/step)")
        if args.checkpoint_dir:
            out = save_checkpoint(args.checkpoint_dir, args.steps,
                                  {"params": params})
            print(f"checkpoint -> {out}")
        print(f"final loss {np.mean(losses[-5:]):.4f} "
              f"(initial {np.mean(losses[:5]):.4f})")
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), \
            "loss did not decrease"


if __name__ == "__main__":
    main()
