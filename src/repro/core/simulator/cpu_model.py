"""XNNPACK-style CPU cost model.

The paper's key empirical observation (Fig. 2) is that mobile CPUs running
XNNPACK's NEON GEMM/IGEMM kernels are competitive with the GPU for many
linear operations.  XNNPACK tiles the output into MR x NR register blocks
(f32 NEON: 6x8) and parallelizes over output-channel tile groups, so the CPU
latency curve is smooth in C_out except for mild quantization from tile and
thread-chunk granularity.
"""
from __future__ import annotations

from repro.core.simulator.devices import DeviceSpec
from repro.core.types import LinearOp, Op

_MR, _NR = 6, 8            # XNNPACK f32 NEON GEMM register tile
_L2_BYTES = 1.5e6          # per-core effective L2/SLC working-set knee


def cpu_latency_us(op: Op, dev: DeviceSpec, threads: int) -> float:
    """Deterministic CPU latency model (microseconds) for 1..n threads."""
    threads = max(1, threads)
    if isinstance(op, LinearOp):
        rows, red, cols = op.L, op.C_in, op.C_out
        in_bytes, w_bytes, out_bytes = (op.input_bytes, op.weight_bytes,
                                        op.output_bytes)
    else:
        # IGEMM view of convolution: rows = output pixels, reduction = K*K*Cin.
        rows, red, cols = op.H_out * op.W_out, op.K * op.K * op.C_in, op.C_out
        in_bytes = op.input_bytes * (1.0 + 0.1 * (op.K * op.K - 1))
        w_bytes, out_bytes = op.weight_bytes, op.output_bytes

    # Tile-padding waste (marginal, but keeps the model honest).
    padded_rows = -(-rows // _MR) * _MR
    padded_cols = -(-cols // _NR) * _NR
    flops = 2.0 * padded_rows * padded_cols * red

    # Thread-chunk quantization: XNNPACK splits the NR-tile grid across
    # threads; with few column tiles the split is imbalanced and the extra
    # threads simply idle (they do not slow the busy ones down).
    col_tiles = max(1, padded_cols // _NR)
    active = min(threads, col_tiles)
    chunks = -(-col_tiles // active)
    balance = col_tiles / (chunks * active)

    gflops = dev.cpu_gflops(active) * balance
    # Working sets that spill the shared L2/SLC run closer to DRAM speed.
    ws = in_bytes + w_bytes + out_bytes
    locality = 1.0 if ws <= _L2_BYTES * threads else 0.88
    compute_us = flops / (gflops * locality * 1e3)

    mem_us = (in_bytes + w_bytes + out_bytes) / (dev.cpu_mem_gbps * 1e3)

    # Thread wake-up/teardown cost grows mildly with the thread count.
    overhead = dev.cpu_op_overhead_us * (1.0 + 0.35 * (threads - 1))
    return overhead + max(compute_us, mem_us) + 0.1 * min(compute_us, mem_us)
