"""Measurement interface over the analytic device models.

`measure(...)` is the only way the rest of the system observes "hardware":
it adds reproducible log-normal measurement noise (thermal/scheduling jitter
survives even the paper's cooling-fan protocol, Section 5.1) so that the
trained predictors never see the analytic oracle exactly — the Table 1 MAPE
numbers are only meaningful against noisy observations.
"""
from __future__ import annotations

import hashlib
import math
from typing import Optional

import numpy as np

from repro.core.simulator.cpu_model import cpu_latency_us
from repro.core.simulator.devices import DEVICES, DeviceSpec
from repro.core.simulator.gpu_model import dispatch_for, gpu_latency_us
from repro.core.types import ConvOp, LinearOp, Op

_NOISE_SIGMA = 0.030


def _stable_seed(*parts) -> int:
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little")


def true_latency_us(op: Op, device: str, backend: str) -> float:
    """Noise-free latency (the simulator oracle). backend: 'gpu' | 'cpuN'."""
    dev = DEVICES[device]
    if op.C_out == 0:
        return 0.0
    if backend == "gpu":
        return gpu_latency_us(op, dev)
    if backend.startswith("cpu"):
        return cpu_latency_us(op, dev, int(backend[3:] or 1))
    raise ValueError(f"unknown backend {backend!r}")


def measure_latency_us(op: Op, device: str, backend: str,
                       repeats: int = 5, seed: int = 0) -> float:
    """Noisy measurement: median of `repeats` jittered observations."""
    base = true_latency_us(op, device, backend)
    if base == 0.0:
        return 0.0
    rng = np.random.default_rng(_stable_seed(device, backend, op, seed))
    obs = base * np.exp(rng.normal(0.0, _NOISE_SIGMA, size=repeats))
    return float(np.median(obs))
