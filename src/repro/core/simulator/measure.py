"""Measurement interface over the analytic device models.

`measure(...)` is the only way the rest of the system observes "hardware":
it adds reproducible log-normal measurement noise (thermal/scheduling jitter
survives even the paper's cooling-fan protocol, Section 5.1) so that the
trained predictors never see the analytic oracle exactly — the Table 1 MAPE
numbers are only meaningful against noisy observations.
"""
from __future__ import annotations

import hashlib
from typing import Sequence

import numpy as np

from repro.core.simulator.cpu_model import cpu_latency_us
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.gpu_model import gpu_latency_us
from repro.core.types import Op

_NOISE_SIGMA = 0.030


def _stable_seed(*parts) -> int:
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little")


def true_latency_us(op: Op, device: str, backend: str) -> float:
    """Noise-free latency (the simulator oracle). backend: 'gpu' | 'cpuN'."""
    dev = DEVICES[device]
    if op.C_out == 0:
        return 0.0
    if backend == "gpu":
        return gpu_latency_us(op, dev)
    if backend.startswith("cpu"):
        return cpu_latency_us(op, dev, int(backend[3:] or 1))
    raise ValueError(f"unknown backend {backend!r}")


def measure_latency_us(op: Op, device: str, backend: str,
                       repeats: int = 5, seed: int = 0) -> float:
    """Noisy measurement: median of `repeats` jittered observations."""
    return float(measure_latency_us_batch([op], device, backend,
                                          repeats=repeats, seed=seed)[0])


def measure_latency_us_batch(ops: Sequence[Op], device: str, backend: str,
                             repeats: int = 5, seed: int = 0) -> np.ndarray:
    """Batched measurement: one call for a whole candidate grid.

    Bit-identical to calling `measure_latency_us` per op — each op keeps its
    own stable noise stream (seeded by the op itself, so the same op measured
    alone or inside any batch observes the same jitter) while the noise
    application and median reduction are vectorized across the batch.
    """
    ops = list(ops)
    base = np.array([true_latency_us(op, device, backend) for op in ops])
    out = np.zeros(len(ops))
    nz = np.nonzero(base)[0]
    if nz.size == 0:
        return out
    noise = np.empty((nz.size, repeats))
    for row, i in enumerate(nz):
        rng = np.random.default_rng(_stable_seed(device, backend, ops[i],
                                                 seed))
        noise[row] = rng.normal(0.0, _NOISE_SIGMA, size=repeats)
    out[nz] = np.median(base[nz, None] * np.exp(noise), axis=1)
    return out
