"""Measurement interface over the analytic device models.

`measure(...)` is the only way the rest of the system observes "hardware":
it adds reproducible log-normal measurement noise (thermal/scheduling jitter
survives even the paper's cooling-fan protocol, Section 5.1) so that the
trained predictors never see the analytic oracle exactly — the Table 1 MAPE
numbers are only meaningful against noisy observations.

`measure_records(...)` emits the same observations in the unified
measurement schema (`repro.measure.MeasurementRecord`, wall = noisy
measurement, pred = noise-free oracle), so simulator measurements, executed
plan runs, and predictor training sets all flow through one record type.
"""
from __future__ import annotations

import hashlib
from typing import TYPE_CHECKING, List, Sequence

import numpy as np

from repro.core.simulator.cpu_model import cpu_latency_us
from repro.core.simulator.decode_model import (attn_cpu_latency_us,
                                               attn_gpu_latency_us,
                                               ssm_cpu_latency_us,
                                               ssm_gpu_latency_us)
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.gpu_model import gpu_latency_us
from repro.core.types import AttnOp, Op, SSMOp

if TYPE_CHECKING:
    from repro.measure.record import MeasurementRecord

_NOISE_SIGMA = 0.030


def _stable_seed(*parts) -> int:
    h = hashlib.blake2b(repr(parts).encode(), digest_size=8)
    return int.from_bytes(h.digest(), "little")


def true_latency_us(op: Op, device: str, backend: str) -> float:
    """Noise-free latency (the simulator oracle). backend: 'gpu' | 'cpuN'."""
    dev = DEVICES[device]
    if isinstance(op, (AttnOp, SSMOp)):
        if backend == "gpu":
            return (attn_gpu_latency_us(op, dev) if isinstance(op, AttnOp)
                    else ssm_gpu_latency_us(op, dev))
        if backend.startswith("cpu"):
            threads = int(backend[3:] or 1)
            return (attn_cpu_latency_us(op, dev, threads)
                    if isinstance(op, AttnOp)
                    else ssm_cpu_latency_us(op, dev, threads))
        raise ValueError(f"unknown backend {backend!r}")
    if op.C_out == 0:
        return 0.0
    if backend == "gpu":
        return gpu_latency_us(op, dev)
    if backend.startswith("cpu"):
        return cpu_latency_us(op, dev, int(backend[3:] or 1))
    raise ValueError(f"unknown backend {backend!r}")


def measure_latency_us(op: Op, device: str, backend: str,
                       repeats: int = 5, seed: int = 0) -> float:
    """Noisy measurement: median of `repeats` jittered observations."""
    return float(measure_latency_us_batch([op], device, backend,
                                          repeats=repeats, seed=seed)[0])


def _measure_batch_with_base(ops: Sequence[Op], device: str, backend: str,
                             repeats: int, seed: int
                             ) -> "tuple[np.ndarray, np.ndarray]":
    """(noisy medians, noise-free oracle) — the oracle is evaluated once
    and shared by both outputs."""
    base = np.array([true_latency_us(op, device, backend) for op in ops])
    out = np.zeros(len(ops))
    nz = np.nonzero(base)[0]
    if nz.size == 0:
        return out, base
    noise = np.empty((nz.size, repeats))
    for row, i in enumerate(nz):
        rng = np.random.default_rng(_stable_seed(device, backend, ops[i],
                                                 seed))
        noise[row] = rng.normal(0.0, _NOISE_SIGMA, size=repeats)
    out[nz] = np.median(base[nz, None] * np.exp(noise), axis=1)
    return out, base


def measure_latency_us_batch(ops: Sequence[Op], device: str, backend: str,
                             repeats: int = 5, seed: int = 0) -> np.ndarray:
    """Batched measurement: one call for a whole candidate grid.

    Bit-identical to calling `measure_latency_us` per op — each op keeps its
    own stable noise stream (seeded by the op itself, so the same op measured
    alone or inside any batch observes the same jitter) while the noise
    application and median reduction are vectorized across the batch.
    """
    return _measure_batch_with_base(list(ops), device, backend, repeats,
                                    seed)[0]


def measure_records(ops: Sequence[Op], device: str, backend: str,
                    repeats: int = 5, seed: int = 0
                    ) -> List["MeasurementRecord"]:
    """Batched measurement in the unified schema: one `MeasurementRecord`
    per op, `wall_us` = the noisy observation (bit-identical to
    `measure_latency_us_batch`), `pred_us` = the noise-free oracle.

    These records feed the same store/calibration/training pipeline as
    executed plan runs (`core/predictor/dataset.training_from_records`).
    """
    from repro.measure.record import record_for_op
    ops = list(ops)
    walls, oracle = _measure_batch_with_base(ops, device, backend, repeats,
                                             seed)
    return [record_for_op(op, index=i, wall_us=float(walls[i]),
                          pred_us=float(oracle[i]),
                          device=device, backend=backend)
            for i, op in enumerate(ops)]
