from repro.core.simulator.devices import DEVICES, DeviceSpec
from repro.core.simulator.gpu_model import (ALL_KERNELS, GpuDispatch,
                                            dispatch_for, gpu_latency_us,
                                            select_conv_kernel)
from repro.core.simulator.cpu_model import cpu_latency_us
from repro.core.simulator.measure import measure_latency_us, true_latency_us

__all__ = [
    "DEVICES", "DeviceSpec", "ALL_KERNELS", "GpuDispatch", "dispatch_for",
    "gpu_latency_us", "select_conv_kernel", "cpu_latency_us",
    "measure_latency_us", "true_latency_us",
]
