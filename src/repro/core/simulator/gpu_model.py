"""White-box model of the TFLite GPU (OpenCL) delegate.

This module re-implements, in simplified analytic form, the two mechanisms
the paper identifies as the cause of discontinuous GPU latency (Section 3.1):

  1. *Heuristic workgroup choices* — the delegate picks a workgroup shape by
     divisibility heuristics; awkward output-channel counts fall back to tiny
     workgroups, inflating the workgroup count and the latency (Fig. 6a).
  2. *Kernel selection* — convolutions switch between `conv_constant`,
     `winograd` and `conv_generic` implementations based on the operation
     parameters, with distinct performance characteristics (Fig. 6b).

The latency model is wave-based: workgroups execute in waves across compute
units, so latency is a *step function* of the workgroup count — exactly the
quantization that black-box shape-only predictors cannot capture.

Everything here is deterministic given (device, op); the measurement noise
lives in measure.py.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core.simulator.devices import DeviceSpec
from repro.core.types import ConvOp, LinearOp, Op

# Kernel implementation identifiers (match the paper's Section 3.2 taxonomy).
KERNEL_LINEAR = "linear_generic"
KERNEL_CONV_GENERIC = "conv_generic"
KERNEL_CONV_CONSTANT = "conv_constant"
KERNEL_CONV_WINOGRAD = "winograd"

ALL_KERNELS = (
    KERNEL_LINEAR,
    KERNEL_CONV_GENERIC,
    KERNEL_CONV_CONSTANT,
    KERNEL_CONV_WINOGRAD,
)

# Candidate workgroup shapes (x: float4 output-channel slices, y: rows),
# ordered by preference, mirroring the delegate's divisor-based selection.
_LINEAR_WG_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (64, 2), (32, 4), (32, 2), (16, 4), (8, 4),
)
_LINEAR_WG_FALLBACK: Tuple[int, int] = (4, 4)

_CONV_WG_CANDIDATES: Tuple[Tuple[int, int], ...] = (
    (32, 4), (16, 8), (16, 4), (8, 8), (8, 4),
)
_CONV_WG_FALLBACK: Tuple[int, int] = (4, 4)

# Threads needed per compute unit for full latency hiding; below this the
# kernel is occupancy-bound (matters for skinny matrices, e.g. L=50).
_OCCUPANCY_THREADS_PER_CU = 2048.0
# Per-workgroup scheduling cost.
_WG_SCHED_US = 0.055
# Workgroups below this thread count underutilize the SIMD lanes.
_FULL_EFF_THREADS = 64.0


@dataclasses.dataclass(frozen=True)
class GpuDispatch:
    """Kernel dispatch information — the paper's augmentation features."""

    kernel: str
    wg_x: int                 # workgroup shape (channel-slices dimension)
    wg_y: int                 # workgroup shape (spatial/row dimension)
    grid_x: int               # number of workgroups along x
    grid_y: int               # number of workgroups along y
    total_threads: int
    padded_flops: float

    @property
    def wg_size(self) -> int:
        return self.wg_x * self.wg_y

    @property
    def wg_count(self) -> int:
        return self.grid_x * self.grid_y


def _pick_workgroup(out_slices: int, rows: int,
                    candidates: Tuple[Tuple[int, int], ...],
                    fallback: Tuple[int, int]) -> Tuple[int, int]:
    """Divisor-preference heuristic: the first candidate whose x dimension
    divides the output-slice count (with enough rows to fill y) wins; awkward
    channel counts fall through to a small, inefficient workgroup."""
    for wx, wy in candidates:
        if out_slices % wx == 0 and rows >= wy:
            return wx, wy
    # Secondary pass: accept <=12.5% padding along x.
    for wx, wy in candidates:
        if rows >= wy and (-out_slices) % wx <= wx // 8:
            return wx, wy
    return fallback


def select_conv_kernel(op: ConvOp, dev: DeviceSpec) -> str:
    """TFLite-style convolution kernel selection (Section 3.2)."""
    if (op.K == 3 and op.S == 1 and op.C_out >= 128
            and op.H_out * op.W_out >= 1024 and op.C_in >= 32):
        return KERNEL_CONV_WINOGRAD
    if op.weight_bytes <= dev.gpu_constant_mem_kb * 1024:
        return KERNEL_CONV_CONSTANT
    return KERNEL_CONV_GENERIC


def dispatch_for(op: Op, dev: DeviceSpec) -> GpuDispatch:
    """Compute the kernel choice + workgroup geometry for an operation."""
    if isinstance(op, LinearOp):
        out_slices = _ceil_div(op.C_out, 4)
        rows = op.L
        wx, wy = _pick_workgroup(out_slices, rows, _LINEAR_WG_CANDIDATES,
                                 _LINEAR_WG_FALLBACK)
        gx, gy = _ceil_div(out_slices, wx), _ceil_div(rows, wy)
        padded_flops = (gx * wx * 4) * (gy * wy) * op.C_in * 2.0
        return GpuDispatch(KERNEL_LINEAR, wx, wy, gx, gy,
                           out_slices * rows, padded_flops)

    kernel = select_conv_kernel(op, dev)
    out_slices = _ceil_div(op.C_out, 4)
    if kernel == KERNEL_CONV_WINOGRAD:
        # F(2x2, 3x3): one thread per 2x2 output tile per channel slice.
        rows = _ceil_div(op.H_out, 2) * _ceil_div(op.W_out, 2)
        reduction = 16 * op.C_in * 2.0          # 4x4 Hadamard-domain MACs
    else:
        rows = op.H_out * op.W_out
        reduction = op.K * op.K * op.C_in * 2.0
    wx, wy = _pick_workgroup(out_slices, rows, _CONV_WG_CANDIDATES,
                             _CONV_WG_FALLBACK)
    gx, gy = _ceil_div(out_slices, wx), _ceil_div(rows, wy)
    padded_flops = (gx * wx * 4) * (gy * wy) * reduction
    return GpuDispatch(kernel, wx, wy, gx, gy, out_slices * rows, padded_flops)


def gpu_latency_us(op: Op, dev: DeviceSpec) -> float:
    """Deterministic GPU latency model (microseconds)."""
    d = dispatch_for(op, dev)

    # --- occupancy: skinny problems cannot hide memory latency ---
    occupancy = min(1.0, d.total_threads /
                    (_OCCUPANCY_THREADS_PER_CU * dev.gpu_compute_units))
    # --- per-workgroup SIMD efficiency: tiny workgroups waste lanes ---
    # (floored: even the fallback workgroup keeps half the lanes busy; this
    # bounds heuristic-miss spikes near the paper's observed ~1.85x)
    wg_eff = max(0.5, min(1.0, d.wg_size / _FULL_EFF_THREADS))

    kernel_eff = {
        KERNEL_LINEAR: 1.0,
        KERNEL_CONV_GENERIC: 0.92,
        KERNEL_CONV_CONSTANT: 1.18,   # constant-memory broadcast of weights
        KERNEL_CONV_WINOGRAD: 0.80,   # transform overhead, worse locality
    }[d.kernel]

    eff_gflops = dev.gpu_gflops * kernel_eff * wg_eff * (occupancy ** 0.65)

    # Wave quantization: workgroups run in waves over the compute units.
    slots = dev.gpu_compute_units * max(1, int(512 // max(1, d.wg_size)))
    waves = _ceil_div(d.wg_count, slots)
    quant = (waves * slots) / max(1, d.wg_count)   # >=1, last-wave waste

    compute_us = d.padded_flops * quant / (eff_gflops * 1e3)

    # Memory traffic (unified memory; weights dominate for linear layers).
    if isinstance(op, LinearOp):
        padded_w = op.C_in * (d.grid_x * d.wg_x * 4) * 4.0
        bytes_total = op.input_bytes + padded_w + op.output_bytes
    else:
        reuse = 1.0 + 0.15 * (op.K * op.K - 1)   # halo re-reads via L1/texture
        if d.kernel == KERNEL_CONV_WINOGRAD:
            # 4x4 input tiles overlap by 2: ~4x input amplification, plus
            # Hadamard-domain intermediates.
            bytes_total = (4.0 * op.input_bytes + op.weight_bytes * (16 / 9)
                           + 2.0 * op.output_bytes)
        else:
            bytes_total = (reuse * op.input_bytes + op.weight_bytes
                           + op.output_bytes)
    mem_us = bytes_total / (dev.gpu_mem_gbps * 1e3)

    sched_us = _WG_SCHED_US * d.wg_count / dev.gpu_compute_units
    if d.kernel == KERNEL_CONV_WINOGRAD:
        # input/output transform passes are separate small kernels
        sched_us += 2 * dev.gpu_dispatch_us * 0.35

    return (dev.gpu_dispatch_us + sched_us
            + max(compute_us, mem_us) + 0.18 * min(compute_us, mem_us))


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)
