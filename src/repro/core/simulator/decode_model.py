"""Analytic latency models for the decode-block kinds (attention / SSM).

The conv/linear models (`gpu_model.py` / `cpu_model.py`) capture the
paper's workgroup-heuristic and GEMM-tiling phenomena; decode attention
and SSD scans have different bottlenecks, modeled here:

  * **decode attention** is memory-bound on the KV cache (the query is a
    single position), so latency tracks cache traffic plus fixed dispatch
    cost.  The kernel *mode* changes the constant structure: ``streaming``
    fuses scores+softmax+weighted-sum into one pass (one dispatch, online
    softmax bookkeeping inflates compute ~12%), ``materialized`` runs two
    plain passes with the (H, S) scores matrix written and re-read.
  * **SSD scans** trade a sequential recurrence against chunked
    parallelism: ``recurrent`` pays a per-step cost that scales with T
    (cheap at T=1, the flash-linear-attention decode regime),
    ``chunked`` pays fixed chunk-setup overhead but runs the intra-chunk
    work at matrix-unit efficiency (wins for prefill-sized T).

Head-split / kv-block / state-split sub-ops (`AttnOp.with_heads`,
``with_cache``, `SSMOp.with_heads`) flow through these same formulas, so
the planner's (axis, split, mode) candidates are priced consistently.
Everything is deterministic given (device, op); measurement noise lives
in measure.py.
"""
from __future__ import annotations

from repro.core.simulator.devices import DeviceSpec
from repro.core.types import AttnOp, SSMOp

# Decode-shaped problems (a handful of rows) cannot fill the GPU: the
# effective throughput fraction at batch-1 decode occupancy.
_GPU_DECODE_OCCUPANCY = 0.25
# Online-softmax running max/sum bookkeeping, per the streaming mode.
_STREAMING_COMPUTE_OVERHEAD = 1.12
_CPU_STREAMING_OVERHEAD = 1.25
# Chunked SSD scans launch an intra-chunk pass and a state-carry pass.
_SSM_CHUNK = 256
_SSM_CHUNK_EFF_GPU = 0.45
_SSM_RECURRENT_EFF_GPU = 0.12
# Sequential recurrence: per-step scheduling cost on each backend.
_SSM_STEP_US_GPU = 0.9
_SSM_STEP_US_CPU = 0.08


def _attn_traffic_bytes(op: AttnOp) -> float:
    """KV cache + query/output activations; materialized mode adds the
    scores matrix (written by pass 1, re-read by pass 2)."""
    total = float(op.weight_bytes + op.input_bytes + op.output_bytes)
    if op.mode == "materialized":
        total += 2.0 * 4.0 * op.H * op.S
    return total


def attn_gpu_latency_us(op: AttnOp, dev: DeviceSpec) -> float:
    eff_gflops = dev.gpu_gflops * _GPU_DECODE_OCCUPANCY
    if op.mode == "streaming":
        dispatches = 1
        compute_us = (op.flops * _STREAMING_COMPUTE_OVERHEAD
                      / (eff_gflops * 1e3))
    else:
        dispatches = 2
        compute_us = op.flops / (eff_gflops * 1e3)
    mem_us = _attn_traffic_bytes(op) / (dev.gpu_mem_gbps * 1e3)
    return (dispatches * dev.gpu_dispatch_us
            + max(compute_us, mem_us) + 0.18 * min(compute_us, mem_us))


def attn_cpu_latency_us(op: AttnOp, dev: DeviceSpec, threads: int) -> float:
    threads = max(1, threads)
    # parallelism is over KV head groups — a 1-kv-head sub-op is serial
    active = min(threads, op.KV)
    gflops = dev.cpu_gflops(active)
    overhead = 1.0 if op.mode == "materialized" else _CPU_STREAMING_OVERHEAD
    compute_us = op.flops * overhead / (gflops * 1e3)
    mem_us = _attn_traffic_bytes(op) / (dev.cpu_mem_gbps * 1e3)
    fixed = dev.cpu_op_overhead_us * (1.0 + 0.35 * (threads - 1))
    return fixed + max(compute_us, mem_us) + 0.1 * min(compute_us, mem_us)


def _ssm_traffic_bytes(op: SSMOp) -> float:
    return float(op.input_bytes + op.weight_bytes + op.output_bytes)


def ssm_gpu_latency_us(op: SSMOp, dev: DeviceSpec) -> float:
    mem_us = _ssm_traffic_bytes(op) / (dev.gpu_mem_gbps * 1e3)
    if op.mode == "chunked":
        # intra-chunk pass + state-carry pass, each a dispatch
        dispatches = 2
        compute_us = (op.flops
                      / (dev.gpu_gflops * _SSM_CHUNK_EFF_GPU * 1e3))
        step_us = 0.0
    else:
        dispatches = 1
        compute_us = (op.flops
                      / (dev.gpu_gflops * _SSM_RECURRENT_EFF_GPU * 1e3))
        step_us = _SSM_STEP_US_GPU * op.T
    return (dispatches * dev.gpu_dispatch_us + step_us
            + max(compute_us, mem_us) + 0.18 * min(compute_us, mem_us))


def ssm_cpu_latency_us(op: SSMOp, dev: DeviceSpec, threads: int) -> float:
    threads = max(1, threads)
    # parallelism is across state heads (the scan is sequential in T)
    active = min(threads, op.H)
    gflops = dev.cpu_gflops(active)
    if op.mode == "chunked":
        # chunking trades a second sweep over the state for parallel form
        compute_us = op.flops * 1.15 / (gflops * 1e3)
        step_us = 0.0
    else:
        compute_us = op.flops / (gflops * 1e3)
        step_us = _SSM_STEP_US_CPU * op.T
    mem_us = _ssm_traffic_bytes(op) / (dev.cpu_mem_gbps * 1e3)
    fixed = dev.cpu_op_overhead_us * (1.0 + 0.35 * (threads - 1))
    return (fixed + step_us
            + max(compute_us, mem_us) + 0.1 * min(compute_us, mem_us))
