"""Calibrated performance specifications of the paper's four mobile platforms.

The container has no mobile SoC, so the paper's measurement substrate is
replaced by a *white-box performance model* of each platform.  The constants
below are calibrated (see tests/test_calibration.py and benchmarks/) so that
the simulated latency curves reproduce the paper's *qualitative and
quantitative* phenomena:

  * Fig. 2  — CPU(3 threads) beats GPU for (50,3072)x(3072,C) when C < ~425
              on OnePlus 11;
  * Fig. 5/6 — discontinuous GPU latency spikes from workgroup heuristics and
              kernel switching;
  * Tab. 2  — co-execution speedup ordering Pixel 5 > Pixel 4 > Moto 2022 >
              OnePlus 11 (larger CPU/GPU performance gap => lower speedup);
  * Sec. 4  — event-notification sync overhead ~162 us vs fine-grained SVM
              polling ~7 us (Moto 2022).

Throughputs are *effective* (achievable) rather than datasheet-peak numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    name: str
    # --- GPU (TFLite OpenCL delegate model) ---
    gpu_gflops: float              # effective fp16/fp32 MAD throughput, GFLOP/s
    gpu_compute_units: int         # number of shader cores / CUs
    gpu_mem_gbps: float            # effective memory bandwidth seen by the GPU
    gpu_dispatch_us: float         # fixed per-kernel dispatch/driver latency
    gpu_constant_mem_kb: int       # on-chip constant memory (conv_constant)
    # --- CPU (XNNPACK model) ---
    cpu_gflops_per_core: float     # effective NEON fp32 throughput per big core
    cpu_big_cores: int
    cpu_mem_gbps: float            # effective memory bandwidth seen by the CPU
    cpu_thread_eff: Tuple[float, ...]  # parallel efficiency for 1..n threads
    cpu_op_overhead_us: float      # per-op XNNPACK scheduling overhead
    # --- synchronization (Section 4) ---
    sync_event_us: float           # clWaitForEvents-style notification delay
    sync_svm_us: float             # fine-grained SVM active-polling overhead

    def cpu_gflops(self, threads: int) -> float:
        threads = max(1, min(threads, self.cpu_big_cores))
        return self.cpu_gflops_per_core * threads * self.cpu_thread_eff[threads - 1]


# Calibration notes:
#  - Pixel 5 pairs a mid-range GPU (Adreno 620) with the same CPU class as
#    Pixel 4, hence the narrowest GPU/CPU gap and the best co-exec speedups.
#  - OnePlus 11 (Adreno 740) has the widest gap, hence the smallest speedups.
#  - sync_* for Moto 2022 matches the paper's measured 162 us / 7 us.
DEVICES: Dict[str, DeviceSpec] = {
    "pixel4": DeviceSpec(
        name="pixel4",
        gpu_gflops=150.0, gpu_compute_units=2, gpu_mem_gbps=14.0,
        gpu_dispatch_us=35.0, gpu_constant_mem_kb=48,
        cpu_gflops_per_core=58.0, cpu_big_cores=4, cpu_mem_gbps=12.0,
        cpu_thread_eff=(1.0, 0.95, 0.90, 0.82), cpu_op_overhead_us=11.0,
        sync_event_us=148.0, sync_svm_us=7.5,
    ),
    "pixel5": DeviceSpec(
        name="pixel5",
        gpu_gflops=102.0, gpu_compute_units=1, gpu_mem_gbps=12.0,
        gpu_dispatch_us=30.0, gpu_constant_mem_kb=48,
        cpu_gflops_per_core=52.0, cpu_big_cores=2, cpu_mem_gbps=11.0,
        # Pixel 5 has 2 big (A76) + 6 little cores; thread 3 lands on a
        # little core, hence the strong efficiency drop at 3 threads.
        cpu_thread_eff=(1.0, 0.93, 0.78, 0.66), cpu_op_overhead_us=12.0,
        sync_event_us=155.0, sync_svm_us=8.0,
    ),
    "moto2022": DeviceSpec(
        name="moto2022",
        gpu_gflops=370.0, gpu_compute_units=3, gpu_mem_gbps=28.0,
        gpu_dispatch_us=24.0, gpu_constant_mem_kb=64,
        cpu_gflops_per_core=82.0, cpu_big_cores=4, cpu_mem_gbps=22.0,
        cpu_thread_eff=(1.0, 0.94, 0.88, 0.80), cpu_op_overhead_us=9.0,
        sync_event_us=162.0, sync_svm_us=7.0,   # Section 4: 162 us -> 7 us
        ),
    "oneplus11": DeviceSpec(
        name="oneplus11",
        gpu_gflops=500.0, gpu_compute_units=4, gpu_mem_gbps=34.0,
        gpu_dispatch_us=20.0, gpu_constant_mem_kb=64,
        cpu_gflops_per_core=80.0, cpu_big_cores=5, cpu_mem_gbps=26.0,
        cpu_thread_eff=(1.0, 0.95, 0.89, 0.82, 0.75), cpu_op_overhead_us=8.0,
        sync_event_us=150.0, sync_svm_us=6.5,
    ),
}

# Pixel 5's big-core count is 2, but the paper runs up to 3 CPU threads on
# every device; thread_eff above already encodes the little-core penalty, so
# allow up to len(cpu_thread_eff) threads everywhere.
for _d in DEVICES.values():
    object.__setattr__(_d, "cpu_big_cores", len(_d.cpu_thread_eff))
