"""End-to-end model partition planning (paper Section 5.4).

Runs the per-operation partitioner over a whole network graph (offline, as
"part of the compilation process"), then evaluates:

  * baseline        — every op on the GPU;
  * individual ops  — sum of each op's co-execution latency in isolation;
  * end-to-end      — co-execution schedule including inter-layer effects:
    pooling stays on the GPU (free of sync overhead), and a boundary cost is
    charged when consecutive layers change their channel split, because each
    side then consumes activations the *other* side produced (extra
    cache-coherent traffic through the shared memory) — this is the paper's
    observed "memory access overhead between layers" that makes end-to-end
    speedups slightly lower than per-op speedups.

The whole network is planned in a fixed number of batched calls: one
baseline measurement batch, two predictor batches covering every candidate
split of every op, and two realized-latency measurement batches — no
per-candidate (or per-op) Python loops on the scoring hot path.

`plan_graph` is the IR-era entry point: it walks a `repro.graph.Graph` in
topological order, partitions every *splittable* node (conv/linear) through
the same batched calls, and charges non-splittable op nodes (attention,
ssm) an analytic GPU-side latency (`opaque_latency_us`) — they stay
unsplit, like pooling, but unlike pooling they are real compute whose
charge scales with the op.  On a unit-chain graph the walk performs the
identical float operations in the identical order as `plan_network`, so
decisions *and* totals are bit-equal — the compatibility contract the
plan cache relies on.  `plan_network` remains the legacy unit-list
implementation (and the reference the equivalence tests pin against).
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Sequence

import numpy as np

from repro.core.networks import Unit
from repro.core.partitioner import (PartitionDecision,
                                    axis_partition_batch,
                                    axis_realized_latency_us_batch,
                                    optimal_partition_batch,
                                    realized_latency_us_batch)
from repro.core.predictor.train import LatencyPredictor
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.measure import measure_latency_us_batch
from repro.core.sync import SyncMechanism
from repro.core.types import Op
from repro.kernels import registry

if TYPE_CHECKING:
    from repro.graph.ir import Graph


@dataclasses.dataclass
class PlanReport:
    device: str
    threads: int
    baseline_us: float          # all-GPU
    individual_us: float        # sum of isolated co-exec latencies
    end_to_end_us: float        # schedule incl. boundary costs
    decisions: List[PartitionDecision]

    @property
    def individual_speedup(self) -> float:
        return self.baseline_us / self.individual_us

    @property
    def end_to_end_speedup(self) -> float:
        return self.baseline_us / self.end_to_end_us


def _pool_latency_us(device: str) -> float:
    # pooling is bandwidth-trivial; charge one dispatch (paper: negligible)
    return DEVICES[device].gpu_dispatch_us * 0.6


def plan_network(units: Sequence[Unit], cpu_pred: LatencyPredictor,
                 gpu_pred: LatencyPredictor, *, threads: int,
                 mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                 step: int = 8, seed: int = 1) -> PlanReport:
    device = gpu_pred.device
    dev = DEVICES[device]

    ops = [payload for kind, payload in units if kind != "pool"]
    gpu_only = measure_latency_us_batch(ops, device, "gpu", seed=seed)
    decisions = optimal_partition_batch(ops, cpu_pred, gpu_pred,
                                        mechanism=mechanism, step=step)
    t_co = realized_latency_us_batch(decisions, device, threads,
                                     mechanism=mechanism, seed=seed)

    # Accumulate in schedule order (identical float-add order to a unit-by-
    # unit walk, so totals match the loop formulation exactly).
    baseline = 0.0
    individual = 0.0
    e2e = 0.0
    prev_split_frac = 0.0       # fraction of channels on CPU in previous op
    i = 0
    for kind, payload in units:
        if kind == "pool":
            t = _pool_latency_us(device)
            baseline += t
            individual += t
            e2e += t
            prev_split_frac = 0.0     # pooling runs wholly on GPU
            continue
        op = payload
        baseline += float(gpu_only[i])
        individual += float(t_co[i])

        dec = decisions[i]
        split_frac = dec.c_cpu / max(1, op.C_out)
        # boundary traffic: activations crossing the CPU/GPU ownership
        # boundary between consecutive layers move through shared memory.
        crossing = abs(split_frac - prev_split_frac) * op.input_bytes
        boundary_us = crossing / (dev.cpu_mem_gbps * 1e3)
        e2e += float(t_co[i]) + boundary_us
        prev_split_frac = split_frac
        i += 1

    return PlanReport(device=device, threads=threads, baseline_us=baseline,
                      individual_us=individual, end_to_end_us=e2e,
                      decisions=decisions)


# ----------------------------------------------------------- graph planning

def opaque_latency_us(op: Op, device: str) -> float:
    """Analytic GPU-side charge for a non-splittable op node (attention,
    ssm): one dispatch plus the roofline max of compute and memory time.
    Deterministic — it keys plan caching like every other planning input."""
    dev = DEVICES[device]
    bytes_total = op.input_bytes + op.weight_bytes + op.output_bytes
    compute_us = op.flops / (dev.gpu_gflops * 1e3)
    mem_us = bytes_total / (dev.gpu_mem_gbps * 1e3)
    return dev.gpu_dispatch_us + max(compute_us, mem_us)


@dataclasses.dataclass
class GraphPlanReport:
    """`plan_graph`'s result: per-node decisions keyed by node id.

    `decisions` holds the splittable (conv/linear) nodes' partition
    choices; `opaque_us` the analytic charges of non-splittable op nodes
    (attention/ssm).  Totals follow the `PlanReport` semantics.
    """

    device: str
    threads: int
    baseline_us: float
    individual_us: float
    end_to_end_us: float
    decisions: Dict[str, PartitionDecision]
    opaque_us: Dict[str, float]

    @property
    def individual_speedup(self) -> float:
        return self.baseline_us / self.individual_us

    @property
    def end_to_end_speedup(self) -> float:
        return self.baseline_us / self.end_to_end_us


def _can_price_kind(pred: LatencyPredictor, kind: str) -> bool:
    """Whether a predictor bundle can price an attention/ssm op: a
    `MuxPredictor` trained with the kind's member.  Plain per-kind
    predictors (and legacy conv/linear-only bundles) cannot — those
    planner calls keep the pre-axis opaque-charge behavior."""
    member = getattr(pred, "member", None)
    return member is not None and member(kind) is not None


def _axis_cpu_frac(dec: PartitionDecision) -> float:
    """CPU-resident fraction of a decision's output channels, for the
    boundary-traffic term.  Stackable axis splits own channels pro-rata;
    a kv-block split materializes its merged output GPU-side (0); an
    exclusive CPU placement owns everything (1)."""
    if dec.axis == "channel":
        return dec.c_cpu / max(1, dec.op.C_out)
    if dec.axis == "none":
        return 1.0 if dec.c_gpu == 0 else 0.0
    spec = registry.axis_spec(registry.op_kind(dec.op), dec.axis)
    if not spec.stackable:
        return 0.0
    return dec.c_cpu / max(1, spec.size(dec.op))


def plan_graph(graph: "Graph", cpu_pred: LatencyPredictor,
               gpu_pred: LatencyPredictor, *, threads: int,
               mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
               step: int = 8, seed: int = 1) -> GraphPlanReport:
    """Plan a `repro.graph.Graph` (the IR-era `plan_network`).

    Splittable nodes are partitioned in the same batched predictor /
    measurement calls as the unit-list path; structural nodes (pool, add)
    are charged one trivial GPU dispatch.  Attention/ssm nodes are scored
    over their typed (axis, boundary, mode) candidate grids — two more
    batched predictor calls — when the predictor bundle has their per-kind
    members; otherwise they keep the analytic `opaque_latency_us` charge
    with a forced exclusive placement (the pre-axis behavior, still used
    by conv/linear-only predictor bundles).
    The boundary-traffic term follows graph edges: a node's crossing cost
    compares its CPU-channel fraction against its *producer's* (0 for
    structural and opaque producers, which materialize GPU-side) — on a
    chain this is exactly `plan_network`'s consecutive-layer rule.
    """
    device = gpu_pred.device
    dev = DEVICES[device]

    split_nodes = graph.splittable_nodes()
    ops = [n.op for n in split_nodes]
    gpu_only = measure_latency_us_batch(ops, device, "gpu", seed=seed)
    decision_list = optimal_partition_batch(ops, cpu_pred, gpu_pred,
                                            mechanism=mechanism, step=step)
    t_co = realized_latency_us_batch(decision_list, device, threads,
                                     mechanism=mechanism, seed=seed)

    axis_nodes = [n for n in graph
                  if n.op is not None and not n.splittable
                  and _can_price_kind(cpu_pred, n.kind)
                  and _can_price_kind(gpu_pred, n.kind)]
    axis_gpu_only = measure_latency_us_batch([n.op for n in axis_nodes],
                                             device, "gpu", seed=seed)
    axis_list = axis_partition_batch([n.op for n in axis_nodes],
                                     cpu_pred, gpu_pred,
                                     mechanism=mechanism)
    axis_t_co = axis_realized_latency_us_batch(axis_list, device, threads,
                                               mechanism=mechanism,
                                               seed=seed)
    axis_index = {n.id: j for j, n in enumerate(axis_nodes)}

    decisions: Dict[str, PartitionDecision] = {}
    opaque_us: Dict[str, float] = {}
    split_frac: Dict[str, float] = {}      # node id -> CPU-channel fraction
    baseline = 0.0
    individual = 0.0
    e2e = 0.0
    i = 0
    for node in graph:
        if node.splittable:
            op = node.op
            baseline += float(gpu_only[i])
            individual += float(t_co[i])
            dec = decision_list[i]
            decisions[node.id] = dec
            frac = dec.c_cpu / max(1, op.C_out)
            frac_in = split_frac.get(node.inputs[0], 0.0) \
                if node.inputs else 0.0
            crossing = abs(frac - frac_in) * op.input_bytes
            boundary_us = crossing / (dev.cpu_mem_gbps * 1e3)
            e2e += float(t_co[i]) + boundary_us
            split_frac[node.id] = frac
            i += 1
        elif node.id in axis_index:        # attention / ssm: typed axes
            j = axis_index[node.id]
            dec = axis_list[j]
            decisions[node.id] = dec
            baseline += float(axis_gpu_only[j])
            individual += float(axis_t_co[j])
            frac = _axis_cpu_frac(dec)
            frac_in = split_frac.get(node.inputs[0], 0.0) \
                if node.inputs else 0.0
            crossing = abs(frac - frac_in) * node.op.input_bytes
            boundary_us = crossing / (dev.cpu_mem_gbps * 1e3)
            e2e += float(axis_t_co[j]) + boundary_us
            split_frac[node.id] = frac
        elif node.op is not None:          # attention / ssm: exclusive
            t = opaque_latency_us(node.op, device)
            opaque_us[node.id] = t
            baseline += t
            individual += t
            e2e += t
            split_frac[node.id] = 0.0
        else:                              # pool / add: trivial GPU dispatch
            t = _pool_latency_us(device)
            baseline += t
            individual += t
            e2e += t
            split_frac[node.id] = 0.0

    return GraphPlanReport(device=device, threads=threads,
                           baseline_us=baseline, individual_us=individual,
                           end_to_end_us=e2e, decisions=decisions,
                           opaque_us=opaque_us)


def grid_plan_graph(graph: "Graph", device: str, threads: int, *,
                    mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                    step: int = 8, seed: int = 0) -> GraphPlanReport:
    """Measurement-driven (oracle) graph planning: grid-searches every
    splittable node over channels and every attention/ssm node over its
    typed (axis, boundary, mode) grid.  No end-to-end totals — the grid
    oracle is a per-op upper bound (Table 2), so the report carries
    decisions only (totals 0)."""
    from repro.core.partitioner import (grid_axis_partition_batch,
                                        grid_search_partition_batch)

    split_nodes = graph.splittable_nodes()
    decision_list = grid_search_partition_batch(
        [n.op for n in split_nodes], device, threads, mechanism=mechanism,
        step=step, seed=seed)
    decisions = {n.id: d for n, d in zip(split_nodes, decision_list)}
    axis_nodes = [n for n in graph
                  if n.op is not None and not n.splittable]
    axis_list = grid_axis_partition_batch(
        [n.op for n in axis_nodes], device, threads, mechanism=mechanism,
        seed=seed)
    decisions.update({n.id: d for n, d in zip(axis_nodes, axis_list)})
    return GraphPlanReport(device=device, threads=threads, baseline_us=0.0,
                           individual_us=0.0, end_to_end_us=0.0,
                           decisions=decisions, opaque_us={})
