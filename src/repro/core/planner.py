"""End-to-end model partition planning (paper Section 5.4).

Runs the per-operation partitioner over a whole network graph (offline, as
"part of the compilation process"), then evaluates:

  * baseline        — every op on the GPU;
  * individual ops  — sum of each op's co-execution latency in isolation;
  * end-to-end      — co-execution schedule including inter-layer effects:
    pooling stays on the GPU (free of sync overhead), and a boundary cost is
    charged when consecutive layers change their channel split, because each
    side then consumes activations the *other* side produced (extra
    cache-coherent traffic through the shared memory) — this is the paper's
    observed "memory access overhead between layers" that makes end-to-end
    speedups slightly lower than per-op speedups.

The whole network is planned in a fixed number of batched calls: one
baseline measurement batch, two predictor batches covering every candidate
split of every op, and two realized-latency measurement batches — no
per-candidate (or per-op) Python loops on the scoring hot path.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from repro.core.networks import Unit
from repro.core.partitioner import (PartitionDecision,
                                    optimal_partition_batch,
                                    realized_latency_us_batch)
from repro.core.predictor.train import LatencyPredictor
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.measure import measure_latency_us_batch
from repro.core.sync import SyncMechanism


@dataclasses.dataclass
class PlanReport:
    device: str
    threads: int
    baseline_us: float          # all-GPU
    individual_us: float        # sum of isolated co-exec latencies
    end_to_end_us: float        # schedule incl. boundary costs
    decisions: List[PartitionDecision]

    @property
    def individual_speedup(self) -> float:
        return self.baseline_us / self.individual_us

    @property
    def end_to_end_speedup(self) -> float:
        return self.baseline_us / self.end_to_end_us


def _pool_latency_us(device: str) -> float:
    # pooling is bandwidth-trivial; charge one dispatch (paper: negligible)
    return DEVICES[device].gpu_dispatch_us * 0.6


def plan_network(units: Sequence[Unit], cpu_pred: LatencyPredictor,
                 gpu_pred: LatencyPredictor, *, threads: int,
                 mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                 step: int = 8, seed: int = 1) -> PlanReport:
    device = gpu_pred.device
    dev = DEVICES[device]

    ops = [payload for kind, payload in units if kind != "pool"]
    gpu_only = measure_latency_us_batch(ops, device, "gpu", seed=seed)
    decisions = optimal_partition_batch(ops, cpu_pred, gpu_pred,
                                        mechanism=mechanism, step=step)
    t_co = realized_latency_us_batch(decisions, device, threads,
                                     mechanism=mechanism, seed=seed)

    # Accumulate in schedule order (identical float-add order to a unit-by-
    # unit walk, so totals match the loop formulation exactly).
    baseline = 0.0
    individual = 0.0
    e2e = 0.0
    prev_split_frac = 0.0       # fraction of channels on CPU in previous op
    i = 0
    for kind, payload in units:
        if kind == "pool":
            t = _pool_latency_us(device)
            baseline += t
            individual += t
            e2e += t
            prev_split_frac = 0.0     # pooling runs wholly on GPU
            continue
        op = payload
        baseline += float(gpu_only[i])
        individual += float(t_co[i])

        dec = decisions[i]
        split_frac = dec.c_cpu / max(1, op.C_out)
        # boundary traffic: activations crossing the CPU/GPU ownership
        # boundary between consecutive layers move through shared memory.
        crossing = abs(split_frac - prev_split_frac) * op.input_bytes
        boundary_us = crossing / (dev.cpu_mem_gbps * 1e3)
        e2e += float(t_co[i]) + boundary_us
        prev_split_frac = split_frac
        i += 1

    return PlanReport(device=device, threads=threads, baseline_us=baseline,
                      individual_us=individual, end_to_end_us=e2e,
                      decisions=decisions)
