"""End-to-end model partition planning (paper Section 5.4).

Runs the per-operation partitioner over a whole network graph (offline, as
"part of the compilation process"), then evaluates:

  * baseline        — every op on the GPU;
  * individual ops  — sum of each op's co-execution latency in isolation;
  * end-to-end      — co-execution schedule including inter-layer effects:
    pooling stays on the GPU (free of sync overhead), and a boundary cost is
    charged when consecutive layers change their channel split, because each
    side then consumes activations the *other* side produced (extra
    cache-coherent traffic through the shared memory) — this is the paper's
    observed "memory access overhead between layers" that makes end-to-end
    speedups slightly lower than per-op speedups.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.networks import Unit
from repro.core.partitioner import (PartitionDecision, optimal_partition,
                                    realized_latency_us)
from repro.core.predictor.train import LatencyPredictor
from repro.core.simulator.devices import DEVICES
from repro.core.simulator.measure import measure_latency_us
from repro.core.sync import SyncMechanism


@dataclasses.dataclass
class PlanReport:
    device: str
    threads: int
    baseline_us: float          # all-GPU
    individual_us: float        # sum of isolated co-exec latencies
    end_to_end_us: float        # schedule incl. boundary costs
    decisions: List[PartitionDecision]

    @property
    def individual_speedup(self) -> float:
        return self.baseline_us / self.individual_us

    @property
    def end_to_end_speedup(self) -> float:
        return self.baseline_us / self.end_to_end_us


def _pool_latency_us(device: str) -> float:
    # pooling is bandwidth-trivial; charge one dispatch (paper: negligible)
    return DEVICES[device].gpu_dispatch_us * 0.6


def plan_network(units: Sequence[Unit], cpu_pred: LatencyPredictor,
                 gpu_pred: LatencyPredictor, *, threads: int,
                 mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                 seed: int = 1) -> PlanReport:
    device = gpu_pred.device
    dev = DEVICES[device]

    baseline = 0.0
    individual = 0.0
    e2e = 0.0
    decisions: List[PartitionDecision] = []
    prev_split_frac = 0.0       # fraction of channels on CPU in previous op

    for kind, payload in units:
        if kind == "pool":
            t = _pool_latency_us(device)
            baseline += t
            individual += t
            e2e += t
            prev_split_frac = 0.0     # pooling runs wholly on GPU
            continue
        op = payload
        gpu_only = measure_latency_us(op, device, "gpu", seed=seed)
        baseline += gpu_only

        dec = optimal_partition(op, cpu_pred, gpu_pred, mechanism=mechanism)
        decisions.append(dec)
        t_co = realized_latency_us(dec, device, threads, mechanism=mechanism,
                                   seed=seed)
        individual += t_co

        split_frac = dec.c_cpu / max(1, op.C_out)
        # boundary traffic: activations crossing the CPU/GPU ownership
        # boundary between consecutive layers move through shared memory.
        crossing = abs(split_frac - prev_split_frac) * op.input_bytes
        boundary_us = crossing / (dev.cpu_mem_gbps * 1e3)
        e2e += t_co + boundary_us
        prev_split_frac = split_frac

    return PlanReport(device=device, threads=threads, baseline_us=baseline,
                      individual_us=individual, end_to_end_us=e2e,
                      decisions=decisions)
