"""Output-channel workload partitioning (paper Section 2).

Solves   min_{c1+c2=C_out}  T_overhead(c1,c2) + max(T_CPU(c1), T_GPU(c2))

over a channel grid, where the latency terms come either from trained
predictors (the deployable path — "3-4 ms per operation, offline") or from
noisy measurements (the grid-search oracle the paper uses as its upper
bound, Table 2).

Planning is vectorized: the `*_batch` functions featurize and score every
candidate split of every op in a handful of batched
`LatencyPredictor.predict` / `measure_latency_us_batch` calls, and the
single-op entry points are thin wrappers over them.  Decisions are
bit-identical to scoring each candidate in its own call — predictions and
measurements are per-row, so batch composition cannot change the argmin.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.predictor.train import LatencyPredictor
from repro.core.simulator.measure import (measure_latency_us,
                                          measure_latency_us_batch)
from repro.core.sync import SyncMechanism, sync_overhead_us
from repro.core.types import Op
from repro.kernels import registry


@dataclasses.dataclass(frozen=True)
class PartitionDecision:
    op: Op
    c_cpu: int
    c_gpu: int
    pred_cpu_us: float
    pred_gpu_us: float
    pred_total_us: float
    #: partition axis: "channel" (the paper's conv/linear domain, where
    #: c_cpu/c_gpu count output channels), "head" / "kv-block" /
    #: "ssm-state" (typed axes, where they count axis units — heads or
    #: cache positions), or "none" (exclusive placement of an axis kind)
    axis: str = "channel"
    #: autotuned kernel tile config for the op's Pallas lowering, attached
    #: by the tune annotation pass (runtime/autotune.py); None means the
    #: kind's default blocking and keeps pre-tile plan JSON byte-identical
    tile: Optional[registry.TileConfig] = None

    @property
    def exclusive(self) -> bool:
        return self.c_cpu == 0 or self.c_gpu == 0


def _candidate_splits(c_out: int, step: int) -> np.ndarray:
    cands = np.arange(0, c_out + 1, step)
    if cands[-1] != c_out:
        cands = np.append(cands, c_out)
    return cands


def _candidate_grid(ops: Sequence[Op], step: int):
    """Flatten every op's candidate splits into one grid.

    Returns (gpu_ops, cpu_ops, c_gpu, c_cpu, spans) where spans[i] is the
    half-open [lo, hi) slice of op i's candidates in the flat arrays.
    """
    gpu_ops: List[Op] = []
    cpu_ops: List[Op] = []
    c_gpu_parts: List[np.ndarray] = []
    c_cpu_parts: List[np.ndarray] = []
    spans: List[Tuple[int, int]] = []
    for op in ops:
        c_gpu = _candidate_splits(op.C_out, step)
        c_cpu = op.C_out - c_gpu
        spans.append((len(gpu_ops), len(gpu_ops) + len(c_gpu)))
        gpu_ops.extend(op.with_cout(int(c)) for c in c_gpu)
        cpu_ops.extend(op.with_cout(int(c)) for c in c_cpu)
        c_gpu_parts.append(c_gpu)
        c_cpu_parts.append(c_cpu)
    c_gpu_all = np.concatenate(c_gpu_parts) if c_gpu_parts else np.empty(0, int)
    c_cpu_all = np.concatenate(c_cpu_parts) if c_cpu_parts else np.empty(0, int)
    return gpu_ops, cpu_ops, c_gpu_all, c_cpu_all, spans


def _decide(ops: Sequence[Op], t_gpu: np.ndarray, t_cpu: np.ndarray,
            c_gpu: np.ndarray, c_cpu: np.ndarray, spans, overhead: float
            ) -> List[PartitionDecision]:
    coexec = (c_gpu > 0) & (c_cpu > 0)
    total = np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead, 0.0)
    decisions = []
    for op, (lo, hi) in zip(ops, spans):
        i = lo + int(np.argmin(total[lo:hi]))
        decisions.append(PartitionDecision(
            op=op, c_cpu=int(c_cpu[i]), c_gpu=int(c_gpu[i]),
            pred_cpu_us=float(t_cpu[i]), pred_gpu_us=float(t_gpu[i]),
            pred_total_us=float(total[i])))
    return decisions


def optimal_partition_batch(ops: Sequence[Op], cpu_pred: LatencyPredictor,
                            gpu_pred: LatencyPredictor, *,
                            mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                            step: int = 8) -> List[PartitionDecision]:
    """Predictor-driven partitioning of many ops in two `predict` calls."""
    ops = list(ops)
    if not ops:
        return []
    device = gpu_pred.device
    overhead = sync_overhead_us(device, mechanism)
    gpu_ops, cpu_ops, c_gpu, c_cpu, spans = _candidate_grid(ops, step)
    t_gpu = np.where(c_gpu > 0, gpu_pred.predict(gpu_ops), 0.0)
    t_cpu = np.where(c_cpu > 0, cpu_pred.predict(cpu_ops), 0.0)
    return _decide(ops, t_gpu, t_cpu, c_gpu, c_cpu, spans, overhead)


def optimal_partition(op: Op, cpu_pred: LatencyPredictor,
                      gpu_pred: LatencyPredictor, *,
                      mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                      step: int = 8) -> PartitionDecision:
    """Predictor-driven partitioning (the paper's deployable method)."""
    return optimal_partition_batch([op], cpu_pred, gpu_pred,
                                   mechanism=mechanism, step=step)[0]


def grid_search_partition_batch(ops: Sequence[Op], device: str, threads: int,
                                *,
                                mechanism: SyncMechanism =
                                SyncMechanism.SVM_POLL,
                                step: int = 8, seed: int = 0
                                ) -> List[PartitionDecision]:
    """Measurement-driven exhaustive search over many ops in two batched
    measurement calls (zero-channel candidates measure as exactly 0)."""
    ops = list(ops)
    if not ops:
        return []
    overhead = sync_overhead_us(device, mechanism)
    gpu_ops, cpu_ops, c_gpu, c_cpu, spans = _candidate_grid(ops, step)
    t_gpu = measure_latency_us_batch(gpu_ops, device, "gpu", seed=seed)
    t_cpu = measure_latency_us_batch(cpu_ops, device, f"cpu{threads}",
                                     seed=seed)
    return _decide(ops, t_gpu, t_cpu, c_gpu, c_cpu, spans, overhead)


def grid_search_partition(op: Op, device: str, threads: int, *,
                          mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                          step: int = 8, seed: int = 0) -> PartitionDecision:
    """Measurement-driven exhaustive search (the paper's oracle baseline;
    step 8 matches Section 5.3)."""
    return grid_search_partition_batch([op], device, threads,
                                       mechanism=mechanism, step=step,
                                       seed=seed)[0]


def realized_latency_us_batch(decisions: Sequence[PartitionDecision],
                              device: str, threads: int, *,
                              mechanism: SyncMechanism =
                              SyncMechanism.SVM_POLL,
                              seed: int = 1) -> np.ndarray:
    """Measured co-execution latencies of many decisions (fresh measurement
    seed, so predictor-driven decisions are scored on unseen noise)."""
    decisions = list(decisions)
    if not decisions:
        return np.empty(0)
    gpu_ops = [d.op.with_cout(d.c_gpu) for d in decisions]
    cpu_ops = [d.op.with_cout(d.c_cpu) for d in decisions]
    t_gpu = measure_latency_us_batch(gpu_ops, device, "gpu", seed=seed)
    t_cpu = measure_latency_us_batch(cpu_ops, device, f"cpu{threads}",
                                     seed=seed)
    overhead = sync_overhead_us(device, mechanism)
    exclusive = np.array([d.exclusive for d in decisions])
    return np.maximum(t_cpu, t_gpu) + np.where(exclusive, 0.0, overhead)


def realized_latency_us(decision: PartitionDecision, device: str,
                        threads: int, *,
                        mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                        seed: int = 1) -> float:
    """Measured co-execution latency of a decision."""
    return float(realized_latency_us_batch([decision], device, threads,
                                           mechanism=mechanism, seed=seed)[0])


def speedup_vs_gpu_batch(decisions: Sequence[PartitionDecision], device: str,
                         threads: int, *,
                         mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                         seed: int = 1) -> np.ndarray:
    """Paper's metric, batched: speedup of co-execution over GPU-only."""
    decisions = list(decisions)
    if not decisions:
        return np.empty(0)
    gpu_only = measure_latency_us_batch([d.op for d in decisions], device,
                                        "gpu", seed=seed)
    co = realized_latency_us_batch(decisions, device, threads,
                                   mechanism=mechanism, seed=seed)
    return gpu_only / co


def speedup_vs_gpu(decision: PartitionDecision, device: str, threads: int, *,
                   mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                   seed: int = 1) -> float:
    """Paper's metric: speedup of co-execution over GPU-only execution."""
    return float(speedup_vs_gpu_batch([decision], device, threads,
                                      mechanism=mechanism, seed=seed)[0])


# ------------------------------------------------------ typed-axis splits
#
# Attention and SSM decode ops partition along registry-typed axes (head /
# kv-block / ssm-state) instead of output channels, and additionally carry
# a kernel *mode* the planner chooses.  The same batched two-predict-call
# structure applies: every (axis, boundary, mode) candidate of every op is
# flattened into one GPU list and one CPU list.

def _axis_candidate_grid(ops: Sequence[Op]):
    """Flatten every op's (axis, boundary, mode) candidates.

    Returns (gpu_ops, cpu_ops, n_gpu, n_cpu, axes, extra_bytes, spans).
    Zero-unit sides are represented by the *full* op (these kinds cannot
    encode an empty sub-op) and masked to zero latency by the callers;
    exclusive placements are labeled axis="none" with unit counts 1/0.
    ``extra_bytes`` carries the kv-block merge traffic (partial outputs
    from both sides are combined with a log-sum-exp pass).
    """
    gpu_ops: List[Op] = []
    cpu_ops: List[Op] = []
    n_gpu: List[int] = []
    n_cpu: List[int] = []
    axes: List[str] = []
    extra: List[float] = []
    spans: List[Tuple[int, int]] = []
    for op in ops:
        entry = registry.entry_for(op)
        modes = entry.modes or ("",)
        lo = len(gpu_ops)
        for mode in modes:
            opm = op.with_mode(mode) if entry.modes else op
            for side_gpu in (1, 0):
                gpu_ops.append(opm)
                cpu_ops.append(opm)
                n_gpu.append(side_gpu)
                n_cpu.append(1 - side_gpu)
                axes.append("none")
                extra.append(0.0)
            for spec in registry.axes_for(opm):
                size, g = spec.size(opm), spec.granularity(opm)
                for n in range(g, size, g):
                    registry.validate_axis_split(opm, spec.axis, n)
                    gpu_ops.append(spec.sub(opm, n))
                    cpu_ops.append(spec.sub(opm, size - n))
                    n_gpu.append(n)
                    n_cpu.append(size - n)
                    axes.append(spec.axis)
                    extra.append(2.0 * opm.output_bytes
                                 if not spec.stackable else 0.0)
        spans.append((lo, len(gpu_ops)))
    return (gpu_ops, cpu_ops, np.asarray(n_gpu), np.asarray(n_cpu),
            axes, np.asarray(extra), spans)


def _axis_decide(ops: Sequence[Op], gpu_ops: Sequence[Op],
                 t_gpu: np.ndarray, t_cpu: np.ndarray,
                 n_gpu: np.ndarray, n_cpu: np.ndarray, axes: Sequence[str],
                 extra_bytes: np.ndarray, spans, device: str,
                 overhead: float) -> List[PartitionDecision]:
    from repro.core.simulator.devices import DEVICES
    dev = DEVICES[device]
    coexec = (n_gpu > 0) & (n_cpu > 0)
    # Non-stackable axes (extra_bytes > 0, i.e. kv-block) materialize a
    # log-sum-exp merge of both sides' partials: besides the merge traffic
    # itself they pay a second sync rendezvous, and cannot amortize it by
    # chaining into a fused segment.
    merge_us = extra_bytes / (dev.cpu_mem_gbps * 1e3)
    merge_us = merge_us + np.where(extra_bytes > 0.0, overhead, 0.0)
    total = (np.maximum(t_cpu, t_gpu)
             + np.where(coexec, overhead + merge_us, 0.0))
    decisions = []
    for op, (lo, hi) in zip(ops, spans):
        i = lo + int(np.argmin(total[lo:hi]))
        chosen = gpu_ops[i]                 # carries the winning mode
        entry = registry.entry_for(op)
        full = op.with_mode(chosen.mode) if entry.modes else op
        decisions.append(PartitionDecision(
            op=full, c_cpu=int(n_cpu[i]), c_gpu=int(n_gpu[i]),
            pred_cpu_us=float(t_cpu[i]), pred_gpu_us=float(t_gpu[i]),
            pred_total_us=float(total[i]), axis=axes[i]))
    return decisions


def axis_partition_batch(ops: Sequence[Op], cpu_pred: LatencyPredictor,
                         gpu_pred: LatencyPredictor, *,
                         mechanism: SyncMechanism = SyncMechanism.SVM_POLL
                         ) -> List[PartitionDecision]:
    """Predictor-driven (axis, boundary, mode) partitioning of many
    attention/SSM ops in two batched `predict` calls."""
    ops = list(ops)
    if not ops:
        return []
    device = gpu_pred.device
    overhead = sync_overhead_us(device, mechanism)
    (gpu_ops, cpu_ops, n_gpu, n_cpu, axes, extra,
     spans) = _axis_candidate_grid(ops)
    t_gpu = np.where(n_gpu > 0, gpu_pred.predict(gpu_ops), 0.0)
    t_cpu = np.where(n_cpu > 0, cpu_pred.predict(cpu_ops), 0.0)
    return _axis_decide(ops, gpu_ops, t_gpu, t_cpu, n_gpu, n_cpu, axes,
                        extra, spans, device, overhead)


def grid_axis_partition_batch(ops: Sequence[Op], device: str, threads: int,
                              *,
                              mechanism: SyncMechanism =
                              SyncMechanism.SVM_POLL,
                              seed: int = 0) -> List[PartitionDecision]:
    """Measurement-driven exhaustive (axis, boundary, mode) search."""
    ops = list(ops)
    if not ops:
        return []
    overhead = sync_overhead_us(device, mechanism)
    (gpu_ops, cpu_ops, n_gpu, n_cpu, axes, extra,
     spans) = _axis_candidate_grid(ops)
    t_gpu = np.where(n_gpu > 0,
                     measure_latency_us_batch(gpu_ops, device, "gpu",
                                              seed=seed), 0.0)
    t_cpu = np.where(n_cpu > 0,
                     measure_latency_us_batch(cpu_ops, device,
                                              f"cpu{threads}", seed=seed),
                     0.0)
    return _axis_decide(ops, gpu_ops, t_gpu, t_cpu, n_gpu, n_cpu, axes,
                        extra, spans, device, overhead)


def axis_side_ops(decision: PartitionDecision) -> Tuple[Op, Op]:
    """(gpu_sub_op, cpu_sub_op) of a typed-axis decision; exclusive
    decisions return the full op for the placed side (the other entry is
    the full op too — callers mask by the zero unit count)."""
    op = decision.op
    if decision.exclusive or decision.axis in ("none", "channel"):
        return op, op
    spec = registry.axis_spec(registry.op_kind(op), decision.axis)
    return (spec.sub(op, decision.c_gpu), spec.sub(op, decision.c_cpu))


def axis_realized_latency_us_batch(decisions: Sequence[PartitionDecision],
                                   device: str, threads: int, *,
                                   mechanism: SyncMechanism =
                                   SyncMechanism.SVM_POLL,
                                   seed: int = 1) -> np.ndarray:
    """Measured latencies of typed-axis decisions (fresh noise seed)."""
    decisions = list(decisions)
    if not decisions:
        return np.empty(0)
    sides = [axis_side_ops(d) for d in decisions]
    t_gpu = measure_latency_us_batch([g for g, _ in sides], device, "gpu",
                                     seed=seed)
    t_cpu = measure_latency_us_batch([c for _, c in sides], device,
                                     f"cpu{threads}", seed=seed)
    n_gpu = np.array([d.c_gpu for d in decisions])
    n_cpu = np.array([d.c_cpu for d in decisions])
    t_gpu = np.where(n_gpu > 0, t_gpu, 0.0)
    t_cpu = np.where(n_cpu > 0, t_cpu, 0.0)
    overhead = sync_overhead_us(device, mechanism)
    exclusive = np.array([d.exclusive for d in decisions])
    return np.maximum(t_cpu, t_gpu) + np.where(exclusive, 0.0, overhead)
