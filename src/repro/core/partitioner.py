"""Output-channel workload partitioning (paper Section 2).

Solves   min_{c1+c2=C_out}  T_overhead(c1,c2) + max(T_CPU(c1), T_GPU(c2))

over a channel grid, where the latency terms come either from trained
predictors (the deployable path — "3-4 ms per operation, offline") or from
noisy measurements (the grid-search oracle the paper uses as its upper
bound, Table 2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.predictor.train import LatencyPredictor, measure_ops
from repro.core.simulator.measure import measure_latency_us
from repro.core.sync import SyncMechanism, sync_overhead_us
from repro.core.types import Op


@dataclasses.dataclass(frozen=True)
class PartitionDecision:
    op: Op
    c_cpu: int
    c_gpu: int
    pred_cpu_us: float
    pred_gpu_us: float
    pred_total_us: float

    @property
    def exclusive(self) -> bool:
        return self.c_cpu == 0 or self.c_gpu == 0


def _candidate_splits(c_out: int, step: int) -> np.ndarray:
    cands = np.arange(0, c_out + 1, step)
    if cands[-1] != c_out:
        cands = np.append(cands, c_out)
    return cands


def optimal_partition(op: Op, cpu_pred: LatencyPredictor,
                      gpu_pred: LatencyPredictor, *,
                      mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                      step: int = 8) -> PartitionDecision:
    """Predictor-driven partitioning (the paper's deployable method)."""
    device = gpu_pred.device
    overhead = sync_overhead_us(device, mechanism)
    c_gpu = _candidate_splits(op.C_out, step)
    c_cpu = op.C_out - c_gpu

    gpu_ops = [op.with_cout(int(c)) for c in c_gpu]
    cpu_ops = [op.with_cout(int(c)) for c in c_cpu]
    t_gpu = np.where(c_gpu > 0, gpu_pred.predict(gpu_ops), 0.0)
    t_cpu = np.where(c_cpu > 0, cpu_pred.predict(cpu_ops), 0.0)

    coexec = (c_gpu > 0) & (c_cpu > 0)
    total = np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead, 0.0)
    i = int(np.argmin(total))
    return PartitionDecision(op=op, c_cpu=int(c_cpu[i]), c_gpu=int(c_gpu[i]),
                             pred_cpu_us=float(t_cpu[i]),
                             pred_gpu_us=float(t_gpu[i]),
                             pred_total_us=float(total[i]))


def grid_search_partition(op: Op, device: str, threads: int, *,
                          mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                          step: int = 8, seed: int = 0) -> PartitionDecision:
    """Measurement-driven exhaustive search (the paper's oracle baseline;
    step 8 matches Section 5.3)."""
    overhead = sync_overhead_us(device, mechanism)
    backend_cpu = f"cpu{threads}"
    c_gpu = _candidate_splits(op.C_out, step)
    c_cpu = op.C_out - c_gpu

    t_gpu = np.array([measure_latency_us(op.with_cout(int(c)), device, "gpu",
                                         seed=seed) if c else 0.0
                      for c in c_gpu])
    t_cpu = np.array([measure_latency_us(op.with_cout(int(c)), device,
                                         backend_cpu, seed=seed) if c else 0.0
                      for c in c_cpu])
    coexec = (c_gpu > 0) & (c_cpu > 0)
    total = np.maximum(t_cpu, t_gpu) + np.where(coexec, overhead, 0.0)
    i = int(np.argmin(total))
    return PartitionDecision(op=op, c_cpu=int(c_cpu[i]), c_gpu=int(c_gpu[i]),
                             pred_cpu_us=float(t_cpu[i]),
                             pred_gpu_us=float(t_gpu[i]),
                             pred_total_us=float(total[i]))


def realized_latency_us(decision: PartitionDecision, device: str,
                        threads: int, *,
                        mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                        seed: int = 1) -> float:
    """Measured co-execution latency of a decision (fresh measurement seed,
    so predictor-driven decisions are scored on unseen noise)."""
    op = decision.op
    t_gpu = measure_latency_us(op.with_cout(decision.c_gpu), device, "gpu",
                               seed=seed) if decision.c_gpu else 0.0
    t_cpu = measure_latency_us(op.with_cout(decision.c_cpu), device,
                               f"cpu{threads}", seed=seed) \
        if decision.c_cpu else 0.0
    overhead = 0.0 if decision.exclusive \
        else sync_overhead_us(device, mechanism)
    return max(t_cpu, t_gpu) + overhead


def speedup_vs_gpu(decision: PartitionDecision, device: str, threads: int, *,
                   mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                   seed: int = 1) -> float:
    """Paper's metric: speedup of co-execution over GPU-only execution."""
    gpu_only = measure_latency_us(decision.op, device, "gpu", seed=seed)
    co = realized_latency_us(decision, device, threads, mechanism=mechanism,
                             seed=seed)
    return gpu_only / co
