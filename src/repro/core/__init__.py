"""Core library: the paper's contribution.

 - simulator/   white-box performance models of the four mobile platforms
 - predictor/   GBDT latency predictors with dispatch-feature augmentation
 - partitioner  optimal output-channel splits (predictor- or search-driven)
 - planner      end-to-end network partition planning
 - sync         synchronization overhead models (event vs fine-grained SVM)
 - coexec       TPU-native uneven channel-split execution (shard_map)
 - networks     op graphs of the paper's end-to-end evaluation models

Exports resolve lazily (PEP 562) so importing any `repro.core.*` submodule
(which executes this package __init__) does not drag in jax via coexec —
the api facade's Target validation and artifact codecs stay jax-free.
"""
import importlib

_EXPORTS = {
    "AttnOp": "repro.core.types",
    "ConvOp": "repro.core.types",
    "LinearOp": "repro.core.types",
    "Op": "repro.core.types",
    "SSMOp": "repro.core.types",
    "SyncMechanism": "repro.core.sync",
    "collective_overhead_us": "repro.core.sync",
    "sync_overhead_us": "repro.core.sync",
    "PartitionDecision": "repro.core.partitioner",
    "grid_search_partition": "repro.core.partitioner",
    "optimal_partition": "repro.core.partitioner",
    "realized_latency_us": "repro.core.partitioner",
    "speedup_vs_gpu": "repro.core.partitioner",
    "GraphPlanReport": "repro.core.planner",
    "PlanReport": "repro.core.planner",
    "grid_plan_graph": "repro.core.planner",
    "opaque_latency_us": "repro.core.planner",
    "plan_graph": "repro.core.planner",
    "plan_network": "repro.core.planner",
    "SplitPlan": "repro.core.coexec",
    "coexec_matmul": "repro.core.coexec",
    "coexec_mesh": "repro.core.coexec",
    "pack_weights": "repro.core.coexec",
    "throughput_split": "repro.core.coexec",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
