"""Core library: the paper's contribution.

 - simulator/   white-box performance models of the four mobile platforms
 - predictor/   GBDT latency predictors with dispatch-feature augmentation
 - partitioner  optimal output-channel splits (predictor- or search-driven)
 - planner      end-to-end network partition planning
 - sync         synchronization overhead models (event vs fine-grained SVM)
 - coexec       TPU-native uneven channel-split execution (shard_map)
 - networks     op graphs of the paper's end-to-end evaluation models
"""
from repro.core.types import ConvOp, LinearOp, Op
from repro.core.sync import (SyncMechanism, collective_overhead_us,
                             sync_overhead_us)
from repro.core.partitioner import (PartitionDecision, grid_search_partition,
                                    optimal_partition, realized_latency_us,
                                    speedup_vs_gpu)
from repro.core.planner import PlanReport, plan_network
from repro.core.coexec import (SplitPlan, coexec_matmul, coexec_mesh,
                               pack_weights, throughput_split)

__all__ = [
    "ConvOp", "LinearOp", "Op",
    "SyncMechanism", "sync_overhead_us", "collective_overhead_us",
    "PartitionDecision", "grid_search_partition", "optimal_partition",
    "realized_latency_us", "speedup_vs_gpu",
    "PlanReport", "plan_network",
    "SplitPlan", "coexec_matmul", "coexec_mesh", "pack_weights",
    "throughput_split",
]
