"""CPU-GPU synchronization cost models (paper Section 4).

Two mechanisms:

  * EVENT — the baseline: the CPU passively waits on GPU kernel completion
    via clWaitForEvents-style notification, plus map/unmap of coarse-grained
    shared buffers for cache coherence.  Mean delay ~150-160 us.
  * SVM_POLL — the paper's contribution: layer outputs live in fine-grained
    shared virtual memory (hardware cache coherence, no map/unmap) and both
    sides busy-poll `cpu_flag`/`gpu_flag`.  Mean overhead ~7 us.

On the TPU transfer target (core/coexec.py) there is no asynchronous host to
poll; `collective_overhead_us` prices the all-gather that materializes a
channel-split output instead — the same role `T_overhead` plays in the
paper's objective.
"""
from __future__ import annotations

import enum

from repro.core.simulator.devices import DEVICES


class SyncMechanism(str, enum.Enum):
    EVENT = "event"          # clWaitForEvents + buffer map/unmap
    SVM_POLL = "svm_poll"    # fine-grained SVM + active polling


def sync_overhead_us(device: str, mechanism: SyncMechanism) -> float:
    """Mean synchronization overhead charged to a co-execution strategy.

    Exclusive execution (all channels on one device) pays no overhead; the
    partitioner applies that rule (T_overhead(c1, c2) = 0 at the borders).
    """
    dev = DEVICES[device]
    if mechanism == SyncMechanism.EVENT:
        return dev.sync_event_us
    return dev.sync_svm_us


def collective_overhead_us(bytes_out: int, link_gbps: float = 50.0,
                           hops: int = 1) -> float:
    """TPU analogue: cost of all-gathering a channel-split output across the
    co-execution groups (ring all-gather, `hops` inter-group steps)."""
    return hops * bytes_out / (link_gbps * 1e3)
