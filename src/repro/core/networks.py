"""Operation graphs of the paper's end-to-end networks (Section 5.4).

Each network is a flat list of scheduling units: ("conv", ConvOp),
("linear", LinearOp) or ("pool", out_bytes).  Pooling is always scheduled on
the GPU (paper: negligible latency, avoids a synchronization point).
Input resolution is 224x224x3, as in the paper's ImageNet models.

The unit list is the *legacy* representation: the pipeline now plans and
executes over the typed op graph (`repro.graph`), and these lists lower
into it via `graph.from_units` — fingerprint-compatible, so nothing here
changed meaning.  New workloads (decoder blocks with attention/SSM nodes,
fan-out, residuals) are expressed directly as graphs, not unit lists.
"""
from __future__ import annotations

import math
from typing import List, Optional, Tuple, Union

from repro.core.types import ConvOp, LinearOp

Unit = Tuple[str, Union[ConvOp, LinearOp, int]]


def unit_input_shape(unit: Unit) -> Optional[Tuple[int, ...]]:
    """Declared input shape of a conv/linear unit ((H, W, C) or (L, C)); a
    pool unit's input is whatever the previous unit produced (None)."""
    kind, payload = unit
    if kind == "pool":
        return None
    from repro.kernels import registry
    return registry.get(kind).input_shape(payload)


def unit_output_shape(unit: Unit, c_prev: int = 0) -> Tuple[int, ...]:
    """Declared output shape of a unit.  Pool units only record output
    bytes, so the producing channel count `c_prev` is needed to recover
    their spatial extent (networks here never pool over channels)."""
    kind, payload = unit
    if kind == "pool":
        edge = pool_out_edge(int(payload), c_prev)
        return (edge, edge, c_prev)
    from repro.kernels import registry
    return registry.get(kind).output_shape(payload)


def pool_out_edge(pool_bytes: int, c: int) -> int:
    """Output edge length of a square pool unit from its recorded float32
    byte count: bytes = 4 * edge^2 * c (edge 1 = global pooling)."""
    if pool_bytes <= 0:
        raise ValueError(f"pool unit needs a positive output byte count, "
                         f"got {pool_bytes}")
    if c <= 0:
        raise ValueError(f"pool unit needs a positive channel count, got {c}")
    return max(1, math.isqrt(max(1, pool_bytes // (4 * c))))


def vgg16() -> List[Unit]:
    cfg = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)]
    units: List[Unit] = []
    h, c_in = 224, 3
    for c_out, reps in cfg:
        for _ in range(reps):
            units.append(("conv", ConvOp(h, h, c_in, c_out, 3, 1)))
            c_in = c_out
        units.append(("pool", 4 * (h // 2) * (h // 2) * c_out))
        h //= 2
    units.append(("linear", LinearOp(1, 7 * 7 * 512, 4096)))
    units.append(("linear", LinearOp(1, 4096, 4096)))
    units.append(("linear", LinearOp(1, 4096, 1000)))
    return units


def _resnet(blocks: List[int]) -> List[Unit]:
    units: List[Unit] = [("conv", ConvOp(224, 224, 3, 64, 7, 2)),
                         ("pool", 4 * 56 * 56 * 64)]
    h, c_in = 56, 64                       # resolution/channels entering stage
    for stage, n in enumerate(blocks):
        c_out = 64 * 2 ** stage
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            units.append(("conv", ConvOp(h, h, c_in, c_out, 3, stride)))
            h_out = h // stride
            units.append(("conv", ConvOp(h_out, h_out, c_out, c_out, 3, 1)))
            if stride == 2 or c_in != c_out:   # projection shortcut
                units.append(("conv", ConvOp(h, h, c_in, c_out, 1, stride)))
            h, c_in = h_out, c_out
    units.append(("pool", 4 * c_in))
    units.append(("linear", LinearOp(1, c_in, 1000)))
    return units


def resnet18() -> List[Unit]:
    return _resnet([2, 2, 2, 2])


def resnet34() -> List[Unit]:
    return _resnet([3, 4, 6, 3])


def inception_v3() -> List[Unit]:
    """Inception-v3 conv graph (channel spec follows Szegedy et al. 2016 /
    torchvision; 'A/B/C/D/E' mixed modules; 299x299 input)."""
    u: List[Unit] = []
    # stem
    u += [("conv", ConvOp(299, 299, 3, 32, 3, 2)),
          ("conv", ConvOp(149, 149, 32, 32, 3, 1)),
          ("conv", ConvOp(147, 147, 32, 64, 3, 1)),
          ("pool", 4 * 73 * 73 * 64),
          ("conv", ConvOp(73, 73, 64, 80, 1, 1)),
          ("conv", ConvOp(73, 73, 80, 192, 3, 1)),
          ("pool", 4 * 35 * 35 * 192)]

    def convs(h, seq):
        res = []
        for c_in, c_out, k, s in seq:
            res.append(("conv", ConvOp(h, h, c_in, c_out, k, s)))
        return res

    # 3x Mixed A @35x35 (in 192/256/288)
    for c_in, pool_c in ((192, 32), (256, 64), (288, 64)):
        u += convs(35, [(c_in, 64, 1, 1),                       # b1
                        (c_in, 48, 1, 1), (48, 64, 5, 1),       # b2
                        (c_in, 64, 1, 1), (64, 96, 3, 1), (96, 96, 3, 1),
                        (c_in, pool_c, 1, 1)])                  # pool proj
        u.append(("pool", 4 * 35 * 35 * c_in))
    # Mixed B (grid reduction) @35->17
    u += convs(35, [(288, 384, 3, 2), (288, 64, 1, 1)])
    u += [("conv", ConvOp(35, 35, 64, 96, 3, 1)),
          ("conv", ConvOp(35, 35, 96, 96, 3, 2)),
          ("pool", 4 * 17 * 17 * 288)]
    # 4x Mixed C @17x17 (768 channels).  The 7x1/1x7 factorized convs are
    # modeled as K=7 ConvOps with C_in/7: this preserves both the FLOPs
    # (2*H*W*7*C_in*C_out) and the weight bytes (7*C_in*C_out*4) of the true
    # asymmetric kernel while staying in the square-filter op grammar.
    def f7(c):                                     # factorized-conv C_in
        return max(1, c // 7)
    for c7 in (128, 160, 160, 192):
        u += convs(17, [(768, 192, 1, 1),                       # b1
                        (768, c7, 1, 1), (f7(c7), c7, 7, 1),
                        (f7(c7), 192, 7, 1),
                        (768, c7, 1, 1), (f7(c7), c7, 7, 1),
                        (f7(c7), c7, 7, 1), (f7(c7), c7, 7, 1),
                        (f7(c7), 192, 7, 1),
                        (768, 192, 1, 1)])                      # pool proj
        u.append(("pool", 4 * 17 * 17 * 768))
    # Mixed D (reduction) @17->8
    u += convs(17, [(768, 192, 1, 1)])
    u += [("conv", ConvOp(17, 17, 192, 320, 3, 2))]
    u += convs(17, [(768, 192, 1, 1), (f7(192), 192, 7, 1),
                    (f7(192), 192, 7, 1)])
    u += [("conv", ConvOp(17, 17, 192, 192, 3, 2)),
          ("pool", 4 * 8 * 8 * 768)]
    # 2x Mixed E @8x8 (1280/2048 in)
    for c_in in (1280, 2048):
        u += convs(8, [(c_in, 320, 1, 1),
                       (c_in, 384, 1, 1), (384, 384, 3, 1), (384, 384, 3, 1),
                       (c_in, 448, 1, 1), (448, 384, 3, 1), (384, 384, 3, 1),
                       (384, 384, 3, 1),
                       (c_in, 192, 1, 1)])
        u.append(("pool", 4 * 8 * 8 * c_in))
    u.append(("pool", 4 * 2048))
    u.append(("linear", LinearOp(1, 2048, 1000)))
    return u


NETWORKS = {
    "vgg16": vgg16,
    "resnet18": resnet18,
    "resnet34": resnet34,
    "inception_v3": inception_v3,
}
