"""Feature construction for the latency predictors.

Two regimes, mirroring the paper's ablation (Tab. 4):

  * **black-box** — operation configuration only (shapes, FLOPs, bytes):
    what prior work [9,13,15,22] uses; captures trends, misses spikes.
  * **white-box (augmented)** — adds kernel *dispatch* features recovered
    from the delegate heuristics (Section 3.2): workgroup shape/size/count,
    grid dims, wave count, wave quantization waste, occupancy, padded FLOPs.
    White-box predictors are additionally trained *per kernel
    implementation* (linear / conv_generic / conv_constant / winograd).
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.core.simulator.devices import DEVICES
from repro.core.simulator.gpu_model import dispatch_for
from repro.core.types import Op
from repro.kernels import registry

BLACKBOX_LINEAR = ["L", "C_in", "C_out", "log_flops", "log_weight_bytes"]
BLACKBOX_CONV = ["H_in", "W_in", "C_in", "C_out", "K", "S",
                 "log_flops", "log_weight_bytes"]
# the trailing mode index is what lets one predictor price both kernel
# modes (streaming/materialized, chunked/recurrent) of a decode kind
BLACKBOX_ATTENTION = ["H", "S", "KV", "hd", "window",
                      "log_flops", "log_weight_bytes", "mode_index"]
BLACKBOX_SSM = ["T", "H", "hd", "N",
                "log_flops", "log_weight_bytes", "mode_index"]
_BLACKBOX_BY_KIND = {"linear": BLACKBOX_LINEAR, "conv": BLACKBOX_CONV,
                     "attention": BLACKBOX_ATTENTION, "ssm": BLACKBOX_SSM}
DISPATCH_FEATURES = ["wg_x", "wg_y", "wg_size", "grid_x", "grid_y",
                     "wg_count", "waves", "wave_quant", "occupancy",
                     "log_padded_flops"]


def tile_feature_names(kind: str) -> List[str]:
    """Per-kind kernel tile-config feature names ("tile_bm", ...), in the
    registry `TileSpec` parameter order."""
    return [f"tile_{n}" for n in registry.tile_spec(kind).names()]


def tile_features(ops: Sequence[Op], tiles=None) -> np.ndarray:
    """Resolved tile-config values per op, one row per op.

    `tiles[i]` is op i's `TileConfig` or None; None (and a missing list)
    resolves to the kind's clamped default, so a predictor trained with
    tile features prices untuned records at the blocking the kernel would
    actually use, and re-prices tuned decisions when the caller passes
    their tiles (the calibrated-replan path).  Only meaningful for
    same-kind batches — feature widths differ across kinds.
    """
    if tiles is None:
        tiles = [None] * len(ops)
    rows = []
    for op, tile in zip(ops, tiles):
        resolved = registry.resolve_tile(op, tile)
        rows.append([float(v) for _, v in resolved.values])
    return np.array(rows, dtype=np.float64)


def _base_features(op: Op) -> List[float]:
    # one dispatch table for planner and executor: the registry owns the
    # per-kind base feature extractors
    return registry.entry_for(op).base_features(op)


def blackbox_features(ops: Sequence[Op]) -> np.ndarray:
    return np.array([_base_features(op) for op in ops], dtype=np.float64)


def _dispatch_features(op: Op, device: str) -> List[float]:
    from repro.core.simulator.gpu_model import _OCCUPANCY_THREADS_PER_CU
    dev = DEVICES[device]
    d = dispatch_for(op, dev)
    slots = dev.gpu_compute_units * max(1, int(512 // max(1, d.wg_size)))
    waves = -(-d.wg_count // slots)
    quant = waves * slots / max(1, d.wg_count)
    occ = min(1.0, d.total_threads /
              (_OCCUPANCY_THREADS_PER_CU * dev.gpu_compute_units))
    return [d.wg_x, d.wg_y, d.wg_size, d.grid_x, d.grid_y, d.wg_count,
            waves, quant, occ, np.log(max(d.padded_flops, 1))]


def whitebox_features(ops: Sequence[Op], device: str) -> np.ndarray:
    return np.array(
        [_base_features(op) + _dispatch_features(op, device) for op in ops],
        dtype=np.float64)


def kernel_of(op: Op, device: str) -> str:
    return dispatch_for(op, DEVICES[device]).kernel


def feature_names(ops_kind: str, whitebox: bool,
                  tiles: bool = False) -> List[str]:
    base = _BLACKBOX_BY_KIND.get(ops_kind, BLACKBOX_CONV)
    names = base + DISPATCH_FEATURES if whitebox else list(base)
    return names + tile_feature_names(ops_kind) if tiles else names
