"""Training/test dataset generation (paper Section 5.2 and 5.3).

Training configurations use the paper's *structured random sampling*: each
dimension is drawn by first picking an interval [2^k, 2^(k+1)] with
k in {2..9} uniformly, then sampling uniformly inside it — this balances
coverage across scales instead of biasing toward large dims.

Evaluation sets reproduce Section 5.3 exactly:
  * linear:  dims from {i * 2^j | 4<=i<=6, 2<=j<=9}, FLOPs in [4e6, 1e9]
             (2,039 operations in the paper; the same construction here);
  * conv:    the 4-stage hierarchy with per-stage resolutions/channels,
             K in {1,3,5,7}, S in {1,2}, FLOPs in [4e6, 1e9].

`training_from_records` closes the measurement loop: any batch of
`repro.measure.MeasurementRecord`s — executed plan runs or simulator
sweeps — converts directly into a `(ops, y_us)` training set for
`train_predictor(ops, ..., y_us=y)`, no glue code.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Tuple

import numpy as np

from repro.core.types import AttnOp, ConvOp, LinearOp, Op, SSMOp

FLOPS_MIN, FLOPS_MAX = 4e6, 1e9


def training_from_records(records: Iterable, kind: Optional[str] = None
                          ) -> Tuple[List[Op], np.ndarray]:
    """(ops, measured µs) training pairs from measurement records.

    Pool units (no op) and non-positive measurements are dropped, and so
    are **co-executed** records: their wall time measures a channel-split
    execution (max of two shards + gather) while `r.op` is the full op —
    using them as-is would teach a per-backend predictor that the whole
    op costs a half-op's time.  Only records whose full op ran unsplit
    (`exclusive` executions, `simulated` measurements) are valid
    per-backend training pairs.

    Predictors are per op kind (`MuxPredictor` routes linear vs conv), so
    a mixed executed run must be split before training: pass
    `kind="linear"`/`"conv"` to select one kind's pairs.  The records are
    duck-typed (`.op` / `.wall_us` / `.mode` / `.unit`), so this module
    stays a leaf — it never imports `repro.measure`.
    """
    ops: List[Op] = []
    y: List[float] = []
    for r in records:
        if r.op is None or r.wall_us <= 0.0 or r.mode == "coexec":
            continue
        if kind is not None and r.unit != kind:
            continue
        ops.append(r.op)
        y.append(float(r.wall_us))
    return ops, np.asarray(y)


def _structured_dim(rng: np.random.Generator) -> int:
    # Paper: pick an interval [2^k, 2^(k+1)] uniformly, then a dim inside it.
    # The paper states k in {2..9}; the Section 5.3 *evaluation* dims reach
    # 6*2^9 = 3072, which tree models cannot extrapolate to, so we extend the
    # training intervals to k <= 11 to cover the evaluation range.
    k = int(rng.integers(2, 12))
    return int(rng.integers(2 ** k, 2 ** (k + 1) + 1))


def sample_linear_ops(n: int, seed: int = 0) -> List[LinearOp]:
    rng = np.random.default_rng(seed)
    ops = []
    while len(ops) < n:
        op = LinearOp(L=_structured_dim(rng), C_in=_structured_dim(rng),
                      C_out=_structured_dim(rng))
        ops.append(op)
    return ops


def sample_conv_ops(n: int, seed: int = 0) -> List[ConvOp]:
    rng = np.random.default_rng(seed)
    ops = []
    while len(ops) < n:
        op = ConvOp(H_in=_structured_dim(rng), W_in=_structured_dim(rng),
                    C_in=_structured_dim(rng), C_out=_structured_dim(rng),
                    K=int(rng.choice([1, 3, 5, 7])),
                    S=int(rng.choice([1, 2])))
        # keep the simulator in a sane regime (the paper phones also cap
        # feasible op sizes via memory/time limits)
        if op.flops <= 4 * FLOPS_MAX:
            ops.append(op)
    return ops


def sample_attn_ops(n: int, seed: int = 0) -> List[AttnOp]:
    """Decode-attention training set: head/cache dims spanning both the
    full tiny-model ops and the head/kv-block sub-ops the planner prices,
    with both kernel modes sampled (the mode index is a feature)."""
    rng = np.random.default_rng(seed)
    ops: List[AttnOp] = []
    while len(ops) < n:
        h = int(2 ** rng.integers(0, 6))                   # 1..32 heads
        kv = int(2 ** rng.integers(0, int(np.log2(h)) + 1))
        hd = int(2 ** rng.integers(3, 8))                  # 8..128
        s = _structured_dim(rng)
        mode = str(rng.choice(["streaming", "materialized"]))
        op = AttnOp(H=h, S=s, KV=kv, hd=hd, mode=mode)
        if op.flops <= 4 * FLOPS_MAX:
            ops.append(op)
    return ops


def sample_ssm_ops(n: int, seed: int = 0) -> List[SSMOp]:
    """SSD-scan training set: a quarter of the draws pin T=1 (the decode
    regime where fused recurrence wins), the rest sample chunked-prefill
    scan lengths; both modes sampled."""
    rng = np.random.default_rng(seed)
    ops: List[SSMOp] = []
    while len(ops) < n:
        t = 1 if rng.random() < 0.25 else _structured_dim(rng)
        h = int(2 ** rng.integers(0, 6))
        hd = int(2 ** rng.integers(3, 8))
        n_state = int(2 ** rng.integers(3, 8))
        mode = str(rng.choice(["chunked", "recurrent"]))
        op = SSMOp(T=t, H=h, hd=hd, N=n_state, mode=mode)
        if op.flops <= 4 * FLOPS_MAX:
            ops.append(op)
    return ops


def eval_linear_ops() -> List[LinearOp]:
    """Section 5.3 linear test set: 2,039 operations."""
    dims = sorted({i * 2 ** j for i in (4, 5, 6) for j in range(2, 10)})
    ops = []
    for L, c_in, c_out in itertools.product(dims, dims, dims):
        op = LinearOp(L, c_in, c_out)
        if FLOPS_MIN <= op.flops <= FLOPS_MAX:
            ops.append(op)
    return ops


def eval_conv_ops() -> List[ConvOp]:
    """Section 5.3 convolution test set: 4-stage hierarchy, 2,051 ops."""
    ops = []
    base_res = (64, 56, 48, 40)
    base_ch = (256, 320, 384, 448, 512)
    div_for_k = {1: 1, 3: 1, 5: 4, 7: 8}
    for stage in range(4):
        scale = 2 ** stage
        for r in base_res:
            res = r // scale
            if res < 1:
                continue
            for K in (1, 3, 5, 7):
                for S in (1, 2):
                    chans = [c * scale // div_for_k[K] for c in base_ch]
                    for c_in in chans:
                        for c_out in chans:
                            op = ConvOp(res, res, c_in, c_out, K, S)
                            if FLOPS_MIN <= op.flops <= FLOPS_MAX:
                                ops.append(op)
    # dedupe while keeping order
    seen, out = set(), []
    for op in ops:
        if op not in seen:
            seen.add(op)
            out.append(op)
    return out
