"""Training of latency predictors (paper Sections 3.2, 5.2).

A `LatencyPredictor` maps operations to predicted latency (microseconds) for
one (device, backend) pair.  GPU white-box predictors are split per kernel
implementation and fed dispatch-augmented features; black-box predictors see
only the operation configuration (the ablation baseline).

Targets are log-latencies: squared loss on logs optimizes relative error,
which is what MAPE (Table 1) scores.
"""
from __future__ import annotations

import dataclasses
import pickle
from pathlib import Path
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.predictor.features import (blackbox_features, kernel_of,
                                           tile_features, whitebox_features)
from repro.core.predictor.gbdt import GBDTParams, GBDTRegressor
from repro.core.simulator.measure import measure_latency_us
from repro.core.types import Op


@dataclasses.dataclass
class LatencyPredictor:
    device: str
    backend: str                    # 'gpu' | 'cpu1' | 'cpu2' | 'cpu3'
    whitebox: bool
    models: Dict[str, GBDTRegressor]   # kernel -> model ('*' if not split)
    #: when True the feature vectors carry the resolved kernel tile config
    #: (see features.tile_features), so `predict(ops, tiles=...)` re-prices
    #: autotuned decisions; False keeps pre-tile vectors and checksums
    #: (read via getattr — predictors pickled before this field existed
    #: unpickle without it)
    tiles: bool = False

    @property
    def tile_aware(self) -> bool:
        return bool(getattr(self, "tiles", False))

    def _featurize(self, ops: Sequence[Op], tiles) -> np.ndarray:
        feats = (whitebox_features(ops, self.device)
                 if self.whitebox and self.backend == "gpu"
                 else blackbox_features(ops))
        if self.tile_aware:
            feats = np.hstack([feats, tile_features(ops, tiles)])
        return feats

    def predict(self, ops: Sequence[Op],
                tiles: Optional[Sequence] = None) -> np.ndarray:
        ops = list(ops)
        out = np.empty(len(ops))
        feats = self._featurize(ops, tiles)
        if not self.whitebox or self.backend != "gpu":
            model = self.models["*"]
            out[:] = np.exp(model.predict(feats))
            return out
        # white-box GPU: route each op to its kernel's model
        kernels = np.array([kernel_of(op, self.device) for op in ops])
        for kern in np.unique(kernels):
            sel = kernels == kern
            model = self.models.get(kern) or self.models["*"]
            out[sel] = np.exp(model.predict(feats[sel]))
        return out

    def save(self, path: Path) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        with open(path, "wb") as f:
            pickle.dump(self, f)

    @staticmethod
    def load(path: Path) -> "LatencyPredictor":
        with open(path, "rb") as f:
            return pickle.load(f)


def measure_ops(ops: Sequence[Op], device: str, backend: str,
                seed: int = 0) -> np.ndarray:
    return np.array([measure_latency_us(op, device, backend, seed=seed)
                     for op in ops])


def train_predictor(ops: Sequence[Op], device: str, backend: str, *,
                    whitebox: bool = True,
                    y_us: Optional[np.ndarray] = None,
                    params: Optional[GBDTParams] = None,
                    tiles: bool = False,
                    tile_list: Optional[Sequence] = None,
                    hpo_trials: int = 0, seed: int = 0) -> LatencyPredictor:
    """Fit a predictor on measured latencies of `ops`.

    hpo_trials > 0 runs an Optuna-style random search with a held-out
    validation split (20%), mirroring Section 5.2.  `tiles=True` appends
    each op's resolved kernel tile config to the feature vector
    (`tile_list[i]` when given, else the default blocking), producing a
    tile-aware predictor that can re-price autotuned decisions; the
    default keeps feature vectors — and the structural checksum cached
    plans key on — identical to pre-tile builds.
    """
    ops = list(ops)
    y = measure_ops(ops, device, backend, seed=seed) if y_us is None \
        else np.asarray(y_us)
    logy = np.log(np.maximum(y, 1e-3))

    gpu_wb = whitebox and backend == "gpu"
    X = whitebox_features(ops, device) if gpu_wb else blackbox_features(ops)
    if tiles:
        X = np.hstack([X, tile_features(ops, tile_list)])

    def fit_group(Xg, yg, prm):
        return GBDTRegressor(prm, seed=seed).fit(Xg, yg)

    def choose_params(Xg, yg) -> GBDTParams:
        if params is not None:
            return params
        if hpo_trials <= 0:
            return GBDTParams()
        rng = np.random.default_rng(seed + 17)
        n = len(yg)
        idx = rng.permutation(n)
        cut = max(1, int(0.8 * n))
        tr, va = idx[:cut], idx[cut:]
        best, best_err = GBDTParams(), np.inf
        for _ in range(hpo_trials):
            cand = GBDTParams.random(rng)
            m = GBDTRegressor(cand, seed=seed).fit(Xg[tr], yg[tr])
            err = float(np.mean(np.abs(np.exp(m.predict(Xg[va]))
                                       - np.exp(yg[va]))
                                / np.exp(yg[va])))
            if err < best_err:
                best, best_err = cand, err
        return best

    models: Dict[str, GBDTRegressor] = {}
    if gpu_wb:
        kernels = np.array([kernel_of(op, device) for op in ops])
        for kern in np.unique(kernels):
            sel = kernels == kern
            if sel.sum() < 30:       # too few samples: fall through to '*'
                continue
            prm = choose_params(X[sel], logy[sel])
            models[kern] = fit_group(X[sel], logy[sel], prm)
        # global fallback model over all samples
        prm = choose_params(X, logy)
        models["*"] = fit_group(X, logy, prm)
    else:
        prm = choose_params(X, logy)
        models["*"] = fit_group(X, logy, prm)

    return LatencyPredictor(device=device, backend=backend,
                            whitebox=gpu_wb, models=models, tiles=tiles)


def mape(pred_us: np.ndarray, true_us: np.ndarray) -> float:
    true_us = np.asarray(true_us)
    return float(np.mean(np.abs(pred_us - true_us) / np.maximum(true_us,
                                                                1e-9)))


@dataclasses.dataclass
class MuxPredictor:
    """Routes each op kind to its own per-kind predictor; the end-to-end
    planner spans every kind in a graph.  The decode-kind members default
    to None so conv/linear-only predictor bundles (and their cached
    pickles/checksums) are unchanged from before attention/SSM became
    plannable."""

    linear: LatencyPredictor
    conv: LatencyPredictor
    attention: Optional[LatencyPredictor] = None
    ssm: Optional[LatencyPredictor] = None

    @property
    def device(self) -> str:
        return self.linear.device

    def member(self, kind: str) -> Optional[LatencyPredictor]:
        return getattr(self, "attention" if kind == "attention" else
                       "ssm" if kind == "ssm" else kind, None)

    def predict(self, ops: Sequence[Op],
                tiles: Optional[Sequence] = None) -> np.ndarray:
        from repro.kernels.registry import op_kind
        ops = list(ops)
        out = np.empty(len(ops))
        kinds = [op_kind(o) for o in ops]
        for kind in sorted(set(kinds)):
            idx = [i for i, k in enumerate(kinds) if k == kind]
            member = self.member(kind)
            if member is None:
                raise ValueError(
                    f"MuxPredictor has no {kind!r} member; train with "
                    f"kinds including {kind!r}")
            out[idx] = member.predict(
                [ops[i] for i in idx],
                None if tiles is None else [tiles[i] for i in idx])
        return out
