"""From-scratch histogram gradient-boosted decision trees (NumPy).

The paper trains LightGBM GBDTs (Section 5.2); LightGBM is not available in
this offline container, so this is a compact reimplementation of the same
algorithm class: quantile-binned features, level-wise regression trees with
L2-regularized gain, squared loss on log-latency (so optimizing relative
error, which is what MAPE measures), shrinkage, and row subsampling.

Vectorized histogram construction keeps training fast enough to fit the
paper's full predictor matrix (4 devices x {GPU, 1-3 CPU threads} x
{linear, conv} x per-kernel splits) on one CPU core.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

_MAX_BINS = 64


@dataclasses.dataclass
class GBDTParams:
    n_estimators: int = 300
    learning_rate: float = 0.08
    max_depth: int = 7
    min_child_samples: int = 4
    reg_lambda: float = 1.0
    subsample: float = 0.9
    max_bins: int = _MAX_BINS

    @staticmethod
    def random(rng: np.random.Generator) -> "GBDTParams":
        """Optuna-style random draw over the paper's hyperparameter ranges."""
        return GBDTParams(
            n_estimators=int(rng.integers(100, 500)),
            learning_rate=float(10 ** rng.uniform(-2, np.log10(0.2))),
            max_depth=int(rng.integers(5, 11)),
            min_child_samples=int(rng.integers(2, 16)),
            reg_lambda=float(10 ** rng.uniform(-4, 0)),
            subsample=float(rng.uniform(0.5, 1.0)),
        )


class _Tree:
    """One level-wise regression tree over pre-binned features."""

    __slots__ = ("feature", "threshold_bin", "left", "right", "value",
                 "n_nodes")

    def __init__(self, n_nodes: int):
        self.feature = np.full(n_nodes, -1, dtype=np.int32)
        self.threshold_bin = np.zeros(n_nodes, dtype=np.int32)
        self.left = np.full(n_nodes, -1, dtype=np.int32)
        self.right = np.full(n_nodes, -1, dtype=np.int32)
        self.value = np.zeros(n_nodes, dtype=np.float64)
        self.n_nodes = n_nodes

    def predict_binned(self, Xb: np.ndarray) -> np.ndarray:
        node = np.zeros(Xb.shape[0], dtype=np.int32)
        # depth is bounded, iterate until all rows sit on leaves
        for _ in range(64):
            feat = self.feature[node]
            active = feat >= 0
            if not active.any():
                break
            rows = np.nonzero(active)[0]
            f = feat[rows]
            go_left = Xb[rows, f] <= self.threshold_bin[node[rows]]
            node[rows] = np.where(go_left, self.left[node[rows]],
                                  self.right[node[rows]])
        return self.value[node]


class GBDTRegressor:
    """predict() operates on raw feature matrices; fit() bins them first."""

    def __init__(self, params: Optional[GBDTParams] = None, seed: int = 0):
        self.params = params or GBDTParams()
        self.seed = seed
        self.trees: List[_Tree] = []
        self.bin_edges_: Optional[List[np.ndarray]] = None
        self.base_: float = 0.0
        self.feature_gain_: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ fit
    def fit(self, X: np.ndarray, y: np.ndarray) -> "GBDTRegressor":
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        n, n_feat = X.shape
        p = self.params
        rng = np.random.default_rng(self.seed)

        # --- quantile binning ---
        self.bin_edges_ = []
        Xb = np.empty((n, n_feat), dtype=np.int32)
        for j in range(n_feat):
            qs = np.quantile(X[:, j], np.linspace(0, 1, p.max_bins + 1)[1:-1])
            edges = np.unique(qs)
            self.bin_edges_.append(edges)
            Xb[:, j] = np.searchsorted(edges, X[:, j], side="right")
        n_bins = p.max_bins

        self.base_ = float(y.mean())
        pred = np.full(n, self.base_)
        self.trees = []
        self.feature_gain_ = np.zeros(n_feat)

        for _ in range(p.n_estimators):
            if p.subsample < 1.0:
                mask = rng.random(n) < p.subsample
                if mask.sum() < 2 * p.min_child_samples:
                    mask[:] = True
            else:
                mask = np.ones(n, dtype=bool)
            grad = pred - y          # d/dpred of 0.5*(pred-y)^2
            tree = self._fit_tree(Xb[mask], grad[mask], n_bins)
            self.trees.append(tree)
            pred += p.learning_rate * tree.predict_binned(Xb)
        return self

    def _fit_tree(self, Xb: np.ndarray, grad: np.ndarray,
                  n_bins: int) -> _Tree:
        p = self.params
        n, n_feat = Xb.shape
        max_nodes = 2 ** (p.max_depth + 1)
        tree = _Tree(max_nodes)
        node_of = np.zeros(n, dtype=np.int32)
        # frontier: list of node ids at current depth
        frontier = [0]
        next_free = 1
        lam = p.reg_lambda

        for depth in range(p.max_depth):
            if not frontier:
                break
            n_nodes_level = max(frontier) + 1
            # histograms: grad sum and count per (node, feature, bin)
            flat = node_of[:, None] * (n_feat * n_bins) \
                + np.arange(n_feat)[None, :] * n_bins + Xb
            size = n_nodes_level * n_feat * n_bins
            gh = np.bincount(flat.ravel(), weights=np.repeat(grad, n_feat),
                             minlength=size).reshape(n_nodes_level, n_feat,
                                                     n_bins)
            ch = np.bincount(flat.ravel(), minlength=size).reshape(
                n_nodes_level, n_feat, n_bins).astype(np.float64)

            gl = np.cumsum(gh, axis=2)
            cl = np.cumsum(ch, axis=2)
            gt = gl[:, :, -1:]
            ct = cl[:, :, -1:]
            gr = gt - gl
            cr = ct - cl
            valid = (cl >= p.min_child_samples) & (cr >= p.min_child_samples)
            gain = (gl ** 2 / (cl + lam) + gr ** 2 / (cr + lam)
                    - gt ** 2 / (ct + lam))
            gain = np.where(valid, gain, -np.inf)

            new_frontier = []
            for node in frontier:
                g = gain[node]
                j, b = np.unravel_index(np.argmax(g), g.shape)
                best = g[j, b]
                ctot = ct[node, 0, 0]
                gtot = gt[node, 0, 0]
                if not np.isfinite(best) or best <= 1e-12 or ctot == 0:
                    tree.value[node] = -gtot / (ctot + lam)
                    continue
                li, ri = next_free, next_free + 1
                next_free += 2
                tree.feature[node] = j
                tree.threshold_bin[node] = b
                tree.left[node], tree.right[node] = li, ri
                self.feature_gain_[j] += float(best)
                new_frontier += [li, ri]

            if not new_frontier:
                break
            # route samples to children
            feat = tree.feature[node_of]
            splittable = feat >= 0
            rows = np.nonzero(splittable)[0]
            go_left = Xb[rows, feat[rows]] <= tree.threshold_bin[node_of[rows]]
            node_of[rows] = np.where(go_left, tree.left[node_of[rows]],
                                     tree.right[node_of[rows]])
            frontier = new_frontier

        # finalize any remaining frontier leaves
        for node in frontier:
            sel = node_of == node
            c = float(sel.sum())
            if c > 0:
                tree.value[node] = -float(grad[sel].sum()) / (c + lam)
        return tree

    # -------------------------------------------------------------- predict
    def _bin(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, dtype=np.float64)
        Xb = np.empty(X.shape, dtype=np.int32)
        for j, edges in enumerate(self.bin_edges_):
            Xb[:, j] = np.searchsorted(edges, X[:, j], side="right")
        return Xb

    def predict(self, X: np.ndarray) -> np.ndarray:
        Xb = self._bin(X)
        out = np.full(Xb.shape[0], self.base_)
        lr = self.params.learning_rate
        for t in self.trees:
            out += lr * t.predict_binned(Xb)
        return out
