from repro.core.predictor.dataset import (eval_conv_ops, eval_linear_ops,
                                          sample_attn_ops, sample_conv_ops,
                                          sample_linear_ops, sample_ssm_ops,
                                          training_from_records)
from repro.core.predictor.features import (blackbox_features, feature_names,
                                           kernel_of, whitebox_features)
from repro.core.predictor.gbdt import GBDTParams, GBDTRegressor
from repro.core.predictor.train import (LatencyPredictor, mape, measure_ops,
                                        train_predictor)

__all__ = [
    "eval_conv_ops", "eval_linear_ops", "sample_attn_ops", "sample_conv_ops",
    "sample_linear_ops", "sample_ssm_ops",
    "training_from_records",
    "blackbox_features", "feature_names", "kernel_of", "whitebox_features",
    "GBDTParams", "GBDTRegressor",
    "LatencyPredictor", "mape", "measure_ops", "train_predictor",
]
