"""TPU-native transfer of the paper's co-execution mechanism.

The paper splits one operation's output channels across two heterogeneous
compute devices that share memory.  On a TPU mesh the analogous structure is
an **uneven channel split across two device groups of one mesh axis**:

  * group 0 ("fast", the GPU analogue) owns `c_fast` output channels,
  * group 1 ("slow", the CPU analogue) owns `C_out - c_fast`,

with the split chosen by the same predictor-driven partitioner, where the
per-group throughputs play the role of the CPU/GPU latency models and the
all-gather that materializes the full output plays the role of
`T_overhead` (see core/sync.collective_overhead_us).

SPMD requires uniform per-device shapes, so both groups are padded to the
same local width `c_pad` and masked — the exact analogue of the paper's
channel-alignment granularity (grid step 8 / float4 slices).  When the
*consumer* is also channel-split (the paper's "subsequent CPU and GPU
operations read the shared output directly"), `gather=False` keeps the
result group-local as a `(2, ..., c_pad)` stack, and the consumer op takes
that stack directly via `x_plan=`: the reconstruction happens *inside* the
consumer's shard_map program (a fused all-gather), eliding the explicit
reshard-to-replicated synchronization point between the two ops — the SVM
analogue of skipping the map/unmap pair.  Both linear (`coexec_matmul`) and
convolution (`coexec_conv2d`) support split execution and chaining.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

COEXEC_AXIS = "coexec"
LANE_AXIS = "lane"


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Uneven output-channel split across two device groups."""

    c_out: int
    c_fast: int                  # channels owned by group 0
    align: int = 8               # channel alignment granularity

    @property
    def c_slow(self) -> int:
        return self.c_out - self.c_fast

    @property
    def c_pad(self) -> int:
        """Uniform local width (SPMD): max of the two shares, aligned."""
        a = self.align
        return -(-max(self.c_fast, self.c_slow) // a) * a


def throughput_split(c_out: int, fast_share: float, align: int = 8) -> SplitPlan:
    """Balance channels proportionally to group throughputs (the closed-form
    optimum of the paper's objective for linear cost models)."""
    c_fast = int(round(c_out * fast_share / align)) * align
    c_fast = min(max(c_fast, 0), c_out)
    return SplitPlan(c_out=c_out, c_fast=c_fast, align=align)


def split_for_mesh(c_out: int, c_fast: int, mesh: Mesh,
                   align: int = 8) -> SplitPlan:
    """Alignment-aware re-split: a partitioner decision (c_gpu channels on
    the fast group) lowered onto a concrete mesh.  The padded local width
    must shard evenly over the mesh's lane axis, so the alignment is lifted
    to lcm(align, lane_count)."""
    lanes = int(mesh.shape[LANE_AXIS])
    return SplitPlan(c_out=c_out, c_fast=c_fast,
                     align=int(np.lcm(align, lanes)))


def pack_weights(w: jax.Array, plan: SplitPlan,
                 mesh: Mesh | None = None) -> jax.Array:
    """(..., C_out) -> (2, ..., c_pad): per-group padded weight slices.

    Works for linear (C_in, C_out) and conv (K, K, C_in, C_out) weights —
    the split is always over the trailing output-channel dim.  With
    `mesh`, the packed stack is placed in its consumption sharding
    (group- and lane-wise) up front, so repeated co-execution calls on
    the same packed weights do not re-shard per call.
    """
    lead = w.shape[:-1]
    wf = jnp.zeros(lead + (plan.c_pad,), w.dtype).at[..., :plan.c_fast].set(
        w[..., :plan.c_fast])
    ws = jnp.zeros(lead + (plan.c_pad,), w.dtype).at[..., :plan.c_slow].set(
        w[..., plan.c_fast:])
    packed = jnp.stack([wf, ws])
    if mesh is not None:
        packed = jax.device_put(
            packed, NamedSharding(mesh, _stacked_spec(packed.ndim)))
    return packed


def coexec_mesh(devices=None) -> Mesh:
    """A two-group mesh along the co-execution axis.

    Degrades gracefully: with fewer than 2 devices there is nothing to
    co-execute against, so the mesh collapses to a **single group** holding
    every device — callers detect this via `mesh_groups(mesh) == 1` and run
    ops exclusively (the executor does exactly that).  Odd device counts
    >= 3 drop the last device to keep the two groups even.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    if not devices:
        raise ValueError("coexec_mesh needs at least one device")
    n = len(devices) - len(devices) % 2
    if n < 2:
        arr = np.array(devices).reshape(1, len(devices))
    else:
        arr = np.array(devices[:n]).reshape(2, n // 2)
    return Mesh(arr, (COEXEC_AXIS, LANE_AXIS))


def mesh_groups(mesh: Mesh) -> int:
    """Number of co-execution groups (2 = split-capable, 1 = degraded)."""
    return int(mesh.shape[COEXEC_AXIS])


def _shard_map():
    # jax.shard_map graduated from jax.experimental in newer releases;
    # support both spellings.
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    return sm


_PROGRAM_CACHE: dict = {}


def mesh_fingerprint(mesh: Mesh) -> tuple:
    """Hashable identity of a mesh (device ids × axis names)."""
    return (tuple(int(d.id) for d in mesh.devices.flat),
            tuple(mesh.axis_names))


def cached_coexec_program(key: tuple, build):
    """One jitted program per eager co-execution call-site configuration.

    Eager shard_map closures are rebuilt on every call, which defeats
    jax's trace and compile caches (fresh function identity each time)
    and turns every co-executed node into a retrace + recompile.  Call
    sites pass a hashable key covering everything that shapes the traced
    program (op, split geometry, input shapes/dtypes, mesh) plus a
    zero-argument `build` returning the shard_map-wrapped local; the
    jitted program is built once per distinct key and reused for the
    life of the process.  (Eager shard_map dispatch executes the body
    primitive-by-primitive across the mesh — orders of magnitude slower
    than one compiled program, and re-traced per call besides.)

    Jitting routes the program through the GSPMD partitioner, whose
    fusion choices can perturb the fp32 rounding of *composite*
    nonlinearities (sigmoid-style rewrites); lowerings that need
    bit-identity against an eager oracle keep such transforms out of the
    traced body (they pre-apply them at weight-pack time) so the traced
    program is fusion-stable."""
    fn = _PROGRAM_CACHE.get(key)
    if fn is None:
        fn = _PROGRAM_CACHE[key] = jax.jit(build())
    return fn


def _merge_stacked(x_local: jax.Array, x_plan: SplitPlan) -> jax.Array:
    """Reconstruct the full (..., C) activation from this device's shard of
    a (2, ..., c_pad) group-local stack — *inside* a shard_map program.

    This is the elided boundary: instead of an explicit reshard to
    replicated between producer and consumer, the consumer all-gathers the
    stack over (lane, coexec) as part of its own program and strips the
    alignment padding with static slices.
    """
    xg = jax.lax.all_gather(x_local[0], LANE_AXIS,
                            axis=x_local.ndim - 2, tiled=True)
    xs = jax.lax.all_gather(xg, COEXEC_AXIS, axis=0)
    return jnp.concatenate([xs[0][..., :x_plan.c_fast],
                            xs[1][..., :x_plan.c_slow]], axis=-1)


def _stacked_spec(ndim: int) -> P:
    """(2, ..., c_pad) stacks shard group-wise + lane-wise on channels."""
    return P(COEXEC_AXIS, *([None] * (ndim - 2)), LANE_AXIS)


def gather_stacked(y: jax.Array, plan: SplitPlan, mesh: Mesh) -> jax.Array:
    """Materialize the combined output of a group-local (2, ..., c_pad)
    stack — the paper's synchronization point.

    Reshard each group's slice to replicated first: concatenating slices
    that are still lane-sharded miscompiles on some jax releases (values
    double through the partitioner), and the gather IS the sync point, so
    an explicit reshard is the honest lowering.
    """
    rep = NamedSharding(mesh, P())
    y_fast = jax.device_put(y[0][..., :plan.c_fast], rep)
    y_slow = jax.device_put(y[1][..., :plan.c_slow], rep)
    return jnp.concatenate([y_fast, y_slow], axis=-1)


def gather_stacked_traced(y: jax.Array, plan: SplitPlan,
                          mesh: Mesh) -> jax.Array:
    """`gather_stacked` spelled as a shard_map program, safe under jit.

    `gather_stacked` reshards with `jax.device_put`, which cannot appear
    inside a traced (jitted) computation.  Fused segment programs instead
    reconstruct the full activation with the same `_merge_stacked`
    collective the chained consumers use (all-gather over lane + coexec,
    static padding slices) and emit it replicated.  Both spellings are
    pure data movement over identical values, so they agree bit-for-bit.
    """

    def merge(y_local: jax.Array) -> jax.Array:
        return _merge_stacked(y_local, plan)

    kwargs = dict(mesh=mesh, in_specs=(_stacked_spec(y.ndim),),
                  out_specs=P())
    try:
        fn = _shard_map()(merge, check_rep=False, **kwargs)
    except TypeError:       # jax versions without the check_rep knob
        fn = _shard_map()(merge, **kwargs)
    return fn(y)


def coexec_matmul(x: jax.Array, packed_w: jax.Array, plan: SplitPlan,
                  mesh: Mesh, *, gather: bool = True,
                  x_plan: SplitPlan | None = None) -> jax.Array:
    """Channel-split matmul: each group computes its slice of X @ W.

    x: (L, C_in) replicated — or, with `x_plan`, the producer's group-local
    (2, L, x_plan.c_pad) stack (chained input, no reshard in between).
    packed_w: (2, C_in, c_pad) sharded on group.
    Returns (L, C_out) if gather else the group-local (2, L, c_pad) stack.
    """

    def build():
        def local(x_l, w_l):
            # w_l: (1, C_in, c_pad) — this group's slice
            x_full = (_merge_stacked(x_l, x_plan) if x_plan is not None
                      else x_l)
            return (x_full @ w_l[0])[None]    # (1, L, c_pad)

        x_spec = _stacked_spec(3) if x_plan is not None else P()
        return _shard_map()(
            local, mesh=mesh,
            in_specs=(x_spec, _stacked_spec(3)),
            out_specs=_stacked_spec(3))

    key = ("matmul", x_plan, mesh_fingerprint(mesh),
           tuple(x.shape), str(x.dtype),
           tuple(packed_w.shape), str(packed_w.dtype))
    y = cached_coexec_program(key, build)(x, packed_w)  # (2, L, c_pad)

    if not gather:
        return y
    return gather_stacked(y, plan, mesh)


def coexec_conv2d(x: jax.Array, packed_w: jax.Array, plan: SplitPlan,
                  mesh: Mesh, *, stride: int = 1, gather: bool = True,
                  x_plan: SplitPlan | None = None) -> jax.Array:
    """Channel-split SAME convolution across the two co-execution groups.

    x: (B, H, W, C_in) replicated — or, with `x_plan`, the producer's
    group-local (2, B, H, W, x_plan.c_pad) stack.
    packed_w: (2, K, K, C_in, c_pad) sharded on group.
    Returns (B, H', W', C_out) if gather else the (2, B, H', W', c_pad)
    stack.  Output channels are the split dim; spatial dims follow SAME
    semantics (callers crop to the declared ConvOp shape).
    """

    def build():
        def local(x_l, w_l):
            x_full = (_merge_stacked(x_l, x_plan) if x_plan is not None
                      else x_l)
            y = jax.lax.conv_general_dilated(
                x_full.astype(jnp.float32), w_l[0].astype(jnp.float32),
                window_strides=(stride, stride), padding="SAME",
                dimension_numbers=("NHWC", "HWIO",
                                   "NHWC")).astype(x_full.dtype)
            return y[None]                    # (1, B, H', W', c_pad)

        x_spec = _stacked_spec(5) if x_plan is not None else P()
        return _shard_map()(
            local, mesh=mesh,
            in_specs=(x_spec, _stacked_spec(5)),
            out_specs=_stacked_spec(5))

    key = ("conv2d", x_plan, stride, mesh_fingerprint(mesh),
           tuple(x.shape), str(x.dtype),
           tuple(packed_w.shape), str(packed_w.dtype))
    y = cached_coexec_program(key, build)(x, packed_w)  # (2,B,H',W',c_pad)

    if not gather:
        return y
    return gather_stacked(y, plan, mesh)


def coexec_linear_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for tests: plain X @ W."""
    return x @ w
