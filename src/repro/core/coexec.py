"""TPU-native transfer of the paper's co-execution mechanism.

The paper splits one operation's output channels across two heterogeneous
compute devices that share memory.  On a TPU mesh the analogous structure is
an **uneven channel split across two device groups of one mesh axis**:

  * group 0 ("fast", the GPU analogue) owns `c_fast` output channels,
  * group 1 ("slow", the CPU analogue) owns `C_out - c_fast`,

with the split chosen by the same predictor-driven partitioner, where the
per-group throughputs play the role of the CPU/GPU latency models and the
all-gather that materializes the full output plays the role of
`T_overhead` (see core/sync.collective_overhead_us).

SPMD requires uniform per-device shapes, so both groups are padded to the
same local width `c_pad` and masked — the exact analogue of the paper's
channel-alignment granularity (grid step 8 / float4 slices).  When the
*consumer* is also channel-parallel (the paper's "subsequent CPU and GPU
operations read the shared output directly"), `gather=False` skips the
all-gather entirely and the result stays group-local.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

COEXEC_AXIS = "coexec"


@dataclasses.dataclass(frozen=True)
class SplitPlan:
    """Uneven output-channel split across two device groups."""

    c_out: int
    c_fast: int                  # channels owned by group 0
    align: int = 8               # channel alignment granularity

    @property
    def c_slow(self) -> int:
        return self.c_out - self.c_fast

    @property
    def c_pad(self) -> int:
        """Uniform local width (SPMD): max of the two shares, aligned."""
        a = self.align
        return -(-max(self.c_fast, self.c_slow) // a) * a


def throughput_split(c_out: int, fast_share: float, align: int = 8) -> SplitPlan:
    """Balance channels proportionally to group throughputs (the closed-form
    optimum of the paper's objective for linear cost models)."""
    c_fast = int(round(c_out * fast_share / align)) * align
    c_fast = min(max(c_fast, 0), c_out)
    return SplitPlan(c_out=c_out, c_fast=c_fast, align=align)


def pack_weights(w: jax.Array, plan: SplitPlan) -> jax.Array:
    """(C_in, C_out) -> (2, C_in, c_pad): per-group padded weight slices."""
    c_in = w.shape[0]
    wf = jnp.zeros((c_in, plan.c_pad), w.dtype).at[:, :plan.c_fast].set(
        w[:, :plan.c_fast])
    ws = jnp.zeros((c_in, plan.c_pad), w.dtype).at[:, :plan.c_slow].set(
        w[:, plan.c_fast:])
    return jnp.stack([wf, ws])


def coexec_mesh(devices=None) -> Mesh:
    """A two-group mesh along the co-execution axis."""
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices) - len(devices) % 2
    arr = np.array(devices[:n]).reshape(2, n // 2)
    return Mesh(arr, (COEXEC_AXIS, "lane"))


def coexec_matmul(x: jax.Array, packed_w: jax.Array, plan: SplitPlan,
                  mesh: Mesh, *, gather: bool = True) -> jax.Array:
    """Channel-split matmul: each group computes its slice of X @ W.

    x: (L, C_in) replicated; packed_w: (2, C_in, c_pad) sharded on group.
    Returns (L, C_out) if gather else the group-local (2, L, c_pad) stack.
    """

    def local(x_l, w_l):
        # w_l: (1, C_in, c_pad) — this group's slice
        return (x_l @ w_l[0])[None]          # (1, L, c_pad)

    # jax.shard_map graduated from jax.experimental in newer releases;
    # support both spellings.
    shard_map = getattr(jax, "shard_map", None)
    if shard_map is None:
        from jax.experimental.shard_map import shard_map

    y = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(COEXEC_AXIS, None, "lane")),
        out_specs=P(COEXEC_AXIS, None, "lane"),
    )(x, packed_w)                            # (2, L, c_pad) global

    if not gather:
        return y
    # materialize the combined output — the paper's synchronization point.
    # Reshard each group's slice to replicated first: concatenating slices
    # that are still lane-sharded miscompiles on some jax releases (values
    # double through the partitioner), and the gather IS the sync point, so
    # an explicit reshard is the honest lowering.
    rep = NamedSharding(mesh, P())
    y_fast = jax.device_put(y[0, :, :plan.c_fast], rep)
    y_slow = jax.device_put(y[1, :, :plan.c_slow], rep)
    return jnp.concatenate([y_fast, y_slow], axis=-1)


def coexec_linear_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """Oracle for tests: plain X @ W."""
    return x @ w
