"""Operation descriptions for the paper's workload domain.

The paper partitions *individual* linear and convolutional operations along
their output channels (Section 2).  These dataclasses are the common currency
between the hardware simulator, the latency predictors, the partitioner and
the end-to-end planner.
"""
from __future__ import annotations

import dataclasses
from typing import Union


@dataclasses.dataclass(frozen=True)
class LinearOp:
    """Y = X @ W with X: (L, C_in), W: (C_in, C_out)."""

    L: int
    C_in: int
    C_out: int

    @property
    def flops(self) -> int:
        return 2 * self.L * self.C_in * self.C_out

    @property
    def input_bytes(self) -> int:
        return 4 * self.L * self.C_in

    @property
    def weight_bytes(self) -> int:
        return 4 * self.C_in * self.C_out

    @property
    def output_bytes(self) -> int:
        return 4 * self.L * self.C_out

    def with_cout(self, c_out: int) -> "LinearOp":
        return dataclasses.replace(self, C_out=c_out)


@dataclasses.dataclass(frozen=True)
class ConvOp:
    """2D convolution, NHWC, square K x K filter, stride S, SAME padding."""

    H_in: int
    W_in: int
    C_in: int
    C_out: int
    K: int = 3
    S: int = 1

    @property
    def H_out(self) -> int:
        return max(1, self.H_in // self.S)

    @property
    def W_out(self) -> int:
        return max(1, self.W_in // self.S)

    @property
    def flops(self) -> int:
        return 2 * self.H_out * self.W_out * self.C_out * self.K * self.K * self.C_in

    @property
    def input_bytes(self) -> int:
        return 4 * self.H_in * self.W_in * self.C_in

    @property
    def weight_bytes(self) -> int:
        return 4 * self.K * self.K * self.C_in * self.C_out

    @property
    def output_bytes(self) -> int:
        return 4 * self.H_out * self.W_out * self.C_out

    def with_cout(self, c_out: int) -> "ConvOp":
        return dataclasses.replace(self, C_out=c_out)


Op = Union[LinearOp, ConvOp]


def op_with_cout(op: Op, c_out: int) -> Op:
    return op.with_cout(c_out)
