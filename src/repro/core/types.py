"""Operation descriptions for the paper's workload domain.

The paper partitions *individual* linear and convolutional operations along
their output channels (Section 2).  These dataclasses are the common currency
between the hardware simulator, the latency predictors, the partitioner and
the end-to-end planner.

Beyond the paper's conv/linear grammar, the graph IR (`repro.graph`) also
schedules decoder-block ops: `AttnOp` (single-position decode attention
over a KV cache) and `SSMOp` (a chunked SSD state-space scan).  These are
not output-channel-splittable, but they partition along typed axes of
their own — attention across query-head groups or KV-cache blocks, SSM
across state heads — and carry a kernel *mode* (streaming vs materialized
scores; chunked scan vs fused recurrence) that the planner selects
alongside the split.  They share the accounting surface (`flops`,
`input_bytes`, `weight_bytes`, `output_bytes`) so analytic latency charges
and measurement records treat every op kind uniformly.
"""
from __future__ import annotations

import dataclasses
from typing import Union


@dataclasses.dataclass(frozen=True)
class LinearOp:
    """Y = X @ W with X: (L, C_in), W: (C_in, C_out)."""

    L: int
    C_in: int
    C_out: int

    @property
    def flops(self) -> int:
        return 2 * self.L * self.C_in * self.C_out

    @property
    def input_bytes(self) -> int:
        return 4 * self.L * self.C_in

    @property
    def weight_bytes(self) -> int:
        return 4 * self.C_in * self.C_out

    @property
    def output_bytes(self) -> int:
        return 4 * self.L * self.C_out

    def with_cout(self, c_out: int) -> "LinearOp":
        return dataclasses.replace(self, C_out=c_out)


@dataclasses.dataclass(frozen=True)
class ConvOp:
    """2D convolution, NHWC, square K x K filter, stride S, SAME padding."""

    H_in: int
    W_in: int
    C_in: int
    C_out: int
    K: int = 3
    S: int = 1

    @property
    def H_out(self) -> int:
        return max(1, self.H_in // self.S)

    @property
    def W_out(self) -> int:
        return max(1, self.W_in // self.S)

    @property
    def flops(self) -> int:
        return 2 * self.H_out * self.W_out * self.C_out * self.K * self.K * self.C_in

    @property
    def input_bytes(self) -> int:
        return 4 * self.H_in * self.W_in * self.C_in

    @property
    def weight_bytes(self) -> int:
        return 4 * self.K * self.K * self.C_in * self.C_out

    @property
    def output_bytes(self) -> int:
        return 4 * self.H_out * self.W_out * self.C_out

    def with_cout(self, c_out: int) -> "ConvOp":
        return dataclasses.replace(self, C_out=c_out)


@dataclasses.dataclass(frozen=True)
class AttnOp:
    """Single-position (decode-step) GQA attention over a length-S KV cache.

    The activation is the current token's query block, flattened to
    (1, H * hd); the KV cache is the op's parameter tensor (2, S, KV, hd)
    — state, not activation, exactly as in a serving decode step.  The op
    attends causally to positions 0..S-1 (optionally sliding-window
    limited) and produces the (1, H * hd) attended block.
    """

    H: int                    # query heads
    S: int                    # cache length (attends to positions 0..S-1)
    KV: int                   # KV heads (GQA; H % KV == 0)
    hd: int                   # head dimension
    window: int = 0           # 0 = full causal attention
    mode: str = "streaming"   # kernel mode: streaming | materialized

    def __post_init__(self):
        if self.H < 1 or self.KV < 1 or self.H % self.KV:
            raise ValueError(f"AttnOp needs H divisible by KV, "
                             f"got H={self.H} KV={self.KV}")
        if self.S < 1 or self.hd < 1:
            raise ValueError(f"AttnOp needs positive S/hd, "
                             f"got S={self.S} hd={self.hd}")
        if self.mode not in ("streaming", "materialized"):
            raise ValueError(f"AttnOp mode must be streaming|materialized, "
                             f"got {self.mode!r}")

    def with_heads(self, h: int) -> "AttnOp":
        """Sub-op attending with `h` query heads (GQA group granularity:
        `h` must be a whole number of H//KV-sized groups)."""
        group = self.H // self.KV
        if h % group:
            raise ValueError(f"head slice {h} breaks GQA groups of {group}")
        return dataclasses.replace(self, H=h, KV=h // group)

    def with_cache(self, s: int) -> "AttnOp":
        """Sub-op over a length-`s` block of the KV cache."""
        return dataclasses.replace(self, S=s)

    def with_mode(self, mode: str) -> "AttnOp":
        return dataclasses.replace(self, mode=mode)

    @property
    def flops(self) -> int:
        # q.k scores + probs.v, each 2*H*S*hd MACs-as-flops
        return 4 * self.H * self.S * self.hd

    @property
    def input_bytes(self) -> int:
        return 4 * self.H * self.hd

    @property
    def weight_bytes(self) -> int:
        return 4 * 2 * self.S * self.KV * self.hd     # the KV cache

    @property
    def output_bytes(self) -> int:
        return 4 * self.H * self.hd


@dataclasses.dataclass(frozen=True)
class SSMOp:
    """Chunked SSD (Mamba2-style) scan over T tokens.

    The activation is the inner-projected token block (T, H * hd); the
    B/C/dt projections, the per-head decay and the carried state are the
    op's parameter vector (flattened; the lowering unpacks and applies the
    stabilizing transforms).  Output is the scanned (T, H * hd) block.
    """

    T: int                    # tokens scanned
    H: int                    # SSM heads
    hd: int                   # head dimension
    N: int                    # state dimension per head
    mode: str = "chunked"     # kernel mode: chunked | recurrent

    def __post_init__(self):
        if min(self.T, self.H, self.hd, self.N) < 1:
            raise ValueError(f"SSMOp needs positive dims, got {self}")
        if self.mode not in ("chunked", "recurrent"):
            raise ValueError(f"SSMOp mode must be chunked|recurrent, "
                             f"got {self.mode!r}")

    def with_heads(self, h: int) -> "SSMOp":
        """Sub-op carrying `h` of the state heads."""
        if h < 1 or h > self.H:
            raise ValueError(f"head slice {h} out of range for H={self.H}")
        return dataclasses.replace(self, H=h)

    def with_mode(self, mode: str) -> "SSMOp":
        return dataclasses.replace(self, mode=mode)

    @property
    def flops(self) -> int:
        # per token: state update (~4*H*hd*N) + output contraction (2*H*hd*N)
        return 6 * self.T * self.H * self.hd * self.N

    @property
    def input_bytes(self) -> int:
        return 4 * self.T * self.H * self.hd

    @property
    def weight_bytes(self) -> int:
        # b, c: (T, N) each; dt: (T, H); a: (H,); state0: (H, hd, N)
        return 4 * (2 * self.T * self.N + self.T * self.H + self.H
                    + self.H * self.hd * self.N)

    @property
    def output_bytes(self) -> int:
        return 4 * self.T * self.H * self.hd


#: the output-channel-splittable kinds — the paper's partitioning domain
SplittableOp = Union[LinearOp, ConvOp]

#: every schedulable op kind (graph IR node payloads)
Op = Union[LinearOp, ConvOp, AttnOp, SSMOp]


def op_with_cout(op: SplittableOp, c_out: int) -> SplittableOp:
    return op.with_cout(c_out)
