"""Synthetic data pipeline.

Deterministic on-the-fly token streams (no external datasets in the offline
container): a mixing of Zipfian unigram draws and short repeated motifs so
the LM loss has learnable structure.  Provides batching, packing to fixed
sequence length, and modality-stub inputs (frame/patch embeddings) per the
assignment carve-out.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass
class DataConfig:
    batch_size: int
    seq_len: int
    seed: int = 0


class SyntheticTokenStream:
    """Zipf unigrams + motif repetition; yields packed (tokens, labels)."""

    def __init__(self, vocab_size: int, cfg: DataConfig):
        self.vocab = vocab_size
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        # motif table: 64 motifs of length 8
        self.motifs = self.rng.integers(0, vocab_size,
                                        size=(64, 8), dtype=np.int32)

    def _sample_seq(self, length: int) -> np.ndarray:
        out = np.empty(length + 1, dtype=np.int32)
        i = 0
        while i < length + 1:
            if self.rng.random() < 0.3:
                m = self.motifs[self.rng.integers(0, len(self.motifs))]
                n = min(len(m), length + 1 - i)
                out[i:i + n] = m[:n]
                i += n
            else:
                n = min(int(self.rng.integers(4, 17)), length + 1 - i)
                # Zipf-ish draw, clipped to vocab
                z = self.rng.zipf(1.3, size=n).astype(np.int64) % self.vocab
                out[i:i + n] = z.astype(np.int32)
                i += n
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        b, t = self.cfg.batch_size, self.cfg.seq_len
        while True:
            seqs = np.stack([self._sample_seq(t) for _ in range(b)])
            yield {"tokens": seqs[:, :-1], "labels": seqs[:, 1:]}


def make_batch(cfg: ModelConfig, batch_size: int, seq_len: int,
               seed: int = 0) -> Dict[str, np.ndarray]:
    """One synthetic batch with the right extra inputs for the modality."""
    stream = SyntheticTokenStream(cfg.vocab_size,
                                  DataConfig(batch_size, seq_len, seed))
    batch = next(iter(stream))
    if cfg.is_encoder_decoder:
        rng = np.random.default_rng(seed + 1)
        batch["frames"] = rng.normal(
            0, 1, size=(batch_size, cfg.encoder_seq, cfg.d_model)
        ).astype(np.float32) * 0.02
    return batch
