from repro.data.pipeline import DataConfig, SyntheticTokenStream, make_batch

__all__ = ["DataConfig", "SyntheticTokenStream", "make_batch"]
