"""chameleon-34b [vlm] — early-fusion token-based mixed-modal
[arXiv:2405.09818].  VQ image tokens live in the unified 65536 vocab, so the
language backbone consumes ordinary token ids; the VQ-VAE image tokenizer is
the stubbed frontend.  Chameleon uses qk-norm for stability.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab_size=65536,
    qk_norm=True, rope_theta=1e4, modality="vision_stub",
)
