"""rwkv6-1.6b [ssm] — "Finch", attention-free, data-dependent decay
[arXiv:2404.05892].  head size 64; channel-mix d_ff=7168."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab_size=65536,
    attn_kind="none", ssm_kind="rwkv6", ssm_head_dim=64,
)
