"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE [arXiv:2405.04434].

Assignment line lists "MoE 64e top-6" and "2 shared+160 routed"; the real
DeepSeek-V2-Lite has 64 routed experts (top-6) + 2 shared, which we follow
(the 160-routed figure belongs to full V2).  First layer is dense.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attn_kind="mla", kv_lora_rank=512,
    qk_rope_head_dim=64, qk_nope_head_dim=128, v_head_dim=128,
    n_experts=64, n_shared_experts=2, experts_per_token=6,
    moe_d_ff=1408, first_dense_layers=1,
    rope_theta=1e4,
)
