"""gemma3-12b [dense] — 5:1 local:global sliding-window, 262k vocab
[hf:google/gemma-3-1b-pt].  head_dim=256 (not d_model/n_heads); local layers
use a 1024-token window; qk-norm."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8,
    d_ff=15360, vocab_size=262144, head_dim=256,
    qk_norm=True, sliding_window=1024, local_global_ratio=5,
    rope_theta=1e6,
)
