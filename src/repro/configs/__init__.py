"""One config module per assigned architecture (+ the paper's own models).

Every config cites its source in the module docstring and instantiates a
single `CONFIG: ModelConfig`.
"""
