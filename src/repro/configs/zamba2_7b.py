"""zamba2-7b [hybrid] — Mamba2 backbone + weight-shared attention blocks
[arXiv:2411.15242].  81 layers; shared attn applied every 9 layers (the
reference interleaves 2 shared blocks; see DESIGN.md), ssm_state=64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_kind="mamba2", ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    attn_every=9,
)
