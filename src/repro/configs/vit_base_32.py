"""ViT-Base-32 — the paper's running example workload (arXiv:2010.11929).
Used by the core benchmarks as a source of linear-op shapes (L=50 tokens,
d=768, mlp 3072)."""
from repro.core.types import LinearOp

# the paper's running-example op: (50, 768) @ (768, 3072)
MLP_UP = LinearOp(L=50, C_in=768, C_out=3072)
MLP_DOWN = LinearOp(L=50, C_in=3072, C_out=768)
QKV = LinearOp(L=50, C_in=768, C_out=2304)
PROJ = LinearOp(L=50, C_in=768, C_out=768)
ALL_OPS = [QKV, PROJ, MLP_UP, MLP_DOWN]
