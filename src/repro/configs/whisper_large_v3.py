"""whisper-large-v3 [audio] — encoder-decoder, conv frontend stubbed to
frame embeddings [arXiv:2212.04356].  32 encoder + 32 decoder layers, MHA
(kv=20).  decode_32k / long_500k exceed the model's 448-token target spec
but are lowered mechanically per the assignment."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    is_encoder_decoder=True, encoder_layers=32, encoder_seq=1500,
    modality="audio_stub",
)
