"""`python -m repro` — the unified CLI (see repro/cli.py)."""
from repro.cli import main

if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:     # e.g. `... | head` closed the pipe
        import os
        import sys
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        raise SystemExit(0)
