"""Named-axis sharding rules for parameters, optimizer state, batches and
KV caches.

Rules are regex patterns over flattened parameter paths, each giving a
PartitionSpec *anchored at the trailing dimensions* of the leaf; leading
stack axes (scan repeats, zamba groups) are padded with None.  After rule
lookup every spec is *sanitized*: an axis that does not evenly divide its
dimension is dropped (replicated) so that any (config x mesh) combination
lowers — awkward head counts degrade gracefully instead of failing.

Strategy (2D "data x model", optionally with a leading "pod" axis):
  * token embeddings / unembeddings: vocab on model;
  * attention/MLP projections: output features on model, input features on
    data (FSDP-style 2D weight sharding keeps 405B-class checkpoints and
    AdamW moments within per-chip HBM);
  * MoE experts: expert axis on model;
  * batches: batch dim on (pod, data);
  * KV caches: batch on data; heads (or head_dim, or the MLA latent) on
    model; for batch=1 long-context decode the *sequence* dim goes on data.
"""
from __future__ import annotations

import re
from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# (path regex, min ndim of the anchored spec, trailing spec)
_PARAM_RULES: Tuple[Tuple[str, int, Tuple], ...] = (
    (r"embed$", 2, ("model", "data")),
    (r"unembed$", 2, ("data", "model")),
    # --- MoE (must precede generic ffn rules; leaves are 3D E,.,.) ---
    (r"ffn/router$", 2, (None, None)),
    (r"(ffn|moe)/w_gate$", 3, ("model", "data", None)),
    (r"(ffn|moe)/w_up$", 3, ("model", "data", None)),
    (r"(ffn|moe)/w_down$", 3, ("model", None, "data")),
    (r"shared/w_gate$", 2, ("data", "model")),
    (r"shared/w_up$", 2, ("data", "model")),
    (r"shared/w_down$", 2, ("model", "data")),
    # --- MLA ---
    (r"attn/wq$", 2, ("data", "model")),
    (r"w_dkv$", 2, ("data", "model")),
    (r"w_krope$", 2, ("data", None)),
    (r"w_uk$", 3, (None, "model", None)),
    (r"w_uv$", 3, (None, "model", None)),
    # --- attention ---
    (r"(attn|self_attn|cross_attn)/w[kv]$", 2, ("data", "model")),
    (r"(attn|self_attn|cross_attn)/b[qkv]$", 1, ("model",)),
    (r"(attn|self_attn|cross_attn|tm)/wo$", 2, ("model", "data")),
    # --- dense mlp ---
    (r"(ffn|mlp)/w_gate$", 2, ("data", "model")),
    (r"(ffn|mlp)/w_up$", 2, ("data", "model")),
    (r"(ffn|mlp)/w_down$", 2, ("model", "data")),
    # --- rwkv ---
    (r"tm/w[rkvg]$", 2, ("data", "model")),
    (r"cm/wk$", 2, ("data", "model")),
    (r"cm/wv$", 2, ("model", "data")),
    # --- mamba ---
    (r"mixer/w_in$", 2, ("data", "model")),
    (r"mixer/w_out$", 2, ("model", "data")),
)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                    for p in path)


def sanitize(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop axes that don't divide the dim; never shard size-1 dims."""
    out = []
    for dim, axis in zip(shape, spec):
        if axis is None:
            out.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        if dim % size == 0 and dim >= size and size > 1:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def param_spec(path, leaf, mesh: Mesh) -> P:
    ps = _path_str(path)
    nd = leaf.ndim
    for pat, anchor_nd, tail in _PARAM_RULES:
        if re.search(pat, ps) and nd >= anchor_nd:
            spec = (None,) * (nd - len(tail)) + tail
            return sanitize(spec, leaf.shape, mesh)
    return P(*([None] * nd))                 # norms, scalars, biases


def param_shardings(params_shape, mesh: Mesh):
    """NamedSharding pytree for a params (or congruent opt-state) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(path, leaf, mesh)),
        params_shape)


def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_spec(batch_size: int, mesh: Mesh) -> P:
    axes = _batch_axes(mesh)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    if batch_size % size == 0 and batch_size >= size:
        return P(axes)
    if batch_size % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def batch_shardings(batch, mesh: Mesh):
    """Shard dim 0 (batch) of every batch leaf."""
    def spec(leaf):
        s = batch_spec(leaf.shape[0], mesh)
        return NamedSharding(mesh, P(*(tuple(s) + (None,) *
                                       (leaf.ndim - 1))))
    return jax.tree_util.tree_map(spec, batch)


def cache_spec(path, leaf, mesh: Mesh, *, batch: int,
               seq_shard: bool) -> P:
    """KV-cache leaf sharding.

    Layout conventions (see models/*): trailing dims are one of
      (B, S, kv, hd) attention cache   (possibly with leading stack dims)
      (B, S, r)      MLA latent cache
      (B, H, hd, N)  ssm state; (B, K-1, C) conv carry; (B, D) shift carry
    """
    ps = _path_str(path)
    nd = leaf.ndim
    b_ax = batch_spec(batch, mesh)
    b_entry = tuple(b_ax)[0] if tuple(b_ax) else None
    s_entry = "data" if (seq_shard and b_entry is None) else None

    if re.search(r"(wkv|ssm)", ps) and nd >= 4:          # (B,H,hd,N)-like
        tail = (b_entry, "model", None, None)
    elif re.search(r"(conv|x_tm|x_cm)", ps):
        tail = (b_entry,) + (None,) * (min(nd, 3) - 1)
    elif re.search(r"enc$", ps):
        tail = (b_entry, None, "model")
    elif nd >= 4:                                        # (B,S,kv,hd)
        kv, hd = leaf.shape[-2], leaf.shape[-1]
        m = mesh.shape["model"]
        if kv % m == 0:                                  # shard kv heads
            tail = (b_entry, s_entry, "model", None)
        elif hd % m == 0:                                # shard head_dim
            tail = (b_entry, s_entry, None, "model")
        else:
            tail = (b_entry, s_entry, None, None)
    elif nd == 3:                                        # (B,S,r) latent
        tail = (b_entry, s_entry, "model")
    else:
        tail = (b_entry,) + (None,) * (nd - 1)
    tail = tail[:nd]
    spec = (None,) * (nd - len(tail)) + tail
    return sanitize(spec, leaf.shape, mesh)


def cache_shardings(cache_shape, mesh: Mesh, *, batch: int,
                    seq_shard: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(
            mesh, cache_spec(path, leaf, mesh, batch=batch,
                             seq_shard=seq_shard)),
        cache_shape)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
