"""Ambient-mesh activation sharding constraints.

Model code calls `constrain(x, "data", None, "model")` at key points (qkv
projections, FFN intermediates, MoE expert dims).  When a mesh has been
installed via `activation_mesh(mesh)` the constraint becomes a
`with_sharding_constraint`; axes that do not divide the corresponding dim
are dropped (replicated) so any (config x mesh) lowers.  Without an
installed mesh (CPU tests, examples) it is the identity — model code stays
runnable everywhere.

Why this exists: with input shardings alone, XLA's sharding propagation on
the 256-chip mesh prefers to all-gather the (model-axis-sharded) weights
and compute replicated — a ~16x FLOP and collective blow-up measured in the
codeqwen train_4k dry-run (see EXPERIMENTS.md §Perf, iteration 0 -> 1).
Constraining activations pins the tensor-parallel pattern instead.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "activation_mesh", default=None)


@contextlib.contextmanager
def activation_mesh(mesh: Mesh):
    token = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def _manual_axes() -> frozenset:
    """Mesh axes currently under shard_map manual control (constraints on
    those axes are illegal inside the manual region)."""
    try:
        amesh = jax.sharding.get_abstract_mesh()
        return frozenset(
            name for name, ty in zip(amesh.axis_names, amesh.axis_types)
            if "Manual" in str(ty))
    except Exception:                                  # noqa: BLE001
        return frozenset()


def constrain(x: jax.Array, *spec):
    """Best-effort sharding constraint; identity without an ambient mesh."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    manual = _manual_axes()
    spec = tuple(spec) + (None,) * (x.ndim - len(spec))
    clean = []
    for dim, axis in zip(x.shape, spec):
        if axis is None:
            clean.append(None)
            continue
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        if any(a not in mesh.shape or a in manual for a in axes):
            clean.append(None)
            continue
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        clean.append(axis if (size > 1 and dim % size == 0) else None)
    if all(c is None for c in clean) and manual:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean)))


def batch_axes():
    """('pod','data') on the multi-pod mesh, else ('data',)."""
    mesh = _MESH.get()
    if mesh is not None and "pod" in mesh.shape:
        return ("pod", "data")
    return ("data",)
