from repro.sharding.rules import (batch_shardings, batch_spec,
                                  cache_shardings, param_shardings,
                                  param_spec, replicated, sanitize)
__all__ = ["batch_shardings", "batch_spec", "cache_shardings",
           "param_shardings", "param_spec", "replicated", "sanitize"]
