"""The compile→run facade: one stable API over planning, caching,
execution, and serving.

The paper's workflow is one pipeline — profile a device, predict per-op
CPU/GPU latency, pick a split, execute with cheap synchronization — but the
pieces live in four subsystems (core/partitioner, core/planner, runtime,
serving).  This module is the single front door:

    import repro
    target = repro.Target(device="moto2022", threads=3)
    compiled = repro.compile("resnet18", target)        # cached planning
    y = compiled.run()                                  # split execution
    report = compiled.profile()                         # fidelity report
    compiled.save("resnet18.coexec.json")               # ship the artifact

`Target` captures everything a plan's validity depends on at the request
level (device, threads, sync mechanism, candidate-grid step, measurement
seed, mesh policy) and validates itself eagerly.  `compile` resolves the
network (a `repro.graph.Graph`, a registered network or model name, a
unit list, or a bare op list), trains-or-loads the mux predictors when
the mode needs them, runs the *cached* planners (`plan_graph_cached` /
`partition_ops_plan_cached` / `grid_plan_graph_cached` —
provenance-identical to calling them directly, so facade and pre-facade
callers share on-disk cache entries bit-for-bit), and returns a
`CompiledNetwork`: the `CoexecPlan` plus a lazily-built `PlanExecutor`
and save/load/explain on top.

Importing this module never imports jax; execution machinery loads on the
first `run`/`profile`/`executor` call.

The unified CLI (`python -m repro` — see cli.py) and `ServingEngine
(compiled=...)` are thin clients of this module.  The legacy single-op
entry points are re-exported at the bottom as deprecation shims.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import warnings
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.core.networks import NETWORKS, Unit
from repro.core.simulator.devices import DEVICES
from repro.core.sync import SyncMechanism
from repro.core.types import ConvOp, LinearOp, Op
from repro.graph.ir import Graph, from_units
from repro.runtime.cache import (PlanCache, grid_plan_graph_cached,
                                 partition_ops_plan_cached,
                                 plan_graph_cached)
from repro.runtime.plan import CoexecPlan, PlanProvenance, spec_label

#: compile() planning modes
MODE_PREDICTED = "predicted"     # GBDT predictors (the deployable path)
MODE_GRID = "grid"               # measurement-driven oracle (upper bound)

#: Target.mesh policies
MESH_AUTO = "auto"               # split when >= 2 devices, degrade otherwise
MESH_SINGLE = "single"           # force the degraded exclusive-only mesh
MESH_SPLIT = "split"             # require a 2-group mesh, error otherwise

ARTIFACT_FORMAT = "repro.compiled_network"
ARTIFACT_VERSION = 1

DEFAULT_CACHE_DIR = "reports/plans"
DEFAULT_MEASUREMENTS_DIR = "reports/measurements"


# ------------------------------------------------------------------ target

@dataclasses.dataclass(frozen=True)
class Target:
    """Where and how a network will run — the request half of provenance.

    Validates eagerly: an invalid device/mechanism/step/mesh fails at
    construction, not deep inside planning.  `mechanism` accepts either a
    `SyncMechanism` or its string value and normalizes to the string, so
    targets compare/serialize structurally.
    """

    device: str
    threads: int = 3
    mechanism: str = SyncMechanism.SVM_POLL.value
    step: int = 8
    seed: int = 1
    mesh: str = MESH_AUTO

    def __post_init__(self):
        if self.device not in DEVICES:
            raise ValueError(f"unknown device {self.device!r}; "
                             f"choices: {sorted(DEVICES)}")
        if isinstance(self.mechanism, SyncMechanism):
            object.__setattr__(self, "mechanism", self.mechanism.value)
        try:
            SyncMechanism(self.mechanism)
        except ValueError:
            raise ValueError(
                f"unknown sync mechanism {self.mechanism!r}; "
                f"choices: {[m.value for m in SyncMechanism]}") from None
        # exact int checks: bool is an int subclass, but threads=True would
        # serialize as JSON `true` and split the cache key from threads=1
        if type(self.threads) is not int or self.threads < 1:
            raise ValueError(f"threads must be a positive int, "
                             f"got {self.threads!r}")
        if type(self.step) is not int or self.step < 1:
            raise ValueError(f"step must be a positive int, "
                             f"got {self.step!r}")
        if self.mesh not in (MESH_AUTO, MESH_SINGLE, MESH_SPLIT):
            raise ValueError(f"unknown mesh policy {self.mesh!r}; "
                             f"choices: ['auto', 'single', 'split']")

    @property
    def sync_mechanism(self) -> SyncMechanism:
        return SyncMechanism(self.mechanism)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Target":
        return Target(**d)


# -------------------------------------------------------------- predictors

def _trained_mux_predictors(device: str, threads: int, *, samples: int,
                            estimators: int,
                            cache_dir: Optional[Union[str, Path]] = None,
                            kinds: Sequence[str] = ("linear", "conv")):
    """Train (or load from `cache_dir`) the (cpu, gpu) MuxPredictor pair.

    The on-disk layout is one pickle per underlying LatencyPredictor, keyed
    by every training knob — a load is checksum-identical to a retrain, so
    predictor caching never changes which plan-cache entry a compile hits.
    `kinds` beyond linear/conv (attention, ssm) add decode members as extra
    role files; the linear/conv files are shared with conv-only compiles.
    """
    from repro.runtime.plan import train_mux_predictors

    if cache_dir is None:
        return train_mux_predictors(device, threads, samples=samples,
                                    estimators=estimators, kinds=kinds)

    from repro.core.predictor.train import LatencyPredictor, MuxPredictor
    root = Path(cache_dir)
    stem = f"mux_{device}_cpu{threads}_{samples}x{estimators}"
    paths = {f"{side}_{kind}": root / f"{stem}_{side}_{kind}.pkl"
             for side in ("cpu", "gpu") for kind in kinds}
    if all(p.exists() for p in paths.values()):
        try:
            def member(side, kind):
                if kind not in kinds:
                    return None
                return LatencyPredictor.load(paths[f"{side}_{kind}"])

            cp = MuxPredictor(member("cpu", "linear"),
                              member("cpu", "conv"),
                              attention=member("cpu", "attention"),
                              ssm=member("cpu", "ssm"))
            gp = MuxPredictor(member("gpu", "linear"),
                              member("gpu", "conv"),
                              attention=member("gpu", "attention"),
                              ssm=member("gpu", "ssm"))
            return cp, gp
        except Exception:           # noqa: BLE001 — corrupt cache: retrain
            pass
    cp, gp = train_mux_predictors(device, threads, samples=samples,
                                  estimators=estimators, kinds=kinds)
    root.mkdir(parents=True, exist_ok=True)
    for side, p in (("cpu", cp), ("gpu", gp)):
        for kind in kinds:
            m = p.member(kind)
            if m is not None:
                m.save(paths[f"{side}_{kind}"])
    return cp, gp


# ------------------------------------------------------- network resolution

def available_networks() -> Dict[str, List[str]]:
    """Every name `compile` resolves, from the two registries: legacy
    unit-chain networks (`core.networks.NETWORKS`) and decoder-block model
    graphs (`graph.frontends`: tiny configs + `models.registry`)."""
    from repro.graph.frontends import model_names
    return {"networks": sorted(NETWORKS), "models": model_names()}


def _unknown_name_error(name: str) -> ValueError:
    names = available_networks()
    return ValueError(
        f"unknown network {name!r}; registered unit networks: "
        f"{names['networks']}; model graphs (via graph.from_model): "
        f"{names['models']}")


def _resolve_graph(network) -> Tuple[Union[Graph, List[Op]], bool]:
    """Normalize `compile`'s first argument to (graph_or_ops, is_graph).

    Accepts a `repro.graph.Graph`, a registered network or model name, a
    unit list (("conv"/"linear"/"pool", payload) tuples), or a bare op
    list.  Everything except bare op lists lowers to a Graph; bare op
    lists are planned per-op (no end-to-end report, threads/seed-free
    provenance — the Table 2 contract), hence the flag.
    """
    if isinstance(network, Graph):
        return network, True
    if isinstance(network, str):
        if network in NETWORKS:
            return from_units(NETWORKS[network]()), True
        from repro.graph.frontends import from_model, model_names
        if network in model_names():
            return from_model(network), True
        raise _unknown_name_error(network)
    seq = list(network)
    if not seq:
        raise ValueError("cannot compile an empty network")
    if all(isinstance(e, (LinearOp, ConvOp)) for e in seq):
        return seq, False
    if all(isinstance(e, tuple) and len(e) == 2 and isinstance(e[0], str)
           for e in seq):
        return from_units(seq), True
    raise TypeError(
        "network must be a repro.graph.Graph, a registered name, a unit "
        "list [(kind, payload), ...], or a bare op list "
        f"[LinearOp/ConvOp, ...]; got {type(seq[0]).__name__} elements")


# ------------------------------------------------------------------ compile

def compile(network, target: Target, *,               # noqa: A001 — facade
            mode: str = MODE_PREDICTED,
            cache: Union[PlanCache, str, Path] = DEFAULT_CACHE_DIR,
            predictors=None,
            samples: int = 400, estimators: int = 60,
            predictor_cache: Optional[Union[str, Path]] = None,
            bucket: str = "",
            tune: bool = False,
            tune_cache=None) -> "CompiledNetwork":
    """Compile a network into a `CompiledNetwork` (cached planning).

    * `network` — a `repro.graph.Graph`, a registered name ("resnet18",
      "tiny_decoder", "gemma3-12b", ...), a unit list, or a bare op list.
    * `target` — the validated `Target` (device/threads/mechanism/step/
      seed/mesh).
    * `mode` — "predicted" plans with trained GBDT predictors (the paper's
      deployable path); "grid" uses the measurement-driven oracle and
      needs no predictors.
    * `cache` — a `PlanCache` or a directory path; planning is skipped
      entirely on a warm hit (the plan file is just read back).
    * `predictors` — optional pre-trained (cpu, gpu) pair; when omitted in
      "predicted" mode a deterministic pair is trained (or loaded from
      `predictor_cache`) with `samples`/`estimators`.

    Provenance is identical to the underlying cached planners, so plans
    compiled here warm-hit entries written by pre-facade callers and vice
    versa.  `bucket` tags the plan with a serving (batch, seq) bucket —
    folded into the provenance digest so portfolio entries get their own
    cache files (see `compile_portfolio`); only graph plans in
    "predicted" mode accept it.

    `tune=True` runs the kernel tile autotuner (`runtime.autotune`) over
    the plan's ops on a cache miss and attaches winning non-default
    `TileConfig`s to the decisions; the tune-cache version folds into
    provenance, so tuned and untuned plans occupy distinct cache entries
    and each warm-hits independently.  `tune_cache` is a `TuneCache` or a
    directory path (default `reports/tune`); a warm tune cache makes the
    annotation pass measurement-free.
    """
    if not isinstance(target, Target):
        raise TypeError(f"target must be a repro.Target, "
                        f"got {type(target).__name__}")
    if mode not in (MODE_PREDICTED, MODE_GRID):
        raise ValueError(f"unknown mode {mode!r}; "
                         f"choices: ['predicted', 'grid']")
    graph_or_ops, is_graph = _resolve_graph(network)
    if bucket and (mode != MODE_PREDICTED or not is_graph):
        raise ValueError("bucket= requires a graph network in "
                         "mode='predicted' (portfolio entries must be "
                         "replannable)")
    if not isinstance(cache, PlanCache):
        cache = PlanCache(Path(cache))
    mech = target.sync_mechanism
    hits_before = cache.hits

    tune_tag = ""
    annotate = None
    if tune:
        from repro.runtime.autotune import (DEFAULT_TUNE_DIR, TuneCache,
                                            annotate_plan_tiles,
                                            tune_cache_version)
        tc = tune_cache
        if not isinstance(tc, TuneCache):
            tc = TuneCache(Path(tc) if tc is not None
                           else Path(DEFAULT_TUNE_DIR))
        tune_tag = tune_cache_version()

        def annotate(plan, _tc=tc):
            return annotate_plan_tiles(plan, cache=_tc)

    if mode == MODE_GRID:
        if predictors is not None:
            raise ValueError("mode='grid' is measurement-driven and takes "
                             "no predictors; drop predictors= or use "
                             "mode='predicted'")
        if not is_graph:
            from repro.kernels.registry import op_kind
            graph_or_ops = from_units(
                [(op_kind(op), op) for op in graph_or_ops])
        plan = grid_plan_graph_cached(
            graph_or_ops, target.device, target.threads, mechanism=mech,
            step=target.step, seed=target.seed, tune=tune_tag,
            annotate=annotate, cache=cache)
    else:
        if predictors is None:
            kinds: Tuple[str, ...] = ("linear", "conv")
            if is_graph:
                # decode kinds present in the graph get predictor members
                # so the planner can price (axis, split, mode) candidates;
                # conv/linear-only graphs keep the pre-decode predictor
                # bundle (and its checksum, hence their cached plans)
                kinds += tuple(sorted(
                    {n.kind for n in graph_or_ops
                     if n.op is not None and n.kind in ("attention", "ssm")}))
            predictors = _trained_mux_predictors(
                target.device, target.threads, samples=samples,
                estimators=estimators, cache_dir=predictor_cache,
                kinds=kinds)
        cpu_pred, gpu_pred = predictors
        if gpu_pred.device != target.device:
            raise ValueError(
                f"predictors were trained for {gpu_pred.device!r} but the "
                f"target device is {target.device!r}")
        if is_graph:
            plan = plan_graph_cached(
                graph_or_ops, cpu_pred, gpu_pred, threads=target.threads,
                mechanism=mech, step=target.step, seed=target.seed,
                bucket=bucket, tune=tune_tag, annotate=annotate,
                cache=cache)
        else:
            plan = partition_ops_plan_cached(
                graph_or_ops, cpu_pred, gpu_pred,
                mechanism=mech, step=target.step, tune=tune_tag,
                annotate=annotate, cache=cache)

    return CompiledNetwork(plan=plan, target=target, mode=mode,
                           from_cache=cache.hits > hits_before,
                           predictors=predictors)


# --------------------------------------------------------- compiled network

def _artifact_checksum(doc: Dict[str, Any]) -> str:
    # .get, not [] — a truncated artifact must surface as the checksum
    # ValueError in from_json, not a KeyError from in here
    body = {k: doc.get(k) for k in ("format", "version", "mode", "target",
                                    "plan")}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.blake2b(blob.encode(), digest_size=16).hexdigest()


class CompiledNetwork:
    """The compile-once / run-many artifact: plan + lazily-built executor.

    Owns the `CoexecPlan` (schedule + provenance), the `Target` it was
    compiled for, and provenance extras (`mode`, `from_cache`).  Execution
    state (`PlanExecutor`, jax, the mesh) is built on first use and memoized
    per (dtype, chain-independent) configuration, so a compiled network is
    cheap to construct, serialize, and ship.
    """

    def __init__(self, plan: CoexecPlan, target: Target, *,
                 mode: str = MODE_PREDICTED, from_cache: bool = False,
                 predictors=None):
        self.plan = plan
        self.target = target
        self.mode = mode
        self.from_cache = from_cache
        self.predictors = predictors      # (cpu, gpu) when mode needed them
        self.last_report = None           # ExecutionReport of the last run
        self.calibration = None           # Calibrator from recalibrate()
        self._executors: Dict[Tuple, Any] = {}

    # --------------------------------------------------------- accessors
    @property
    def provenance(self) -> PlanProvenance:
        return self.plan.provenance

    @property
    def key(self) -> str:
        return self.plan.key

    @property
    def units(self) -> List[Unit]:
        """Legacy unit-list view (chain plans only; raises for DAG plans
        — use `.graph` instead)."""
        return self.plan.units

    @property
    def graph(self):
        """The compiled network's op graph (`repro.graph.Graph`)."""
        return self.plan.graph_ir()

    @property
    def decisions(self):
        return self.plan.decisions

    @property
    def decisions_by_node(self):
        return self.plan.decisions_by_node

    def report(self):
        """The planning-time `PlanReport` (None for bare-op plans)."""
        return self.plan.report()

    def __repr__(self) -> str:
        return (f"CompiledNetwork(mode={self.mode!r}, "
                f"device={self.target.device!r}, key={self.key!r}, "
                f"units={len(self.plan.schedule)})")

    # --------------------------------------------------------- execution
    def _mesh(self):
        from repro.core.coexec import coexec_mesh, mesh_groups
        mesh = coexec_mesh()
        if self.target.mesh == MESH_SINGLE and mesh_groups(mesh) != 1:
            import jax
            mesh = coexec_mesh(jax.devices()[:1])
        elif self.target.mesh == MESH_SPLIT and mesh_groups(mesh) != 2:
            raise RuntimeError(
                "target requires a 2-group split mesh but only a degraded "
                "single-group mesh is available (need >= 2 devices)")
        return mesh

    def executor(self, *, dtype="float32", seed: int = 0,
                 use_pallas: bool = False):
        """The (memoized) `PlanExecutor` lowering of this plan."""
        import jax.numpy as jnp

        from repro.runtime.executor import PlanExecutor
        dt = jnp.dtype(dtype)
        key = (dt.name, seed, use_pallas, self.target.mesh)
        if key not in self._executors:
            self._executors[key] = PlanExecutor(
                self.plan, mesh=self._mesh(), dtype=dt, seed=seed,
                use_pallas=use_pallas)
        return self._executors[key]

    def run(self, x=None, *, dtype="float32", chain: bool = True,
            warmup: bool = False, fused: bool = False, seed: int = 0,
            use_pallas: bool = False):
        """Execute the plan once; returns the output activation.

        `fused=True` takes the segment walk (one jitted program per fused
        segment, bit-identical outputs); the per-node walk is the
        `fused=False` reference.  The per-op `ExecutionReport` of this run
        is kept on `self.last_report` (and `profile()` is the report-first
        spelling).
        """
        exe = self.executor(dtype=dtype, seed=seed, use_pallas=use_pallas)
        y, report = exe.run(x, chain=chain, warmup=warmup, fused=fused)
        self.last_report = report
        return y

    def profile(self, x=None, *, dtype="float32", chain: bool = True,
                warmup: bool = True, fused: bool = False, seed: int = 0,
                use_pallas: bool = False):
        """Execute the plan and return the executed-vs-predicted
        `ExecutionReport` (warmed up by default so timings are
        steady-state, not tracing + compilation)."""
        exe = self.executor(dtype=dtype, seed=seed, use_pallas=use_pallas)
        _, report = exe.run(x, chain=chain, warmup=warmup, fused=fused)
        self.last_report = report
        return report

    # ------------------------------------- measurement & adaptive replan
    def _store(self, store):
        from repro.measure import MeasurementStore
        if isinstance(store, MeasurementStore):
            return store
        return MeasurementStore(Path(store))

    def record(self, x=None, *, store=DEFAULT_MEASUREMENTS_DIR,
               dtype="float32", chain: bool = True, warmup: bool = True,
               fused: bool = False, seed: int = 0, use_pallas: bool = False):
        """Execute the plan and append its per-op `MeasurementRecord`s to
        the measurement store (keyed by this plan's provenance digest).

        Returns the `ExecutionReport`; the accumulated records are what
        `recalibrate()` fits on.  Fused runs record with
        `source="fused"` (segment wall attributed pro-rata) and feed the
        same calibration fit.
        """
        report = self.profile(x, dtype=dtype, chain=chain, warmup=warmup,
                              fused=fused, seed=seed, use_pallas=use_pallas)
        self._store(store).append(report)
        return report

    def recalibrate(self, store=DEFAULT_MEASUREMENTS_DIR):
        """Fit a `Calibrator` from every execution recorded for this plan
        and keep it on `self.calibration` (replan() uses it).

        Raises ValueError when nothing was recorded yet — call
        `record()` (ideally ≥2 runs) first.
        """
        from repro.measure import Calibrator
        records = self._store(store).load(self.key)
        if not records:
            raise ValueError(
                f"no recorded executions for plan {self.key}; call "
                f"record() first (>= 2 runs give a stable fit)")
        self.calibration = Calibrator.fit(records)
        return self.calibration

    def replan(self, calibrator=None, *, store=DEFAULT_MEASUREMENTS_DIR,
               cache: Union[PlanCache, str, Path] = DEFAULT_CACHE_DIR):
        """Re-plan with calibrated predictors; returns
        (new CompiledNetwork, PlanDiff).

        Uses `calibrator`, falling back to `self.calibration`, falling
        back to `recalibrate(store)`.  The new plan lands in the plan
        cache under a new provenance digest (calibration version folded
        in); the old entry is untouched.
        """
        if self.mode != MODE_PREDICTED or self.predictors is None:
            raise ValueError(
                "replan() needs the (cpu, gpu) predictors of a "
                "mode='predicted' compile; grid plans are "
                "measurement-driven and artifacts carry no predictors")
        cal = calibrator or self.calibration or self.recalibrate(store)
        if not isinstance(cache, PlanCache):
            cache = PlanCache(Path(cache))
        from repro.measure.replan import replan as _replan
        cpu_pred, gpu_pred = self.predictors
        hits_before = cache.hits
        new_plan, diff = _replan(self.plan, cpu_pred, gpu_pred, cal,
                                 cache=cache)
        compiled = CompiledNetwork(plan=new_plan, target=self.target,
                                   mode=self.mode,
                                   from_cache=cache.hits > hits_before,
                                   predictors=self.predictors)
        compiled.calibration = cal
        return compiled, diff

    # ------------------------------------------------------------ explain
    def explain(self) -> str:
        """Per-op decision table: what the planner chose and why it costs
        what it costs (pure plan introspection, no execution)."""
        prov = self.provenance
        tune_tag = (f" tune={prov.tune}"
                    if getattr(prov, "tune", "") else "")
        lines = [
            f"CompiledNetwork [{self.mode}] device={prov.device} "
            f"cpu{prov.threads} mechanism={prov.mechanism} "
            f"step={prov.step} planner={prov.planner}{tune_tag}",
            f"  key={self.key}  fingerprint={prov.network_fingerprint}",
            f"  {'node':>12}  {'seg':>3}  {'label':<42} "
            f"{'cpu':>5}/{'gpu':<5} {'pred_us':>9}  placement",
        ]
        n_co = 0
        for spec in self.plan.exec_specs():
            label = spec_label(spec)     # same renderer as execute --per-op
            tag = spec.node_id
            seg = f"{spec.segment}" if spec.segment >= 0 else "-"
            if spec.unit in ("pool", "add"):
                lines.append(f"  {tag:>12}  {seg:>3}  {label:<42} "
                             f"{'-':>5}/{'-':<5} {'-':>9}  gpu (no sync)")
                continue
            c_cpu, c_gpu = spec.c_slow, spec.c_fast
            mode_tag = ""
            if spec.unit in ("attention", "ssm") and spec.op is not None \
                    and getattr(spec.op, "mode", ""):
                mode_tag = f", mode={spec.op.mode}"
            if spec.coexec:
                n_co += 1
                if spec.axis != "channel":
                    from repro.kernels.registry import axis_spec
                    size = axis_spec(spec.unit, spec.axis).size(spec.op)
                    placement = (f"coexec {spec.axis}-split "
                                 f"{c_gpu}/{size}{mode_tag}")
                else:
                    placement = "co-executed"
            elif spec.unit in ("attention", "ssm"):
                if c_gpu == 0 and c_cpu == 0:
                    placement = "gpu-only (unsplit kind)"   # legacy plan
                elif c_gpu:
                    placement = f"gpu-only{mode_tag}"
                else:
                    placement = f"cpu-only{mode_tag}"
            elif c_gpu:
                placement = "gpu-only"
            else:
                placement = "cpu-only"
            lines.append(f"  {tag:>12}  {seg:>3}  {label:<42} {c_cpu:>5}/"
                         f"{c_gpu:<5} {spec.pred_total_us:>9.1f}  "
                         f"{placement}")
        n_ops = sum(1 for e in self.plan.schedule
                    if e["unit"] not in ("pool", "add"))
        parts = self.plan.segment_partition()
        n_fused = sum(1 for s in parts if s.kind == "fused")
        tail = (f"  {n_co}/{n_ops} ops co-executed | "
                f"{len(parts)} segments ({n_fused} fused)")
        if self.plan.end_to_end_us is not None:
            speedup = self.plan.baseline_us / self.plan.end_to_end_us
            tail += (f" | baseline {self.plan.baseline_us / 1e3:.1f} ms -> "
                     f"end-to-end {self.plan.end_to_end_us / 1e3:.1f} ms "
                     f"({speedup:.2f}x)")
        lines.append(tail)
        from repro.analysis import errors as diag_errors, verify_plan
        diags = verify_plan(self.plan, stats=False)
        errs = diag_errors(diags)
        if errs:
            lines.append(f"  verify: {len(errs)} error(s) — {errs[0]}")
        else:
            warns = sum(1 for d in diags if d.severity == "warning")
            lines.append("  verify: clean"
                         + (f" ({warns} warnings)" if warns else ""))
        return "\n".join(lines)

    # ------------------------------------------------------------- codecs
    def to_json(self) -> Dict[str, Any]:
        doc = {"format": ARTIFACT_FORMAT, "version": ARTIFACT_VERSION,
               "mode": self.mode, "target": self.target.to_json(),
               "plan": self.plan.to_json()}
        doc["checksum"] = _artifact_checksum(doc)
        return doc

    @staticmethod
    def from_json(doc: Dict[str, Any], *,
                  verify: bool = True) -> "CompiledNetwork":
        if doc.get("format") != ARTIFACT_FORMAT:
            raise ValueError(f"not a {ARTIFACT_FORMAT} artifact "
                             f"(format={doc.get('format')!r})")
        if doc.get("version") != ARTIFACT_VERSION:
            raise ValueError(f"unsupported artifact version "
                             f"{doc.get('version')!r}")
        if doc.get("checksum") != _artifact_checksum(doc):
            raise ValueError("artifact checksum mismatch: the file was "
                             "modified after it was saved")
        return CompiledNetwork(plan=CoexecPlan.from_json(doc["plan"],
                                                         verify=verify),
                               target=Target.from_json(doc["target"]),
                               mode=doc["mode"])

    def save(self, path: Union[str, Path]) -> Path:
        """Write the shippable artifact (target + plan + checksum) as
        JSON; `CompiledNetwork.load` round-trips it exactly."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path

    @staticmethod
    def load(path: Union[str, Path], *,
             verify: bool = True) -> "CompiledNetwork":
        """Load a saved artifact.  ``verify=True`` (default) statically
        verifies the embedded plan (`repro.analysis`) and raises
        `VerificationError` on error diagnostics; pass ``verify=False``
        to inspect a quarantined artifact anyway."""
        return CompiledNetwork.from_json(json.loads(Path(path).read_text()),
                                         verify=verify)


# ---------------------------------------------------------- plan portfolio

PORTFOLIO_FORMAT = "repro.plan_portfolio"
PORTFOLIO_VERSION = 1

#: default (batch, seq) buckets for `compile_portfolio`
DEFAULT_BUCKETS = ((1, 64), (4, 64), (4, 256))


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One (batch, seq) serving shape a portfolio holds a plan for.

    Ordering is lexicographic (batch, then seq) — `select` relies on it
    to pick the *smallest* bucket that covers a step."""

    batch: int
    seq: int

    @property
    def tag(self) -> str:
        """The provenance tag folded into the plan digest."""
        return f"b{self.batch}s{self.seq}"

    def covers(self, batch: int, seq: int) -> bool:
        return self.batch >= batch and self.seq >= seq


class PlanPortfolio:
    """One compiled plan per (batch, seq) bucket — the serving scheduler's
    plan source.

    `select(batch, seq)` returns the smallest bucket that covers the
    step's live shape (falling back to the largest bucket when nothing
    covers it) together with its `CompiledNetwork`; the compiled
    network's memoized executor makes repeated selections free.
    `replace()` swaps one bucket's entry in place — the drift-triggered
    replan path.  Serializes like `CompiledNetwork` (one checksummed
    JSON document embedding every entry); loaded portfolios carry no
    predictors, so they can serve but not replan.
    """

    def __init__(self, model: str, target: Target,
                 entries: Dict[Bucket, "CompiledNetwork"], *,
                 mode: str = MODE_PREDICTED):
        if not entries:
            raise ValueError("a portfolio needs at least one bucket")
        self.model = model
        self.target = target
        self.mode = mode
        self.entries = dict(sorted(entries.items()))

    @property
    def buckets(self) -> List[Bucket]:
        return list(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        tags = ",".join(b.tag for b in self.buckets)
        return (f"PlanPortfolio(model={self.model!r}, "
                f"device={self.target.device!r}, buckets=[{tags}])")

    def select(self, batch: int, seq: int
               ) -> Tuple[Bucket, "CompiledNetwork"]:
        """Smallest bucket covering (batch, seq); the largest bucket when
        none covers (an oversized step is served by the biggest plan
        rather than refused)."""
        for b in self.buckets:                   # sorted ascending
            if b.covers(batch, seq):
                return b, self.entries[b]
        b = self.buckets[-1]
        return b, self.entries[b]

    def replace(self, bucket: Bucket,
                compiled: "CompiledNetwork") -> None:
        """Swap one bucket's compiled plan in place (post-replan)."""
        if bucket not in self.entries:
            raise KeyError(f"unknown bucket {bucket.tag}")
        self.entries[bucket] = compiled

    def can_replan(self) -> bool:
        """Whether entries carry predictors (in-process compiles do;
        artifacts loaded from disk do not)."""
        return all(c.predictors is not None for c in self.entries.values())

    # ------------------------------------------------------------- codecs
    def to_json(self) -> Dict[str, Any]:
        doc = {"format": PORTFOLIO_FORMAT, "version": PORTFOLIO_VERSION,
               "model": self.model, "mode": self.mode,
               "target": self.target.to_json(),
               "entries": [{"batch": b.batch, "seq": b.seq,
                            "artifact": c.to_json()}
                           for b, c in self.entries.items()]}
        doc["checksum"] = _portfolio_checksum(doc)
        return doc

    @staticmethod
    def from_json(doc: Dict[str, Any], *,
                  verify: bool = True) -> "PlanPortfolio":
        if doc.get("format") != PORTFOLIO_FORMAT:
            raise ValueError(f"not a {PORTFOLIO_FORMAT} artifact "
                             f"(format={doc.get('format')!r})")
        if doc.get("version") != PORTFOLIO_VERSION:
            raise ValueError(f"unsupported portfolio version "
                             f"{doc.get('version')!r}")
        if doc.get("checksum") != _portfolio_checksum(doc):
            raise ValueError("portfolio checksum mismatch: the file was "
                             "modified after it was saved")
        entries = {
            Bucket(e["batch"], e["seq"]):
                CompiledNetwork.from_json(e["artifact"], verify=verify)
            for e in doc["entries"]}
        return PlanPortfolio(model=doc["model"],
                             target=Target.from_json(doc["target"]),
                             entries=entries, mode=doc["mode"])

    def save(self, path: Union[str, Path]) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1))
        return path

    @staticmethod
    def load(path: Union[str, Path], *,
             verify: bool = True) -> "PlanPortfolio":
        """Load a saved portfolio; ``verify=False`` skips the static
        verification of every embedded plan."""
        return PlanPortfolio.from_json(json.loads(Path(path).read_text()),
                                       verify=verify)


def _portfolio_checksum(doc: Dict[str, Any]) -> str:
    body = {k: doc.get(k) for k in ("format", "version", "model", "mode",
                                    "target", "entries")}
    blob = json.dumps(body, sort_keys=True, separators=(",", ":"))
    return hashlib.blake2b(blob.encode(), digest_size=16).hexdigest()


def compile_portfolio(model, target: Target, *,
                      buckets: Sequence[Tuple[int, int]] = DEFAULT_BUCKETS,
                      blocks: int = 1,
                      cache: Union[PlanCache, str, Path] = DEFAULT_CACHE_DIR,
                      predictors=None,
                      samples: int = 400, estimators: int = 60,
                      predictor_cache: Optional[Union[str, Path]] = None
                      ) -> PlanPortfolio:
    """Compile one `CoexecPlan` per (batch, seq) bucket of a model graph.

    `model` is a model-graph name or `ModelConfig` (`tiny_decoder`,
    "gemma3-12b", ... — legacy unit networks have no batch/seq knobs).
    Each bucket lowers through `graph.from_model(model, blocks=blocks,
    cache_len=seq, batch=batch)` and compiles through the ordinary cached
    path with the bucket tag folded into provenance — recompiling the
    same portfolio in another process is all warm cache hits.  The
    predictor pair is trained (or loaded) once and shared across buckets.
    """
    from repro.graph.frontends import from_model, resolve_config
    cfg = resolve_config(model)
    seen = set()
    parsed: List[Bucket] = []
    for batch, seq in buckets:
        b = Bucket(int(batch), int(seq))
        if b.batch < 1 or b.seq < 1:
            raise ValueError(f"bucket {b.tag}: batch and seq must be >= 1")
        if b in seen:
            raise ValueError(f"duplicate bucket {b.tag}")
        seen.add(b)
        parsed.append(b)
    if predictors is None:
        kinds: Tuple[str, ...] = ("linear", "conv")
        probe = from_model(cfg, blocks=blocks, cache_len=parsed[0].seq,
                           batch=parsed[0].batch)
        kinds += tuple(sorted({n.kind for n in probe if n.op is not None
                               and n.kind in ("attention", "ssm")}))
        predictors = _trained_mux_predictors(
            target.device, target.threads, samples=samples,
            estimators=estimators, cache_dir=predictor_cache, kinds=kinds)
    entries = {}
    for b in parsed:
        graph = from_model(cfg, blocks=blocks, cache_len=b.seq,
                           batch=b.batch)
        entries[b] = compile(graph, target, mode=MODE_PREDICTED,
                             cache=cache, predictors=predictors,
                             bucket=b.tag)
    return PlanPortfolio(model=cfg.name, target=target, entries=entries)


# ------------------------------------------------------------- deprecation

#: entry points that already warned this process (one warning per spelling)
_DEPRECATED_SEEN: set = set()


def _warn_once(old: str, new: str) -> None:
    """Emit a DeprecationWarning for `old` exactly once per process."""
    if old in _DEPRECATED_SEEN:
        return
    _DEPRECATED_SEEN.add(old)
    warnings.warn(f"{old} is deprecated; use {new} instead",
                  DeprecationWarning, stacklevel=3)


def optimal_partition(op: Op, cpu_pred, gpu_pred, *,
                      mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                      step: int = 8):
    """Deprecated single-op wrapper; use `repro.compile([op], target)`."""
    _warn_once("repro.api.optimal_partition",
               "repro.compile([op], Target(...), mode='predicted')")
    from repro.core.partitioner import optimal_partition as _impl
    return _impl(op, cpu_pred, gpu_pred, mechanism=mechanism, step=step)


def grid_search_partition(op: Op, device: str, threads: int, *,
                          mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                          step: int = 8, seed: int = 0):
    """Deprecated single-op wrapper; use `repro.compile([op], target,
    mode='grid')`."""
    _warn_once("repro.api.grid_search_partition",
               "repro.compile([op], Target(...), mode='grid')")
    from repro.core.partitioner import grid_search_partition as _impl
    return _impl(op, device, threads, mechanism=mechanism, step=step,
                 seed=seed)
