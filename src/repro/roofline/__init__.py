from repro.roofline.analysis import (HBM_BW, ICI_BW, PEAK_FLOPS,
                                     RooflineReport, build_report,
                                     collective_bytes_per_device,
                                     model_flops_estimate)
__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "RooflineReport",
           "build_report", "collective_bytes_per_device",
           "model_flops_estimate"]
