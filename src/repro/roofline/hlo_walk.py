"""Structural HLO cost analysis with correct while-loop accounting.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, but every
layer stack and flash-attention chunk loop in this framework is a lax.scan
— so raw cost_analysis under-reports FLOPs by ~n_layers x.  This walker
parses the post-SPMD HLO text, builds a per-computation symbol table, and
accumulates

    * dot FLOPs          2 * prod(out_dims) * prod(contracting dims)
    * HBM byte traffic   operand + output bytes of materializing ops
    * collective operand bytes (per collective kind)

recursively through `while` ops using their `known_trip_count` backend
config (emitted by XLA for counted loops; unknown trips fall back to 1 and
are reported).  All numbers are per-device (the HLO is the partitioned
per-device program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# ops that materialize HBM traffic on TPU (elementwise chains get fused)
_TRAFFIC_OPS = frozenset({
    "dot", "dot_general", "convolution", "fusion", "custom-call",
    "dynamic-slice", "dynamic-update-slice", "gather", "scatter",
    "reduce", "sort", "copy", "concatenate", "pad", "slice",
    "rng-bit-generator",
})

_SHAPE_RE = re.compile(r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32"
                       r"|s64|u64|f64|c64|c128|token)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->\s+.*\{")
_OP_RE = re.compile(r"^(\(.*?\)|\S+)\s+([\w\-]+)(?:\.\d+)?\(")


def _dims(dims_str: str) -> List[int]:
    return [int(d) for d in dims_str.split(",") if d] if dims_str else []


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    return (m.group(1), _dims(m.group(2))) if m else None


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        n = 1
        for d in _dims(m.group(2)):
            n *= d
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes_: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    unknown_while: int = 0

    def add(self, other: "CompCost", times: float = 1.0):
        self.flops += times * other.flops
        self.bytes_ += times * other.bytes_
        for k in self.coll:
            self.coll[k] += times * other.coll[k]
        self.unknown_while += other.unknown_while


class HloCostWalker:
    def __init__(self, hlo_text: str):
        self.computations = self._split(hlo_text)
        self._memo: Dict[str, CompCost] = {}
        self.entry = next((name for name, (is_entry, _) in
                           self.computations.items() if is_entry), None)

    # ------------------------------------------------------------ parsing
    @staticmethod
    def _split(text: str) -> Dict[str, Tuple[bool, List[str]]]:
        comps: Dict[str, Tuple[bool, List[str]]] = {}
        cur: Optional[str] = None
        lines: List[str] = []
        header = None
        for raw in text.splitlines():
            line = raw.rstrip()
            m = _HEADER_RE.match(line.strip())
            if m and not line.startswith(" "):
                cur = m.group(2)
                header = line.strip()
                lines = [header]
                comps[cur] = (bool(m.group(1)), lines)
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                lines.append(line.strip())
        return comps

    @staticmethod
    def _symbols(lines: List[str]) -> Dict[str, str]:
        """name -> type string (for operand shape lookup)."""
        syms: Dict[str, str] = {}
        header = lines[0]
        m = _HEADER_RE.match(header)
        if m:
            for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,()]+(?:\[[\d,]*\]"
                                  r"(?:\{[^}]*\})?)?)", m.group(3)):
                syms[pm.group(1)] = pm.group(2)
        for line in lines[1:]:
            dm = _DEF_RE.match(line)
            if dm:
                # store only the instruction's RESULT type: the raw rhs also
                # embeds the operand shapes inside op(...), which would make
                # operand-byte lookups count an operand's own operands
                om = _OP_RE.match(dm.group(2))
                syms[dm.group(1)] = om.group(1) if om else dm.group(2)
        return syms

    # ------------------------------------------------------------ costing
    def cost(self, comp_name: Optional[str] = None) -> CompCost:
        name = comp_name or self.entry
        if name is None or name not in self.computations:
            return CompCost()
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = CompCost()          # cycle guard
        _, lines = self.computations[name]
        syms = self._symbols(lines)
        total = CompCost()

        for line in lines[1:]:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            rhs = dm.group(2)
            om = _OP_RE.match(rhs)
            if not om:
                continue
            out_type, op = om.group(1), om.group(2)
            op_base = re.sub(r"-(start|done)$", "", op)

            if op_base in _COLLECTIVES:
                ops_bytes = self._operand_bytes(rhs, syms)
                total.coll[op_base] += ops_bytes
                total.bytes_ += ops_bytes + _type_bytes(out_type)
                continue
            if op == "while":
                body = re.search(r"body=%?([\w.\-]+)", rhs)
                trips = re.search(r'known_trip_count[^0-9]*(\d+)', rhs)
                n = int(trips.group(1)) if trips else 1
                if not trips:
                    total.unknown_while += 1
                if body:
                    total.add(self.cost(body.group(1)), times=n)
                continue
            if op == "conditional":
                for bm in re.finditer(
                        r"(?:branch_computations=\{([^}]*)\}"
                        r"|true_computation=%?([\w.\-]+)"
                        r"|false_computation=%?([\w.\-]+))", rhs):
                    names = (bm.group(1) or "").split(",") \
                        + [bm.group(2), bm.group(3)]
                    for nm in names:
                        if nm:
                            total.add(self.cost(nm.strip().lstrip("%")),
                                      times=1.0)
                continue
            if op in ("dot", "dot_general"):
                total.flops += self._dot_flops(rhs, out_type, syms)
            if op == "fusion":
                callee = re.search(r"calls=%?([\w.\-]+)", rhs)
                if callee:
                    inner = self.cost(callee.group(1))
                    total.flops += inner.flops    # dots inside fusions
            # HBM traffic proxy: only ops a TPU would materialize through
            # HBM.  The CPU backend barely fuses, so counting every
            # elementwise op would overstate TPU traffic by ~30x; dots,
            # data movement, reductions and fusion boundaries are the
            # honest proxy.
            if op_base in _TRAFFIC_OPS:
                total.bytes_ += (self._operand_bytes(rhs, syms)
                                 + _type_bytes(out_type))

        self._memo[name] = total
        return total

    def _operand_bytes(self, rhs: str, syms: Dict[str, str]) -> int:
        am = re.search(r"\((.*)\)", rhs)
        if not am:
            return 0
        total = 0
        for name in re.findall(r"%([\w.\-]+)", am.group(1).split("),")[0]):
            if name in syms:
                total += _type_bytes(syms[name])
        return total

    def _dot_flops(self, rhs: str, out_type: str,
                   syms: Dict[str, str]) -> float:
        out = _first_shape(out_type)
        if out is None:
            return 0.0
        out_elems = 1
        for d in out[1]:
            out_elems *= d
        # contracting dims of lhs
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
        am = re.search(r"\((.*)\)", rhs)
        contract = 1
        if cm and am:
            lhs_name_m = re.search(r"%([\w.\-]+)", am.group(1))
            if lhs_name_m and lhs_name_m.group(1) in syms:
                lhs = _first_shape(syms[lhs_name_m.group(1)])
                if lhs:
                    for idx in _dims(cm.group(1)):
                        if idx < len(lhs[1]):
                            contract *= lhs[1][idx]
        return 2.0 * out_elems * contract


def analyze_hlo(hlo_text: str) -> CompCost:
    return HloCostWalker(hlo_text).cost()
