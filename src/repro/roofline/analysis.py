"""Roofline-term extraction from lowered/compiled XLA artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs / (chips * 197e12)
    memory     = HLO_bytes / (chips * 819e9)
    collective = collective_bytes / (chips * 50e9)

`compiled.cost_analysis()` reports the *per-device* program, so FLOPs/bytes
are multiplied by the device count to get cluster totals (verified in
tests/test_roofline.py).  collective_bytes is parsed from the post-SPMD HLO
text: the summed operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instructions (per device), times devices.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64"
                       r"|u64|f64|c64|c128)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes_per_device(hlo_text: str) -> Dict[str, int]:
    """Sum of operand bytes per collective kind, from one device's HLO.

    Delegates to the structural walker (roofline/hlo_walk.py), which
    resolves operand shapes through a per-computation symbol table and
    multiplies loop bodies by their known trip counts."""
    from repro.roofline.hlo_walk import analyze_hlo
    return {k: int(v) for k, v in analyze_hlo(hlo_text).coll.items()}


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # cluster total
    hlo_bytes: float              # cluster total
    collective_bytes: float       # cluster total
    collective_breakdown: Dict[str, int]
    model_flops: float            # 6*N*D (or 6*N_active*D)
    peak_memory_bytes: float      # per device, from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_breakdown": self.collective_breakdown,
            "model_flops": self.model_flops,
            "peak_memory_bytes": self.peak_memory_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training; 2*N*D forward-only (prefill);
    2*N*1 token for decode.  MoE uses active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch          # one token per sequence


def build_report(arch: str, shape, mesh_name: str, chips: int,
                 cost: dict, mem_analysis, hlo_text: str,
                 cfg) -> RooflineReport:
    # Structural walk with while-trip accounting (raw cost_analysis counts
    # loop bodies once — see roofline/hlo_walk.py and tests/test_roofline).
    from repro.roofline.hlo_walk import analyze_hlo
    walked = analyze_hlo(hlo_text)
    peak = getattr(mem_analysis, "temp_size_in_bytes", 0) + \
        getattr(mem_analysis, "argument_size_in_bytes", 0) + \
        getattr(mem_analysis, "output_size_in_bytes", 0)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=walked.flops * chips, hlo_bytes=walked.bytes_ * chips,
        collective_bytes=float(sum(walked.coll.values())) * chips,
        collective_breakdown={k: int(v) for k, v in walked.coll.items()},
        model_flops=model_flops_estimate(cfg, shape),
        peak_memory_bytes=float(peak),
    )
