"""Fine-grained CPU-GPU co-execution, reproduced — public API.

The supported front door is the compile→run facade (see api.py):

    import repro
    compiled = repro.compile("resnet18", repro.Target(device="moto2022"))
    y = compiled.run()

plus the unified CLI, `python -m repro {plan,execute,bench,serve}`.

Exports resolve lazily (PEP 562): `import repro` never imports jax, the
planners, or the simulator — subsystem packages (`repro.core`,
`repro.runtime`, `repro.kernels`, `repro.serving`, ...) keep working as
direct imports exactly as before.
"""
import importlib

__version__ = "0.1.0"

_EXPORTS = {
    "Target": "repro.api",
    "CompiledNetwork": "repro.api",
    "available_networks": "repro.api",
    "compile": "repro.api",
    "MODE_PREDICTED": "repro.api",
    "MODE_GRID": "repro.api",
    "Bucket": "repro.api",
    "PlanPortfolio": "repro.api",
    "compile_portfolio": "repro.api",
    "optimal_partition": "repro.api",        # deprecated shim (warns once)
    "grid_search_partition": "repro.api",    # deprecated shim (warns once)
}

__all__ = sorted(_EXPORTS) + ["__version__"]


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
