"""Measured Pallas tile-config search, cached on disk.

The planner prices *which side runs how much* of an op; this module picks
*how the kernel blocks* what it runs.  `autotune(op)` measures every legal
candidate in the kind's registry `TileSpec` grid (see
`registry.TileSpec.configs`) against the op's actual kernel lowering and
returns the fastest — by default searching only the numerics-preserving
grid, whose candidates vary how the output space is tiled but keep every
reduction-axis block at its default, so the winner computes bit-identical
fp32 results to the default config.  `preserve_numerics=False` additionally
searches reduction-axis blocks (bk / bs / chunk); those candidates are
tolerance-exact, not bit-identical, and are never selected unless asked.

Results persist in a content-addressed `TuneCache` with the same digest
discipline as `runtime.cache.PlanCache`: the key digests the op codec, the
measuring device and backend, the kernel blocking version
(`registry.KERNEL_TILE_VERSION`), and the search mode, so a kernel rewrite
or a different host invalidates stale choices.  Corrupt or mismatched
entries are treated as misses and overwritten, never trusted.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

from repro.core.types import Op
from repro.kernels import registry

TUNE_SCHEMA_VERSION = 1

#: default on-disk location, next to the plan cache's reports layout
DEFAULT_TUNE_DIR = Path("reports/tune")

#: a candidate must beat the default by this fraction to dethrone it —
#: keeps measurement noise from churning the cached choice run to run
TUNE_HYSTERESIS = 0.02


def tune_cache_version() -> str:
    """The tune-cache format/kernels version folded into
    `PlanProvenance.tune` when a plan is compiled with tuning enabled —
    bumping either constant invalidates every tuned plan."""
    return f"tune-v{TUNE_SCHEMA_VERSION}.k{registry.KERNEL_TILE_VERSION}"


def measure_device() -> Tuple[str, str]:
    """(device_kind, backend) identity of the host actually measured."""
    import jax
    dev = jax.devices()[0]
    return (getattr(dev, "device_kind", dev.platform), jax.default_backend())


@dataclasses.dataclass(frozen=True)
class TuneKey:
    """Everything a cached tile choice's validity depends on."""

    op_json: Tuple[Tuple[str, Any], ...]
    device: str
    backend: str
    kernel_version: int = registry.KERNEL_TILE_VERSION
    schema_version: int = TUNE_SCHEMA_VERSION
    preserve_numerics: bool = True

    @staticmethod
    def for_op(op: Op, device: str, backend: str, *,
               preserve_numerics: bool = True) -> "TuneKey":
        return TuneKey(op_json=tuple(sorted(registry.op_to_json(op).items())),
                       device=device, backend=backend,
                       preserve_numerics=preserve_numerics)

    def _canonical(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["op_json"] = dict(self.op_json)
        return d

    @property
    def key(self) -> str:
        blob = json.dumps(self._canonical(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.blake2b(blob.encode(), digest_size=16).hexdigest()


class TuneCache:
    """On-disk cache of measured tile choices — one JSON file per TuneKey
    digest.  `hits`/`misses` count lookups since construction (tests
    assert on them, mirroring PlanCache)."""

    def __init__(self, root: Path = DEFAULT_TUNE_DIR):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def path_for(self, key: TuneKey) -> Path:
        return self.root / f"{key.key}.json"

    def get(self, key: TuneKey) -> Optional[registry.TileConfig]:
        from repro.analysis import rejections
        path = self.path_for(key)
        if path.exists():
            try:
                doc = json.loads(path.read_text())
                want = key._canonical()
                got = doc.get("key")
                if got == want:
                    kind = dict(key.op_json)["kind"]
                    tile = registry.tile_from_json(kind, doc["tile"])
                    self.hits += 1
                    return tile
                fields = sorted(set(want) | set(got or {})) \
                    if isinstance(got, dict) else []
                stale = [f for f in fields
                         if (got or {}).get(f) != want.get(f)]
                rejections.record(path.stem, "provenance.mismatch",
                                  f"stale tune key fields: {stale}")
            except (ValueError, KeyError, TypeError) as e:
                # corrupt entry: treat as a miss, but say which field/rule
                rejections.record(path.stem, "tile.legality"
                                  if "tile" in str(e).lower()
                                  else "schema.malformed", str(e))
        self.misses += 1
        return None

    def put(self, key: TuneKey, tile: registry.TileConfig,
            measured: List[Tuple[str, float]]) -> Path:
        path = self.path_for(key)
        path.parent.mkdir(parents=True, exist_ok=True)
        doc = {"schema_version": TUNE_SCHEMA_VERSION,
               "key": key._canonical(),
               "tile": registry.tile_to_json(tile),
               "measured_us": [[label, round(us, 3)]
                               for label, us in measured]}
        path.write_text(json.dumps(doc, indent=1))
        return path

    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json"))


def _op_arrays(op: Op, seed: int = 0):
    """Representative (x, w) inputs for measuring one op's kernel."""
    import jax.numpy as jnp
    import numpy as np
    entry = registry.entry_for(op)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(
        entry.input_shape(op)).astype(np.float32))
    if registry.op_kind(op) == "conv":
        x = x[None]                        # lowering expects a batch dim
    w = jnp.asarray(entry.init_weight(op, rng))
    return x, w


def measure_tile_us(op: Op, tile: Optional[registry.TileConfig], *,
                    reps: int = 2, interpret: bool = True,
                    seed: int = 0) -> float:
    """Median wall (us) of the op's Pallas lowering under one config.

    ``tile=None`` measures the default blocking.  The first call warms the
    jit cache (tile params are static), so the timed reps measure steady-
    state execution only.
    """
    x, w = _op_arrays(op, seed=seed)
    low = registry.get_lowering(registry.op_kind(op))

    def run():
        y = low.pallas(x, w, op, interpret=interpret, tile=tile)
        try:
            return y.block_until_ready()
        except AttributeError:              # tuple outputs
            import jax
            return jax.block_until_ready(y)

    run()                                   # compile + warm
    walls = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        run()
        walls.append((time.perf_counter() - t0) * 1e6)
    walls.sort()
    return walls[len(walls) // 2]


def autotune(op: Op, candidates: Optional[List[registry.TileConfig]] = None,
             *, cache: Optional[TuneCache] = None,
             device: str = "", backend: str = "",
             preserve_numerics: bool = True, reps: int = 2,
             interpret: bool = True, seed: int = 0
             ) -> registry.TileConfig:
    """Measured search over an op's legal tile-config grid.

    Returns the winning `TileConfig` (the clamped default when nothing
    beats it by `TUNE_HYSTERESIS`).  With a `cache`, a prior choice for
    the same (op, device, backend, kernel version, search mode) is
    returned without measuring anything; a cold search stores its result
    plus the per-candidate timings.
    """
    kind = registry.op_kind(op)
    spec = registry.tile_spec(kind)
    if not device or not backend:
        mdev, mback = measure_device()
        device = device or mdev
        backend = backend or mback
    key = TuneKey.for_op(op, device, backend,
                         preserve_numerics=preserve_numerics)
    if cache is not None and candidates is None:
        hit = cache.get(key)
        if hit is not None:
            return hit
    if candidates is None:
        candidates = spec.configs(op, preserve_numerics=preserve_numerics)
    default = spec.default_config(op)

    measured: List[Tuple[str, float]] = []
    best, best_us, default_us = default, None, None
    for cfg in candidates:
        us = measure_tile_us(op, cfg, reps=reps, interpret=interpret,
                             seed=seed)
        measured.append((cfg.label(), us))
        if cfg == default:
            default_us = us
        if best_us is None or us < best_us:
            best, best_us = cfg, us
    if default_us is None:                  # default outside the grid
        default_us = measure_tile_us(op, default, reps=reps,
                                     interpret=interpret, seed=seed)
        measured.append((default.label(), default_us))
    # hysteresis: stay on the default unless the winner clearly beats it
    if best != default and best_us > default_us * (1.0 - TUNE_HYSTERESIS):
        best = default
    if cache is not None:
        cache.put(key, best, measured)
    return best


def annotate_plan_tiles(plan, *, cache: Optional[TuneCache] = None,
                        device: str = "", backend: str = "",
                        preserve_numerics: bool = True, reps: int = 2,
                        interpret: bool = True):
    """Attach autotuned tile configs to a plan's decisions, in place.

    The tune pass `compile(..., tune=True)` runs on a plan-cache miss
    (see `runtime.cache.plan_graph_cached`'s `annotate` hook): every
    unique op is tuned once, and a decision gains a `tile` only when the
    winner differs from the default blocking — a plan whose ops all tune
    to their defaults serializes byte-identically to an untuned one
    (modulo the provenance `tune` tag).
    """
    from repro.runtime.plan import decision_from_json, decision_to_json
    if not device or not backend:
        mdev, mback = measure_device()
        device = device or mdev
        backend = backend or mback
    chosen: Dict[Any, Optional[registry.TileConfig]] = {}
    for entry in plan.schedule:
        dec_json = entry.get("decision")
        if not dec_json:
            continue
        dec = decision_from_json(dec_json)
        op = dec.op
        if op not in chosen:
            spec = registry.tile_spec(registry.op_kind(op))
            best = autotune(op, cache=cache, device=device, backend=backend,
                            preserve_numerics=preserve_numerics, reps=reps,
                            interpret=interpret)
            chosen[op] = None if best == spec.default_config(op) else best
        if chosen[op] is not None:
            entry["decision"] = decision_to_json(
                dataclasses.replace(dec, tile=chosen[op]))
    return plan
