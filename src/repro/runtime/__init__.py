"""Compiled co-execution plans: plan once, serve many times.

The paper's partitioner runs offline as part of model compilation; this
package is the artifact layer that makes that real — `CoexecPlan` (the
serialized schedule + provenance), `PlanCache` (on-disk persistence), and
cached planning entry points that skip all predictor/simulator work on a
warm hit — plus the execution runtime that lowers a plan into actual
split computation: `PlanExecutor` (executor.py) runs every decision on the
co-execution mesh with gather-elided chaining and reports per-op
executed-vs-predicted fidelity.  CLIs: `python -m repro.runtime.plan`,
`python -m repro.runtime.executor`.

Exports resolve lazily (PEP 562) so `python -m repro.runtime.plan` does not
pre-import the CLI module through the package and trip runpy's
double-import warning.
"""
import importlib

_EXPORTS = {
    "PlanCache": "repro.runtime.cache",
    "grid_partition_ops_cached": "repro.runtime.cache",
    "grid_plan_graph_cached": "repro.runtime.cache",
    "grid_plan_network_cached": "repro.runtime.cache",
    "partition_ops_cached": "repro.runtime.cache",
    "partition_ops_plan_cached": "repro.runtime.cache",
    "plan_graph_cached": "repro.runtime.cache",
    "plan_network_cached": "repro.runtime.cache",
    "PLAN_SCHEMA_VERSION": "repro.runtime.plan",
    "CoexecPlan": "repro.runtime.plan",
    "ExecSpec": "repro.runtime.plan",
    "PlanProvenance": "repro.runtime.plan",
    "build_graph_schedule": "repro.runtime.plan",
    "calibration_version": "repro.runtime.plan",
    "decision_from_json": "repro.runtime.plan",
    "decision_to_json": "repro.runtime.plan",
    "decision_to_spec": "repro.runtime.plan",
    "network_fingerprint": "repro.runtime.plan",
    "plan_from_graph_report": "repro.runtime.plan",
    "op_from_json": "repro.runtime.plan",
    "op_to_json": "repro.runtime.plan",
    "plan_from_report": "repro.runtime.plan",
    "predictor_checksum": "repro.runtime.plan",
    "train_mux_predictors": "repro.runtime.plan",
    "ExecutionReport": "repro.runtime.executor",
    "OpTiming": "repro.runtime.executor",
    "PlanExecutor": "repro.runtime.executor",
    "segments_json": "repro.runtime.plan",
    "SegmentProgram": "repro.runtime.segments",
    "compile_segments": "repro.runtime.segments",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
