"""Persistent plan cache: compile once, execute many.

`PlanCache` stores `CoexecPlan` JSON files under one directory, keyed by the
plan's provenance digest.  The cached planning entry points below check the
cache *before* touching the predictors or the simulator, so a warm hit
performs zero `LatencyPredictor.predict` and zero `measure_latency_us`
calls — repeated planning of the same (network, device, mechanism, threads,
predictors) tuple costs one JSON read.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Sequence

from repro.core.networks import Unit
from repro.core.partitioner import (PartitionDecision,
                                    grid_search_partition_batch,
                                    optimal_partition_batch)
from repro.core.planner import plan_network
from repro.core.sync import SyncMechanism
from repro.core.types import Op
from repro.runtime.plan import (PLANNER_GRID, PLANNER_PREDICTOR, CoexecPlan,
                                PlanProvenance, build_schedule,
                                network_fingerprint, plan_from_report,
                                predictor_checksum)


class PlanCache:
    """On-disk cache of compiled co-execution plans.

    One JSON file per provenance key; `hits`/`misses` count lookups since
    construction (tests assert on them).  Corrupt or mismatched files are
    treated as misses and overwritten, never trusted.
    """

    def __init__(self, root: Path):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def path_for(self, provenance: PlanProvenance) -> Path:
        return self.root / f"{provenance.key}.json"

    def get(self, provenance: PlanProvenance) -> Optional[CoexecPlan]:
        path = self.path_for(provenance)
        if path.exists():
            try:
                plan = CoexecPlan.load(path)
            except (ValueError, KeyError, TypeError):
                plan = None
            if plan is not None and plan.provenance == provenance:
                self.hits += 1
                return plan
        self.misses += 1
        return None

    def put(self, plan: CoexecPlan) -> Path:
        path = self.path_for(plan.provenance)
        plan.save(path)
        return path

    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json"))


def plan_network_cached(units: Sequence[Unit], cpu_pred, gpu_pred, *,
                        threads: int,
                        mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                        step: int = 8, seed: int = 1,
                        cache: PlanCache) -> CoexecPlan:
    """End-to-end network planning through the cache.

    Provenance (and therefore the cache key) covers the network graph, the
    target (device, threads), the sync mechanism, the candidate-grid step,
    the measurement seed, and a structural checksum of both predictors.
    """
    prov = PlanProvenance(
        device=gpu_pred.device, threads=threads, mechanism=mechanism.value,
        step=step, seed=seed,
        network_fingerprint=network_fingerprint(units),
        predictor_checksum=predictor_checksum(cpu_pred, gpu_pred),
        planner=PLANNER_PREDICTOR)
    hit = cache.get(prov)
    if hit is not None:
        return hit
    report = plan_network(units, cpu_pred, gpu_pred, threads=threads,
                          mechanism=mechanism, step=step, seed=seed)
    plan = plan_from_report(units, report, mechanism=mechanism, step=step,
                            seed=seed,
                            pred_checksum=prov.predictor_checksum)
    cache.put(plan)
    return plan


def _ops_as_units(ops: Sequence[Op]) -> List[Unit]:
    from repro.core.types import LinearOp
    return [("linear" if isinstance(op, LinearOp) else "conv", op)
            for op in ops]


def partition_ops_cached(ops: Sequence[Op], cpu_pred, gpu_pred, *,
                         mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                         step: int = 8,
                         cache: PlanCache) -> List[PartitionDecision]:
    """Predictor-driven partitioning of a bare op list through the cache
    (the Table 2 sweeps); decisions come back in op order."""
    units = _ops_as_units(ops)
    prov = PlanProvenance(
        device=gpu_pred.device, threads=0, mechanism=mechanism.value,
        step=step, seed=0, network_fingerprint=network_fingerprint(units),
        predictor_checksum=predictor_checksum(cpu_pred, gpu_pred),
        planner=PLANNER_PREDICTOR)
    hit = cache.get(prov)
    if hit is not None:
        return hit.decisions
    decisions = optimal_partition_batch(ops, cpu_pred, gpu_pred,
                                        mechanism=mechanism, step=step)
    cache.put(CoexecPlan(provenance=prov,
                         schedule=build_schedule(units, decisions)))
    return decisions


def grid_partition_ops_cached(ops: Sequence[Op], device: str, threads: int, *,
                              mechanism: SyncMechanism =
                              SyncMechanism.SVM_POLL,
                              step: int = 8, seed: int = 0,
                              cache: PlanCache) -> List[PartitionDecision]:
    """Measurement-driven (oracle) partitioning through the cache; keyed by
    planner="grid" with no predictor checksum (none is involved)."""
    units = _ops_as_units(ops)
    prov = PlanProvenance(
        device=device, threads=threads, mechanism=mechanism.value,
        step=step, seed=seed, network_fingerprint=network_fingerprint(units),
        predictor_checksum="", planner=PLANNER_GRID)
    hit = cache.get(prov)
    if hit is not None:
        return hit.decisions
    decisions = grid_search_partition_batch(ops, device, threads,
                                            mechanism=mechanism, step=step,
                                            seed=seed)
    cache.put(CoexecPlan(provenance=prov,
                         schedule=build_schedule(units, decisions)))
    return decisions
