"""Persistent plan cache: compile once, execute many.

`PlanCache` stores `CoexecPlan` JSON files under one directory, keyed by the
plan's provenance digest.  The cached planning entry points below check the
cache *before* touching the predictors or the simulator, so a warm hit
performs zero `LatencyPredictor.predict` and zero `measure_latency_us`
calls — repeated planning of the same (network, device, mechanism, threads,
predictors) tuple costs one JSON read.

`plan_graph_cached` / `grid_plan_graph_cached` are the graph-IR entry
points; the unit-list spellings (`plan_network_cached`,
`grid_plan_network_cached`) are thin lowering shims over them via
`graph.from_units` — provenance-identical to the pre-IR implementations
(chain graphs fingerprint to the legacy unit-list digest), so existing
on-disk caches stay warm across the representation change.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Sequence

from repro.core.networks import Unit
from repro.core.partitioner import PartitionDecision, optimal_partition_batch
from repro.core.planner import grid_plan_graph, plan_graph
from repro.core.sync import SyncMechanism
from repro.core.types import Op
from repro.graph.ir import Graph, from_units
from repro.runtime.plan import (PLANNER_GRID, PLANNER_PREDICTOR,
                                CoexecPlan, PlanProvenance, build_schedule,
                                calibration_version, network_fingerprint,
                                plan_from_graph_report, predictor_checksum)


class PlanCache:
    """On-disk cache of compiled co-execution plans.

    One JSON file per provenance key; `hits`/`misses` count lookups since
    construction (tests assert on them).  Corrupt or mismatched files are
    treated as misses and overwritten, never trusted.
    """

    def __init__(self, root: Path):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def path_for(self, provenance: PlanProvenance) -> Path:
        return self.root / f"{provenance.key}.json"

    def get(self, provenance: PlanProvenance) -> Optional[CoexecPlan]:
        from repro.analysis import VerificationError, rejections
        path = self.path_for(provenance)
        if path.exists():
            try:
                plan = CoexecPlan.load(path)
            except VerificationError as e:
                first = e.diagnostics[0]
                rejections.record(path.stem, first.rule, first.message)
                plan = None
            except (ValueError, KeyError, TypeError) as e:
                rejections.record(path.stem, "schema.malformed", str(e))
                plan = None
            if plan is not None:
                if plan.provenance == provenance:
                    self.hits += 1
                    return plan
                import dataclasses
                fields = [f.name for f in dataclasses.fields(provenance)
                          if getattr(plan.provenance, f.name, None) !=
                          getattr(provenance, f.name, None)]
                rejections.record(path.stem, "provenance.mismatch",
                                  f"stale fields: {fields}")
        self.misses += 1
        return None

    def put(self, plan: CoexecPlan) -> Path:
        path = self.path_for(plan.provenance)
        plan.save(path)
        return path

    def keys(self) -> List[str]:
        if not self.root.is_dir():
            return []
        return sorted(p.stem for p in self.root.glob("*.json"))


def plan_graph_cached(graph: Graph, cpu_pred, gpu_pred, *,
                      threads: int,
                      mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                      step: int = 8, seed: int = 1,
                      bucket: str = "",
                      tune: str = "", annotate=None,
                      cache: PlanCache) -> CoexecPlan:
    """End-to-end graph planning through the cache.

    Provenance (and therefore the cache key) covers the graph's
    content-addressed fingerprint, the target (device, threads), the sync
    mechanism, the candidate-grid step, the measurement seed, a structural
    checksum of both predictors, and — when the predictors are calibrated
    (`repro.measure.Calibrator.wrap`) — the calibration version, so refit
    calibrators never alias stale plans.  `bucket` tags the (batch, seq)
    serving bucket a portfolio entry was compiled for; it folds into the
    digest (omitted when empty, so unbucketed keys are unchanged) and lets
    portfolio compiles warm-hit across processes.  `tune` tags plans whose
    decisions carry autotuned tile configs (the tune-cache version, see
    `runtime.autotune.tune_cache_version`); it folds into the digest the
    same way, so tuned and untuned plans never alias, and `annotate` — a
    plan -> plan hook applied on a miss before the plan is stored — is
    where the tune pass attaches its tiles, so warm hits skip tuning
    entirely.
    """
    prov = PlanProvenance(
        device=gpu_pred.device, threads=threads, mechanism=mechanism.value,
        step=step, seed=seed,
        network_fingerprint=graph.fingerprint(),
        predictor_checksum=predictor_checksum(cpu_pred, gpu_pred),
        planner=PLANNER_PREDICTOR,
        calibration=calibration_version(cpu_pred, gpu_pred),
        bucket=bucket, tune=tune)
    hit = cache.get(prov)
    if hit is not None:
        return hit
    report = plan_graph(graph, cpu_pred, gpu_pred, threads=threads,
                        mechanism=mechanism, step=step, seed=seed)
    plan = plan_from_graph_report(graph, report, mechanism=mechanism,
                                  step=step, seed=seed,
                                  pred_checksum=prov.predictor_checksum,
                                  calibration=prov.calibration,
                                  bucket=bucket, tune=tune)
    if annotate is not None:
        plan = annotate(plan)
    cache.put(plan)
    return plan


def plan_network_cached(units: Sequence[Unit], cpu_pred, gpu_pred, *,
                        threads: int,
                        mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                        step: int = 8, seed: int = 1,
                        cache: PlanCache) -> CoexecPlan:
    """Legacy unit-list spelling: lowers through `graph.from_units` into
    `plan_graph_cached`.  Chain graphs fingerprint identically to the old
    unit-list digest and their schedules serialize in the pre-IR format,
    so cache entries (keys *and* file bytes) are unchanged."""
    return plan_graph_cached(from_units(units), cpu_pred, gpu_pred,
                             threads=threads, mechanism=mechanism,
                             step=step, seed=seed, cache=cache)


def _ops_as_units(ops: Sequence[Op]) -> List[Unit]:
    from repro.core.types import LinearOp
    return [("linear" if isinstance(op, LinearOp) else "conv", op)
            for op in ops]


def partition_ops_plan_cached(ops: Sequence[Op], cpu_pred, gpu_pred, *,
                              mechanism: SyncMechanism =
                              SyncMechanism.SVM_POLL,
                              step: int = 8,
                              tune: str = "", annotate=None,
                              cache: PlanCache) -> CoexecPlan:
    """Predictor-driven partitioning of a bare op list through the cache,
    returned as the full `CoexecPlan` artifact (the Table 2 sweeps and
    `repro.compile(ops, ...)` go through here).

    Bare op lists carry no thread count or measurement seed in their
    provenance (threads=0, seed=0): predictions are deterministic and the
    CPU predictor already embeds its thread count in the checksum.
    """
    units = _ops_as_units(ops)
    prov = PlanProvenance(
        device=gpu_pred.device, threads=0, mechanism=mechanism.value,
        step=step, seed=0, network_fingerprint=network_fingerprint(units),
        predictor_checksum=predictor_checksum(cpu_pred, gpu_pred),
        planner=PLANNER_PREDICTOR,
        calibration=calibration_version(cpu_pred, gpu_pred),
        tune=tune)
    hit = cache.get(prov)
    if hit is not None:
        return hit
    decisions = optimal_partition_batch(ops, cpu_pred, gpu_pred,
                                        mechanism=mechanism, step=step)
    plan = CoexecPlan(provenance=prov,
                      schedule=build_schedule(units, decisions))
    if annotate is not None:
        plan = annotate(plan)
    cache.put(plan)
    return plan


def partition_ops_cached(ops: Sequence[Op], cpu_pred, gpu_pred, *,
                         mechanism: SyncMechanism = SyncMechanism.SVM_POLL,
                         step: int = 8,
                         cache: PlanCache) -> List[PartitionDecision]:
    """Predictor-driven partitioning of a bare op list through the cache;
    decisions come back in op order (thin wrapper over the plan-returning
    variant — identical provenance, so the two share cache entries)."""
    return partition_ops_plan_cached(ops, cpu_pred, gpu_pred,
                                     mechanism=mechanism, step=step,
                                     cache=cache).decisions


def grid_plan_graph_cached(graph: Graph, device: str, threads: int, *,
                           mechanism: SyncMechanism =
                           SyncMechanism.SVM_POLL,
                           step: int = 8, seed: int = 0,
                           tune: str = "", annotate=None,
                           cache: PlanCache) -> CoexecPlan:
    """Measurement-driven (oracle) planning of a graph through the cache;
    keyed by planner="grid" with no predictor checksum (none is involved).
    Pool/add nodes pass through unsplit; attention/ssm nodes are charged
    analytically (the grid oracle has no measurement model for them)."""
    prov = PlanProvenance(
        device=device, threads=threads, mechanism=mechanism.value,
        step=step, seed=seed, network_fingerprint=graph.fingerprint(),
        predictor_checksum="", planner=PLANNER_GRID, tune=tune)
    hit = cache.get(prov)
    if hit is not None:
        return hit
    report = grid_plan_graph(graph, device, threads, mechanism=mechanism,
                             step=step, seed=seed)
    plan = plan_from_graph_report(graph, report, mechanism=mechanism,
                                  step=step, seed=seed, pred_checksum="",
                                  planner=PLANNER_GRID, tune=tune,
                                  with_totals=False)
    if annotate is not None:
        plan = annotate(plan)
    cache.put(plan)
    return plan


def grid_plan_network_cached(units: Sequence[Unit], device: str,
                             threads: int, *,
                             mechanism: SyncMechanism =
                             SyncMechanism.SVM_POLL,
                             step: int = 8, seed: int = 0,
                             cache: PlanCache) -> CoexecPlan:
    """Legacy unit-list spelling of `grid_plan_graph_cached` (lowers via
    `graph.from_units`; provenance and file bytes unchanged)."""
    return grid_plan_graph_cached(from_units(units), device, threads,
                                  mechanism=mechanism, step=step,
                                  seed=seed, cache=cache)


def grid_partition_ops_cached(ops: Sequence[Op], device: str, threads: int, *,
                              mechanism: SyncMechanism =
                              SyncMechanism.SVM_POLL,
                              step: int = 8, seed: int = 0,
                              cache: PlanCache) -> List[PartitionDecision]:
    """Measurement-driven (oracle) partitioning of a bare op list through
    the cache (wrapper over `grid_plan_network_cached` on ops-as-units —
    identical provenance, shared cache entries)."""
    return grid_plan_network_cached(_ops_as_units(ops), device, threads,
                                    mechanism=mechanism, step=step,
                                    seed=seed, cache=cache).decisions
