"""Compiled co-execution plans.

The paper runs predictor-driven partitioning "offline, as part of the
compilation process" (3-4 ms per operation).  This module makes that story
concrete: a `CoexecPlan` is the compiled artifact — the full per-node
`PartitionDecision` schedule of a network plus the provenance needed to know
when it is safe to reuse (device, threads, sync mechanism, candidate-grid
step, network fingerprint, predictor checksum).  Plans serialize to JSON and
round-trip exactly (floats survive via repr-shortest encoding).

Plans are built over the graph IR (`repro.graph`).  Schedule entries are
keyed by node id; a plan over a legacy unit-chain graph (canonical "n{i}"
ids) serializes in the exact pre-IR format — no "id" keys, no "graph"
section — so stored plan JSON and cache keys are bit-identical to what the
unit-list era wrote, and old on-disk caches stay warm.  Real DAG plans
(fan-out, residual adds, attention/ssm nodes) embed their graph and carry
explicit ids.

`python -m repro.runtime.plan --network resnet18 --device moto2022` compiles
a plan from scratch (training small predictors on the analytic simulator)
and stores it in an on-disk `PlanCache` (see runtime/cache.py); the second
invocation is a pure cache hit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from pathlib import Path
from typing import Any, Dict, FrozenSet, List, Optional, Sequence

import numpy as np

from repro.core.networks import Unit
from repro.core.partitioner import PartitionDecision
from repro.core.planner import GraphPlanReport, PlanReport
from repro.core.sync import SyncMechanism
from repro.core.types import Op
from repro.graph.ir import Graph, Segment, from_units
from repro.kernels.registry import (TileConfig,             # noqa: F401 —
                                    op_from_json, op_kind,  # re-exported
                                    op_label, op_to_json, resolve_tile,
                                    tile_from_json, tile_to_json,
                                    validate_axis_split)

PLAN_SCHEMA_VERSION = 1

#: planner identifiers recorded in provenance
PLANNER_PREDICTOR = "predictor"      # GBDT-driven (deployable path)
PLANNER_GRID = "grid"                # measurement-driven oracle


def _validate_decision(dec: PartitionDecision) -> PartitionDecision:
    # both codec directions route through the registry's split validation,
    # so an illegal typed split (GQA-violating head split, under-aligned
    # state split) can neither enter a schedule nor load from a tampered
    # or stale plan file
    if dec.axis not in ("channel", "none"):
        validate_axis_split(dec.op, dec.axis, dec.c_gpu)
    # same discipline for tiles: an illegal tile (misaligned, over the
    # padded extent, over the VMEM budget) cannot enter a schedule or load
    # from a tampered plan file
    if dec.tile is not None:
        resolve_tile(dec.op, dec.tile)
    return dec


def decision_to_json(dec: PartitionDecision) -> Dict[str, Any]:
    _validate_decision(dec)
    d = {"op": op_to_json(dec.op), "c_cpu": dec.c_cpu, "c_gpu": dec.c_gpu,
         "pred_cpu_us": dec.pred_cpu_us, "pred_gpu_us": dec.pred_gpu_us,
         "pred_total_us": dec.pred_total_us}
    # the axis key is omitted for channel splits so pre-axis plan JSON
    # (every conv/linear schedule ever written) stays byte-identical
    if dec.axis != "channel":
        d["axis"] = dec.axis
    # likewise the tile key: omitted for default blocking so every
    # pre-autotune plan file (and cache entry) stays byte-identical
    if dec.tile is not None:
        d["tile"] = tile_to_json(dec.tile)
    return d


def decision_from_json(d: Dict[str, Any]) -> PartitionDecision:
    op = op_from_json(d["op"])
    tile = (tile_from_json(op_kind(op), d["tile"])
            if "tile" in d else None)
    return _validate_decision(PartitionDecision(
        op=op, c_cpu=d["c_cpu"], c_gpu=d["c_gpu"],
        pred_cpu_us=d["pred_cpu_us"], pred_gpu_us=d["pred_gpu_us"],
        pred_total_us=d["pred_total_us"], axis=d.get("axis", "channel"),
        tile=tile))


# ------------------------------------------------------------- provenance

def network_fingerprint(units: Sequence[Unit]) -> str:
    """Stable digest of a network's op graph (the plan's input contract)."""
    canon = []
    for kind, payload in units:
        if kind == "pool":
            canon.append(["pool", int(payload)])
        else:
            canon.append([kind, op_to_json(payload)])
    blob = json.dumps(canon, sort_keys=True, separators=(",", ":"))
    return hashlib.blake2b(blob.encode(), digest_size=12).hexdigest()


def _hash_array(h, arr) -> None:
    a = np.ascontiguousarray(arr)
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(a.tobytes())


def _hash_gbdt(h, model) -> None:
    h.update(repr(dataclasses.astuple(model.params)).encode())
    h.update(repr(model.base_).encode())
    for edges in model.bin_edges_ or []:
        _hash_array(h, edges)
    for tree in model.trees:
        _hash_array(h, tree.feature)
        _hash_array(h, tree.threshold_bin)
        _hash_array(h, tree.left)
        _hash_array(h, tree.right)
        _hash_array(h, tree.value)


def predictor_checksum(*predictors) -> str:
    """Structural digest of one or more (possibly Mux) latency predictors.

    Two predictors trained from identical data/seeds hash identically across
    processes, so warm plan caches survive restarts; any retraining that
    changes a tree invalidates dependent plans.
    """
    h = hashlib.blake2b(digest_size=12)
    for p in predictors:
        # CalibratedPredictor is checksum-transparent: structurally it IS
        # the wrapped predictor — the calibration invalidates plans through
        # the provenance `calibration` field, not the predictor checksum
        while hasattr(p, "inner") and hasattr(p, "calibration"):
            p = p.inner
        if hasattr(p, "models"):                     # LatencyPredictor
            h.update(f"{p.device}/{p.backend}/{p.whitebox}".encode())
            # tile-aware predictors see different feature vectors, so they
            # must never alias a tile-blind bundle's plans; the tag is
            # appended only when set so pre-tile checksums are unchanged
            if getattr(p, "tiles", False):
                h.update(b"/tiles")
            for kern in sorted(p.models):
                h.update(kern.encode())
                _hash_gbdt(h, p.models[kern])
        elif hasattr(p, "linear") and hasattr(p, "conv"):   # MuxPredictor
            # decode-kind members are appended only when present, so
            # conv/linear-only bundles keep their pre-axis checksums (and
            # the on-disk plan caches keyed by them stay warm)
            members = [p.linear, p.conv]
            for extra in (getattr(p, "attention", None),
                          getattr(p, "ssm", None)):
                if extra is not None:
                    members.append(extra)
            h.update(predictor_checksum(*members).encode())
        else:
            raise TypeError(f"cannot checksum predictor {type(p).__name__}")
    return h.hexdigest()


def calibration_version(*predictors) -> str:
    """The calibration digest a set of predictors carries ("" when none is
    calibrated).  Folded into `PlanProvenance.calibration` by the cached
    planners so a refit calibrator invalidates dependent plans."""
    versions = sorted({p.calibration.version for p in predictors
                       if getattr(p, "calibration", None) is not None})
    return "+".join(versions)


@dataclasses.dataclass(frozen=True)
class PlanProvenance:
    """Everything a cached plan's validity depends on.

    A plan may be reused iff every field matches the request; the cache key
    is a digest over all of them, so any change — different device, thread
    count, sync mechanism, grid step, network graph, retrained predictors,
    or schema bump — is a miss (see docs/ARCHITECTURE.md).
    """

    device: str
    threads: int
    mechanism: str                # SyncMechanism value
    step: int
    seed: int                     # measurement-noise seed used when planning
    network_fingerprint: str
    predictor_checksum: str
    planner: str = PLANNER_PREDICTOR
    schema_version: int = PLAN_SCHEMA_VERSION
    calibration: str = ""         # Calibrator version ("" = uncalibrated)
    bucket: str = ""              # (batch, seq) bucket tag ("" = unbucketed)
    tune: str = ""                # tune-cache version ("" = untuned plan)

    def _canonical(self) -> Dict[str, Any]:
        # the calibration/bucket/tune fields are omitted when empty so
        # legacy keys (and stored plan JSON) stay bit-identical to the
        # older formats — existing on-disk caches remain warm
        d = dataclasses.asdict(self)
        if not d.get("calibration"):
            d.pop("calibration", None)
        if not d.get("bucket"):
            d.pop("bucket", None)
        if not d.get("tune"):
            d.pop("tune", None)
        return d

    @property
    def key(self) -> str:
        blob = json.dumps(self._canonical(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.blake2b(blob.encode(), digest_size=16).hexdigest()

    def to_json(self) -> Dict[str, Any]:
        return self._canonical()

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "PlanProvenance":
        return PlanProvenance(**d)


# ------------------------------------------------------------- exec specs

@dataclasses.dataclass(frozen=True)
class ExecSpec:
    """Executable lowering of one schedule entry.

    A `PartitionDecision` is a *planning* fact (what the predictors said);
    an ExecSpec is the runtime contract the executor consumes: which unit
    kind to dispatch through the kernel registry, the partition `axis`,
    how much of that axis each co-execution group owns (`c_fast` = the
    GPU-analogue share, `c_slow` = the CPU-analogue share — output
    channels on the channel axis, heads / cache positions on the typed
    axes), and the predicted latency the fidelity report compares
    executed timings against.  Pool units carry only their output bytes;
    add units carry nothing; exclusive attention/ssm placements carry
    their op with zero shares (axis "none").  `node_id` names the graph
    node the spec lowers and `segment` its segment-partition index
    (metadata: both excluded from equality).
    """

    unit: str                  # "conv"|"linear"|"attention"|"ssm"|"pool"|"add"
    op: Optional[Op] = None
    pool_bytes: int = 0
    c_fast: int = 0
    c_slow: int = 0
    pred_total_us: float = 0.0
    axis: str = "channel"
    #: autotuned tile config for the op's Pallas kernel (None = default
    #: blocking); part of equality — a retuned tile is a different program
    tile: Optional[TileConfig] = None
    node_id: str = dataclasses.field(default="", compare=False)
    segment: int = dataclasses.field(default=-1, compare=False)

    @property
    def exclusive(self) -> bool:
        return self.c_fast == 0 or self.c_slow == 0

    @property
    def coexec(self) -> bool:
        return self.op is not None and not self.exclusive


def decision_to_spec(dec: PartitionDecision, node_id: str = "") -> ExecSpec:
    """Lower a planning decision to its executable spec (GPU share -> fast
    group, CPU share -> slow group, mirroring the TPU transfer)."""
    return ExecSpec(unit=op_kind(dec.op), op=dec.op, c_fast=dec.c_gpu,
                    c_slow=dec.c_cpu, pred_total_us=dec.pred_total_us,
                    axis=dec.axis, tile=dec.tile, node_id=node_id)


def spec_label(spec: ExecSpec) -> str:
    """Human-readable label of one spec — the one format shared by the
    executor's measurement records and `CompiledNetwork.explain()` (op
    rendering delegates to the kernel registry's `op_label`)."""
    if spec.unit == "pool":
        return f"pool {spec.pool_bytes}B"
    if spec.unit == "add":
        return f"add {spec.node_id}".rstrip()
    label = op_label(spec.op)
    if spec.tile is not None:
        label += f" tile[{spec.tile.label()}]"
    return label


# ------------------------------------------------------------------- plan

@dataclasses.dataclass
class CoexecPlan:
    """Compile-once / execute-many co-execution schedule.

    `schedule` mirrors the network graph in topological order: pool nodes
    pass through as `{"unit": "pool", "bytes": n}`, add joins as
    `{"unit": "add"}`, conv/linear nodes carry their `PartitionDecision`,
    attention/ssm nodes their op + analytic `pred_us`.  Entries of a
    non-chain plan carry an `"id"` and the plan embeds its graph
    (`graph_json`); unit-chain plans omit both — their ids are the
    canonical positions ("n{i}") and the graph reconstructs from the
    schedule — which keeps the serialized format bit-identical to the
    pre-IR era.  The report fields are optional — plans compiled from a
    bare op list (e.g. the Table 2 sweeps) have no end-to-end totals.

    `segments` records the segment-compiler partition the fused executor
    runs (`[{"kind": ..., "nodes": [...]}, ...]`); like `graph`, the key
    is omitted-when-absent, and `segment_partition()` re-derives the
    partition from the schedule for plans (old cached entries, hand-built
    tests) that carry none — provenance never depends on it, so old
    on-disk caches stay warm.
    """

    provenance: PlanProvenance
    schedule: List[Dict[str, Any]]
    baseline_us: Optional[float] = None
    individual_us: Optional[float] = None
    end_to_end_us: Optional[float] = None
    graph_json: Optional[Dict[str, Any]] = None
    segments: Optional[List[Dict[str, Any]]] = None

    # ---------------------------------------------------------- accessors
    @property
    def key(self) -> str:
        return self.provenance.key

    def node_ids(self) -> List[str]:
        """Schedule-order node ids ("n{i}" when entries carry none)."""
        return [e.get("id", f"n{i}") for i, e in enumerate(self.schedule)]

    @property
    def decisions(self) -> List[PartitionDecision]:
        return [decision_from_json(e["decision"]) for e in self.schedule
                if "decision" in e]

    @property
    def decisions_by_node(self) -> Dict[str, PartitionDecision]:
        """Per-node partition decisions keyed by graph node id."""
        return {nid: decision_from_json(e["decision"])
                for nid, e in zip(self.node_ids(), self.schedule)
                if "decision" in e}

    @property
    def units(self) -> List[Unit]:
        if self.graph_json is not None:
            raise ValueError(
                "this plan was compiled over a non-chain graph (fan-out, "
                "add joins, or attention/ssm nodes); use plan.graph_ir() "
                "instead of the legacy unit-list view")
        out: List[Unit] = []
        for e in self.schedule:
            if e["unit"] == "pool":
                out.append(("pool", e["bytes"]))
            else:
                out.append((e["unit"], op_from_json(e["decision"]["op"])))
        return out

    def graph_ir(self) -> Graph:
        """The plan's network graph — embedded for DAG plans,
        reconstructed from the schedule for legacy unit chains."""
        cached = getattr(self, "_graph_ir", None)
        if cached is not None:
            return cached
        if self.graph_json is not None:
            g = Graph.from_json(self.graph_json)
        else:
            g = from_units(self.units)
        self._graph_ir = g
        return g

    def coexec_node_ids(self) -> FrozenSet[str]:
        """Ids of the co-executed *channel-split* nodes — the fusable set
        the segment partition is computed over.  Typed-axis splits (head,
        kv-block, ssm-state) co-execute but run as exclusive-segment
        singletons: kv-block merges inside its own lowering with a
        materialized output, and the head/state lowerings wrap nonlinear
        kernels (softmax, the SSD recurrence) whose fp32 rounding depends
        on the XLA fusion context — inlining them into a larger jitted
        segment program would break bit-identity with the unsplit oracle,
        so each stays its own compilation unit."""
        ids = []
        for nid, e in zip(self.node_ids(), self.schedule):
            d = e.get("decision")
            if (d is not None and d["c_cpu"] > 0 and d["c_gpu"] > 0
                    and d.get("axis") in (None, "channel")):
                ids.append(nid)
        return frozenset(ids)

    def segment_partition(self) -> List[Segment]:
        """The segment-compiler partition of this plan's schedule.

        Embedded `segments` metadata is used when present and consistent
        with the schedule; otherwise (old cached plans, hand-built plans)
        the partition is re-derived from the graph and the plan's coexec
        decisions — the two spellings agree by construction, since the
        planners embed exactly `graph.segments(coexec_node_ids())`.
        """
        cached = getattr(self, "_segment_partition", None)
        if cached is not None:
            return cached
        parts: Optional[List[Segment]] = None
        if self.segments is not None:
            parts = [Segment(kind=e["kind"], node_ids=tuple(e["nodes"]))
                     for e in self.segments]
            covered = [nid for s in parts for nid in s.node_ids]
            if covered != self.node_ids():      # stale metadata: re-derive
                parts = None
        if parts is None:
            parts = self.graph_ir().segments(self.coexec_node_ids())
        self._segment_partition = parts
        return parts

    def segment_of(self) -> Dict[str, int]:
        """node id -> segment-partition index."""
        return {nid: k for k, seg in enumerate(self.segment_partition())
                for nid in seg.node_ids}

    def exec_specs(self) -> List[ExecSpec]:
        """The schedule lowered to executable specs, in topological order
        (the input contract of `repro.runtime.executor.PlanExecutor`)."""
        out: List[ExecSpec] = []
        for nid, e in zip(self.node_ids(), self.schedule):
            if e["unit"] == "pool":
                out.append(ExecSpec(unit="pool", pool_bytes=int(e["bytes"]),
                                    node_id=nid))
            elif e["unit"] == "add":
                out.append(ExecSpec(unit="add", node_id=nid))
            elif "decision" in e:
                out.append(decision_to_spec(
                    decision_from_json(e["decision"]), node_id=nid))
            else:                       # legacy attention / ssm: exclusive
                out.append(ExecSpec(unit=e["unit"],
                                    op=op_from_json(e["op"]),
                                    pred_total_us=float(e.get("pred_us",
                                                              0.0)),
                                    axis="none", node_id=nid))
        seg_of = self.segment_of()
        return [dataclasses.replace(s, segment=seg_of.get(s.node_id, -1))
                for s in out]

    def report(self) -> Optional[PlanReport]:
        if self.end_to_end_us is None:
            return None
        return PlanReport(device=self.provenance.device,
                          threads=self.provenance.threads,
                          baseline_us=self.baseline_us,
                          individual_us=self.individual_us,
                          end_to_end_us=self.end_to_end_us,
                          decisions=self.decisions)

    # ------------------------------------------------------------- codecs
    def to_json(self) -> Dict[str, Any]:
        doc = {"schema_version": self.provenance.schema_version,
               "provenance": self.provenance.to_json(),
               "schedule": self.schedule,
               "report": {"baseline_us": self.baseline_us,
                          "individual_us": self.individual_us,
                          "end_to_end_us": self.end_to_end_us}}
        if self.graph_json is not None:
            doc["graph"] = self.graph_json
        if self.segments is not None:
            doc["segments"] = self.segments
        return doc

    @staticmethod
    def from_json(d: Dict[str, Any], *, verify: bool = True) -> "CoexecPlan":
        """Decode a plan document.

        ``verify=True`` (default) statically verifies the document first
        (`repro.analysis.verify_plan`) and raises `VerificationError` on
        error-severity diagnostics, so a corrupted or hand-edited plan is
        rejected at load time rather than at first execution.  Pass
        ``verify=False`` to load anyway (e.g. to inspect a quarantined
        artifact).
        """
        if verify:
            from repro.analysis import raise_on_error, verify_plan
            raise_on_error(verify_plan(d, stats=False),
                           "plan document")
        rep = d.get("report") or {}
        return CoexecPlan(provenance=PlanProvenance.from_json(d["provenance"]),
                          schedule=d["schedule"],
                          baseline_us=rep.get("baseline_us"),
                          individual_us=rep.get("individual_us"),
                          end_to_end_us=rep.get("end_to_end_us"),
                          graph_json=d.get("graph"),
                          segments=d.get("segments"))

    def dumps(self) -> str:
        return json.dumps(self.to_json(), indent=1)

    @staticmethod
    def loads(text: str, *, verify: bool = True) -> "CoexecPlan":
        return CoexecPlan.from_json(json.loads(text), verify=verify)

    def save(self, path: Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.dumps())

    @staticmethod
    def load(path: Path, *, verify: bool = True) -> "CoexecPlan":
        return CoexecPlan.loads(Path(path).read_text(), verify=verify)


def build_schedule(units: Sequence[Unit],
                   decisions: Sequence[PartitionDecision]
                   ) -> List[Dict[str, Any]]:
    """Zip a unit list with its op decisions into the plan schedule."""
    schedule: List[Dict[str, Any]] = []
    it = iter(decisions)
    for kind, payload in units:
        if kind == "pool":
            schedule.append({"unit": "pool", "bytes": int(payload)})
        else:
            schedule.append({"unit": kind,
                             "decision": decision_to_json(next(it))})
    return schedule


def build_graph_schedule(graph: Graph,
                         decisions: Dict[str, PartitionDecision],
                         opaque_us: Dict[str, float]
                         ) -> List[Dict[str, Any]]:
    """Lower a planned graph into the schedule entry list.

    Unit-chain graphs emit the exact pre-IR entry format (no "id" keys —
    their node ids canonicalize to positions on reload, matching the
    content-addressed fingerprint, which ignores ids); everything else
    carries explicit node ids (and the caller embeds the graph via
    `graph_json`).
    """
    legacy = graph.is_unit_chain()
    schedule: List[Dict[str, Any]] = []
    for node in graph:
        if node.kind == "pool":
            entry: Dict[str, Any] = {"unit": "pool",
                                     "bytes": int(node.pool_bytes)}
        elif node.kind == "add":
            entry = {"unit": "add"}
        elif node.id in decisions:
            entry = {"unit": node.kind,
                     "decision": decision_to_json(decisions[node.id])}
        else:                # no decision: legacy opaque exclusive-GPU node
            entry = {"unit": node.kind, "op": op_to_json(node.op),
                     "pred_us": float(opaque_us[node.id])}
        if not legacy:
            entry["id"] = node.id
        schedule.append(entry)
    return schedule


def segments_json(graph: Graph,
                  decisions: Dict[str, PartitionDecision]
                  ) -> List[Dict[str, Any]]:
    """The plan's embedded segment-partition metadata: `graph.segments`
    over the co-executed node set of `decisions` (the fused executor's
    boundary contract, stored so `.explain()` and tooling can print it
    without re-deriving).

    Unit-chain graphs canonicalize segment node ids to positions
    ("n{i}"), matching the id-free schedule `build_graph_schedule` emits
    for them — the embedded metadata must reference the ids a reload
    reconstructs, not the pre-canonicalization spellings."""
    coexec = {nid for nid, d in decisions.items()
              if d.c_cpu > 0 and d.c_gpu > 0 and d.axis == "channel"}
    canon = {n.id: f"n{i}" for i, n in enumerate(graph)} \
        if graph.is_unit_chain() else {n.id: n.id for n in graph}
    return [{"kind": s.kind, "nodes": [canon[nid] for nid in s.node_ids]}
            for s in graph.segments(coexec)]


def plan_from_graph_report(graph: Graph, report: GraphPlanReport, *,
                           mechanism: SyncMechanism, step: int, seed: int,
                           pred_checksum: str, planner: str =
                           PLANNER_PREDICTOR,
                           calibration: str = "",
                           bucket: str = "",
                           tune: str = "",
                           with_totals: bool = True) -> CoexecPlan:
    """Assemble the compiled plan of a `plan_graph`/`grid_plan_graph` run
    (provenance fingerprint = the graph's content-addressed digest)."""
    prov = PlanProvenance(device=report.device, threads=report.threads,
                          mechanism=mechanism.value, step=step, seed=seed,
                          network_fingerprint=graph.fingerprint(),
                          predictor_checksum=pred_checksum,
                          planner=planner, calibration=calibration,
                          bucket=bucket, tune=tune)
    return CoexecPlan(
        provenance=prov,
        schedule=build_graph_schedule(graph, report.decisions,
                                      report.opaque_us),
        baseline_us=report.baseline_us if with_totals else None,
        individual_us=report.individual_us if with_totals else None,
        end_to_end_us=report.end_to_end_us if with_totals else None,
        graph_json=None if graph.is_unit_chain() else graph.to_json(),
        segments=segments_json(graph, report.decisions))


def plan_from_report(units: Sequence[Unit], report: PlanReport, *,
                     mechanism: SyncMechanism, step: int, seed: int,
                     pred_checksum: str, calibration: str = "") -> CoexecPlan:
    prov = PlanProvenance(device=report.device, threads=report.threads,
                          mechanism=mechanism.value, step=step, seed=seed,
                          network_fingerprint=network_fingerprint(units),
                          predictor_checksum=pred_checksum,
                          planner=PLANNER_PREDICTOR,
                          calibration=calibration)
    graph = from_units(units)
    decisions = {nid: dec for nid, dec in zip(
        (n.id for n in graph if n.kind != "pool"), report.decisions)}
    return CoexecPlan(provenance=prov,
                      schedule=build_schedule(units, report.decisions),
                      baseline_us=report.baseline_us,
                      individual_us=report.individual_us,
                      end_to_end_us=report.end_to_end_us,
                      segments=segments_json(graph, decisions))


# --------------------------------------------------------------------- CLI

def train_mux_predictors(device: str, threads: int, *, samples: int = 400,
                         estimators: int = 60,
                         kinds: Sequence[str] = ("linear", "conv")):
    """Train the (cpu, gpu) MuxPredictor pair the planning/executor CLIs
    use.  Deterministic (fixed data seeds), so two CLI invocations with the
    same knobs produce checksum-identical predictors — which is what lets
    the executor CLI warm-hit a plan the plan CLI compiled.

    `kinds` adds optional decode-kind members ("attention", "ssm") on top
    of the always-present linear/conv pair; conv/linear-only bundles keep
    the pre-decode checksum."""
    from repro.core.predictor import (sample_attn_ops, sample_conv_ops,
                                      sample_linear_ops, sample_ssm_ops,
                                      train_predictor)
    from repro.core.predictor.gbdt import GBDTParams
    from repro.core.predictor.train import MuxPredictor

    params = GBDTParams(n_estimators=estimators)
    lt = sample_linear_ops(samples, seed=1)
    ct = sample_conv_ops(samples, seed=1)
    gp = MuxPredictor(
        train_predictor(lt, device, "gpu", whitebox=True, params=params),
        train_predictor(ct, device, "gpu", whitebox=True, params=params))
    cp = MuxPredictor(
        train_predictor(lt, device, f"cpu{threads}",
                        whitebox=False, params=params),
        train_predictor(ct, device, f"cpu{threads}",
                        whitebox=False, params=params))
    # decode kinds have no dispatch-table white-box features yet: both
    # backends train black-box on the configuration (+ mode index)
    if "attention" in kinds:
        at = sample_attn_ops(samples, seed=1)
        gp.attention = train_predictor(at, device, "gpu",
                                       whitebox=False, params=params)
        cp.attention = train_predictor(at, device, f"cpu{threads}",
                                       whitebox=False, params=params)
    if "ssm" in kinds:
        st = sample_ssm_ops(samples, seed=1)
        gp.ssm = train_predictor(st, device, "gpu",
                                 whitebox=False, params=params)
        cp.ssm = train_predictor(st, device, f"cpu{threads}",
                                 whitebox=False, params=params)
    return cp, gp


def main(argv: Optional[Sequence[str]] = None) -> int:
    """Deprecated CLI shim: forwards to `python -m repro plan`.

    Flags are a strict subset of the unified CLI's, and the provenance it
    builds is identical — a plan compiled by the old spelling warm-hits
    the same cache entry under the new one (and vice versa).
    """
    import sys

    from repro.api import _warn_once
    from repro.cli import main as _cli_main

    _warn_once("python -m repro.runtime.plan", "python -m repro plan")
    rest = list(sys.argv[1:] if argv is None else argv)
    return _cli_main(["plan", *rest])


if __name__ == "__main__":
    try:
        raise SystemExit(main())
    except BrokenPipeError:     # e.g. `... | head` closed the pipe
        import os
        import sys
        os.dup2(os.open(os.devnull, os.O_WRONLY), sys.stdout.fileno())
        raise SystemExit(0)
