"""Plan execution runtime: lower a CoexecPlan into a real split-execution
graph.

PR 1 made partitioning a compile-once artifact; this module closes the
plan->execution gap.  `PlanExecutor` walks the plan's op graph
(`repro.graph`) in topological order and lowers every node to actual
computation on the co-execution mesh:

  * **co-executed** conv/linear nodes run channel-split across the two
    device groups (`core/coexec.coexec_matmul` / `coexec_conv2d`), with the
    split taken verbatim from the plan's `PartitionDecision` (GPU share ->
    fast group) and re-aligned to the mesh (`split_for_mesh`);
  * gather-elision is a *graph property*: a split node's output stays
    **group-local** (`gather=False`) iff its **sole consumer** is a
    compatible split node — the consumer reconstructs its input inside its
    own shard_map program, eliding the explicit reshard.  This is the TPU
    analogue of the paper's fine-grained SVM: "subsequent CPU and GPU
    operations read the shared output directly".  An explicit reshard
    (`gather_stacked`) happens only at true boundaries: pool/add nodes,
    exclusive nodes, shape-adapting transitions, fan-out, and the final
    output — and a **fanned-out** split output is gathered exactly once
    (the materialized activation is written back for the remaining
    consumers);
  * **exclusive** nodes (all channels on one side), attention/ssm nodes
    (never split), and every node on a degraded single-group mesh run
    unsplit through the shared kernel registry — jnp oracle by default,
    Pallas kernels with `use_pallas=True`;
  * **pool** nodes lower to max/global-average pooling on the materialized
    activation (pooling always runs GPU-side in the paper: no sync point);
  * **add** nodes materialize their producers and sum them — the residual
    joins of decoder-block graphs.

Where an op node's declared input shape disagrees with the producing
activation (ResNet projection shortcuts in the legacy unit chains), the
executor re-materializes the declared shape deterministically (tile +
crop), and the unsplit oracle (`run_oracle`) applies the identical
adaptation — so executed plans are testable against the oracle end to end.

Every node execution is timed into a `repro.measure.MeasurementRecord` —
the one schema shared with the simulator and the predictor training sets —
and the resulting `ExecutionReport` pairs executed wall time with the
plan's predicted latency per op (what `MeasurementStore`/`Calibrator`
consume for online replanning).  Note the predictions model a *phone*, the
execution runs on *this host* — the report tracks the ratio's stability
across ops, not its absolute value.
"""
from __future__ import annotations

import dataclasses
import math
import platform
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coexec import (SplitPlan, coexec_conv2d, coexec_matmul,
                               coexec_mesh, gather_stacked, mesh_groups,
                               pack_weights, split_for_mesh)
from repro.core.networks import Unit, pool_out_edge
from repro.graph.ir import Graph
from repro.kernels import registry
from repro.measure.record import (SOURCE_EXECUTOR, SOURCE_FUSED,
                                  MeasurementRecord, usable_for_fidelity)
from repro.runtime.plan import (CoexecPlan, ExecSpec, network_fingerprint,
                                spec_label)

# -------------------------------------------------------------- reporting

#: deprecated alias — the executor's one-off timing format was unified
#: into the shared measurement schema (see docs/MIGRATION.md)
OpTiming = MeasurementRecord


@dataclasses.dataclass
class ExecutionReport:
    """Per-op measurement records + reshard accounting for one plan run."""

    device: str                  # the plan's (simulated) target device
    network_fingerprint: str
    chain: bool
    split_capable: bool
    timings: List[MeasurementRecord]
    reshard_points: int
    elided: int
    fused: bool = False          # segment walk (True) vs per-node walk
    sync_points: int = 0         # device syncs issued by the walk
    #: fused runs: per-segment wall, in partition order (the per-node
    #: wall_us of member records is this attributed pro-rata by pred_us)
    segment_wall_us: List[float] = dataclasses.field(default_factory=list)

    @property
    def wall_us(self) -> float:
        return sum(t.wall_us for t in self.timings)

    @property
    def predicted_us(self) -> float:
        return sum(t.pred_us for t in self.timings)

    def count(self, mode: str) -> int:
        return sum(1 for t in self.timings if t.mode == mode)

    def fidelity_error(self) -> float:
        """Σ |log(wall/pred)| over usable units — delegates to the one
        metric implementation (`repro.measure.fidelity_error`), so the
        executor's number can never drift from what the CLI, benchmarks,
        and Calibrator report."""
        from repro.measure.calibrate import fidelity_error
        return fidelity_error(self.timings)

    def mean_log_ratio(self) -> Optional[float]:
        """Mean signed log(wall/pred) — the drift signal `ServingEngine`
        tracks across runs (None when nothing is comparable)."""
        ratios = [math.log(t.wall_us / t.pred_us) for t in self.timings
                  if usable_for_fidelity(t)]
        if not ratios:
            return None
        return sum(ratios) / len(ratios)

    def fidelity_summary(self) -> str:
        n = len(self.timings)
        if n == 0:
            return (f"fidelity: 0 units (empty schedule), "
                    f"{self.reshard_points} reshard points "
                    f"({self.elided} elided)")
        # guard the ratio: schedules with no predicted latency at all
        # (e.g. pool-only) must not divide by ~zero into a garbage figure
        if self.predicted_us > 0.0:
            ratio = f"(x{self.wall_us / self.predicted_us:.2f})"
        else:
            ratio = "(ratio n/a: no predicted latency)"
        seg = (f"{len(self.segment_wall_us)} segments "
               f"({self.sync_points} syncs), " if self.fused else "")
        return (f"fidelity: {n} units ({self.count('coexec')} co-executed, "
                f"{self.count('exclusive')} exclusive, "
                f"{self.count('pool')} pool), {seg}"
                f"{self.reshard_points} reshard points "
                f"({self.elided} elided), "
                f"executed {self.wall_us / 1e3:.1f} ms vs predicted "
                f"{self.predicted_us / 1e3:.1f} ms {ratio}")

    def to_json(self) -> Dict[str, Any]:
        return {"device": self.device,
                "network_fingerprint": self.network_fingerprint,
                "chain": self.chain,
                "split_capable": self.split_capable,
                "reshard_points": self.reshard_points,
                "elided": self.elided,
                "fused": self.fused,
                "sync_points": self.sync_points,
                "segment_wall_us": list(self.segment_wall_us),
                "wall_us": self.wall_us,
                "predicted_us": self.predicted_us,
                "timings": [t.to_json() for t in self.timings]}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "ExecutionReport":
        return ExecutionReport(
            device=d["device"],
            network_fingerprint=d["network_fingerprint"],
            chain=d["chain"], split_capable=d["split_capable"],
            timings=[MeasurementRecord.from_json(t) for t in d["timings"]],
            reshard_points=d["reshard_points"], elided=d["elided"],
            fused=d.get("fused", False),
            sync_points=d.get("sync_points", 0),
            segment_wall_us=list(d.get("segment_wall_us", [])))


# ------------------------------------------------------------- activations

@dataclasses.dataclass
class _Stacked:
    """A group-local (2, ..., c_pad) activation that has NOT been gathered.

    `shape` is the logical materialized shape the stack reconstructs to —
    what shape-chaining compatibility is checked against.
    """

    data: jax.Array
    split: SplitPlan
    shape: Tuple[int, ...]


_Act = Union[jax.Array, _Stacked]


def _fit_axis(x: jax.Array, axis: int, size: int, *, align: int = 8,
              adapt: bool = False) -> jax.Array:
    """Re-materialize one axis to `size`.

    By default this is strict: the only tolerated mismatch is cropping
    away alignment padding — `size <= cur <= size` rounded up to `align`
    (callers on a split mesh pass the lcm-of-8-and-lanes granularity the
    channel split pads to).  Anything else raises: it means the caller
    wired incompatible shapes together, and silently tiling values to
    paper over that corrupts results without failing any test.

    `adapt=True` opts in to the deterministic tile + crop the executor
    uses for *declared* shape adaptation (`_adapt`: ResNet projection
    shortcuts in the legacy unit chains), where re-materializing is the
    documented semantics rather than an accident.
    """
    cur = x.shape[axis]
    if cur == size:
        return x
    if not adapt:
        padded = -(-size // align) * align
        if not (size < cur <= padded):
            raise ValueError(
                f"axis {axis} has size {cur}, expected {size} (or its "
                f"alignment padding up to {padded}); shapes do not chain "
                "and this call site does not adapt")
    if cur < size:
        reps = [1] * x.ndim
        reps[axis] = -(-size // cur)
        x = jnp.tile(x, reps)
    return jax.lax.slice_in_dim(x, 0, size, axis=axis)


# --------------------------------------------------------------- executor

class PlanExecutor:
    """Executes a compiled `CoexecPlan` on the co-execution mesh.

    Parameters are materialized once at construction from a seeded rng
    (fan-in-scaled, via the kernel registry) and shared by the split run
    and the unsplit oracle, so the two are comparable elementwise.
    """

    def __init__(self, plan: CoexecPlan, units: Optional[Sequence[Unit]] = None,
                 *, mesh=None, dtype=jnp.float32, seed: int = 0,
                 use_pallas: bool = False, interpret: bool = False):
        self.plan = plan
        self.specs = plan.exec_specs()
        if units is not None:
            fp = network_fingerprint(list(units))
            if fp != plan.provenance.network_fingerprint:
                raise ValueError(
                    "units do not match the plan's network fingerprint "
                    f"({fp} != {plan.provenance.network_fingerprint}); "
                    "the plan was compiled for a different graph")
        self.graph: Graph = plan.graph_ir()
        fp = self.graph.fingerprint()
        if fp != plan.provenance.network_fingerprint:
            raise ValueError(
                "graph does not match the plan's network fingerprint "
                f"({fp} != {plan.provenance.network_fingerprint}); "
                "the plan was compiled for a different graph")
        if [n.kind for n in self.graph] != [s.unit for s in self.specs]:
            raise ValueError("plan schedule and graph disagree on node "
                             "kinds — corrupt plan")
        self.mesh = coexec_mesh() if mesh is None else mesh
        self.split_capable = mesh_groups(self.mesh) == 2
        self.dtype = dtype
        self.use_pallas = use_pallas
        self.interpret = interpret
        self.last_report: Optional[ExecutionReport] = None
        self._warmed: set = set()      # (chain, fused) keys executed once
        # segment programs, memoized per input shape (chaining is
        # shape-exact, so the fused layout depends on the input shape)
        self._programs: Dict[Tuple[int, ...], list] = {}

        rng = np.random.default_rng(seed)
        self.params: List[Optional[jax.Array]] = []
        for spec in self.specs:
            if spec.op is None:
                self.params.append(None)
            else:
                w = registry.get(spec.unit).init_weight(spec.op, rng)
                self.params.append(jnp.asarray(w, dtype))
        # pre-split the co-executed weights once: (split, packed) per spec —
        # they depend only on (spec, mesh, params), and packing host-side
        # inside the per-op stopwatch would contaminate the timings.
        # Channel splits pack the trailing weight dim; typed axes (head /
        # kv-block / ssm-state) pack through their registered split
        # lowering (per-side KV-head slices, cache-block slices, per-head
        # parameter vectors)
        self._splits: List[Optional[Tuple[SplitPlan, jax.Array]]] = []
        for spec, w in zip(self.specs, self.params):
            if self.split_capable and spec.coexec:
                if spec.axis == "channel":
                    split = split_for_mesh(spec.op.C_out, spec.c_fast,
                                           self.mesh)
                    self._splits.append(
                        (split, pack_weights(w, split, self.mesh)))
                else:
                    low = registry.get_split_lowering(spec.unit, spec.axis)
                    self._splits.append(
                        low.pack(w, spec.op, spec.c_fast, self.mesh))
            else:
                self._splits.append(None)
        self._input_seed = seed + 1

    @property
    def units(self) -> List[Unit]:
        """Legacy unit-list view (chain plans only; see plan.units)."""
        return self.plan.units

    # ------------------------------------------------------------- inputs
    def input_template(self) -> jax.Array:
        """A seeded input matching the first source node's declared shape
        (deterministic: every call returns the same values, so `run` and
        `run_oracle` with x=None see identical inputs)."""
        src = self.graph.sources[0]
        shape = tuple(registry.get(src.kind).input_shape(src.op))
        if src.kind == "conv":
            shape = (1,) + shape
        rng = np.random.default_rng(self._input_seed)
        x = rng.standard_normal(shape).astype(np.float32)
        return jnp.asarray(x, self.dtype)

    # -------------------------------------------------------- elementaries
    def _materialize(self, act: _Act) -> Tuple[jax.Array, int]:
        """Explicit reshard of a group-local stack (1 sync point), no-op on
        plain activations."""
        if isinstance(act, _Stacked):
            return gather_stacked(act.data, act.split, self.mesh), 1
        return act, 0

    def _adapt(self, x: jax.Array, spec: ExecSpec) -> jax.Array:
        """Re-materialize a plain activation to the node's declared input
        shape (identity when shapes already chain)."""
        op = spec.op
        if spec.unit == "conv":
            if x.ndim == 2:                   # linear -> conv (not in the
                x = x.reshape(1, 1, *x.shape)  # paper's nets, but total)
            x = _fit_axis(x, 1, op.H_in, adapt=True)
            x = _fit_axis(x, 2, op.W_in, adapt=True)
            return _fit_axis(x, 3, op.C_in, adapt=True)
        # 2D (rows, channels) contracts: linear, attention, ssm
        shape = tuple(registry.get(spec.unit).input_shape(op))
        flat = x.reshape(-1)
        flat = _fit_axis(flat, 0, int(np.prod(shape)), adapt=True)
        return flat.reshape(shape)

    def _pool(self, x: jax.Array, pool_bytes: int) -> jax.Array:
        """Lower a pool unit: global average pool when the recorded output
        is one value per channel, else max-pool down to the recorded edge."""
        c = x.shape[-1]
        edge = pool_out_edge(pool_bytes, c)
        if edge <= 1:
            return jnp.mean(x, axis=(1, 2), keepdims=True)
        r = max(1, x.shape[1] // edge)
        x = x[:, :edge * r, :edge * r, :]
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max,
            window_dimensions=(1, r, r, 1), window_strides=(1, r, r, 1),
            padding="VALID")

    def _dense(self, x: jax.Array, w: jax.Array, spec: ExecSpec
               ) -> jax.Array:
        """Unsplit execution through the registry lowering."""
        low = registry.get_lowering(spec.unit)
        if self.use_pallas:
            return low.pallas(x, w, spec.op, interpret=self.interpret,
                              tile=spec.tile)
        return low.oracle(x, w, spec.op)

    def _chains(self, act: _Stacked, spec: ExecSpec) -> bool:
        """Can this unit consume the producer's stack directly?  Only when
        the declared input shape equals the stack's logical shape exactly —
        any adaptation is a true boundary."""
        op = spec.op
        if spec.unit == "conv":
            return act.shape == (1, op.H_in, op.W_in, op.C_in)
        # 2D (rows, channels) contracts: linear, attention, ssm
        return act.shape == tuple(registry.get(spec.unit).input_shape(op))

    # ------------------------------------------------------------ segments
    def segment_programs(self, x_shape: Optional[Tuple[int, ...]] = None):
        """The compiled `SegmentProgram` list for input shape `x_shape`
        (default: the input template's shape).  Memoized per shape."""
        if x_shape is None:
            x_shape = tuple(self.input_template().shape)
        x_shape = tuple(x_shape)
        if x_shape not in self._programs:
            from repro.runtime.segments import compile_segments
            self._programs[x_shape] = compile_segments(self, x_shape)
        return self._programs[x_shape]

    # ----------------------------------------------------------------- run
    def run(self, x: Optional[jax.Array] = None, *, chain: bool = True,
            warmup: bool = False, fused: bool = False
            ) -> Tuple[jax.Array, ExecutionReport]:
        """Execute the plan; returns (output, ExecutionReport).

        `warmup=True` runs the whole schedule once untimed first, so the
        reported per-op wall times measure steady-state execution rather
        than shard_map tracing + XLA compilation (first-touch compile can
        dominate the microsecond-scale predictions by orders of
        magnitude).  The executor tracks what it has already executed
        (per chain flag), so `warmup=True` is a no-op after the first
        run — callers can pass it unconditionally without paying 2N
        schedule passes for N recorded runs.  The warmup pass never
        publishes its report: only the timed run lands on
        `self.last_report` (a warmup report leaking there would poison
        the measurement store and any calibration fit from it).  The
        CLIs and `tab3 --execute` warm up by default; equivalence tests
        skip it for speed.

        `fused=True` takes the segment walk instead of the per-node walk:
        the plan's partition lowered into one jitted program per fused
        segment (see `repro.runtime.segments`), bit-identical outputs,
        one device sync per segment.  The per-node walk stays as the
        `fused=False` reference.
        """
        if fused and not chain:
            raise ValueError(
                "fused=True implies chaining — chain=False is the "
                "gather-every-op reference walk and has no fused form")
        step = (lambda: self._execute_fused(x)) if fused else (
            lambda: self._execute(x, chain=chain))
        key = (chain, fused)
        if warmup and key not in self._warmed:
            step()                               # untimed: not published
            self._warmed.add(key)
        y, report = step()
        self._warmed.add(key)
        self.last_report = report
        return y, report

    __call__ = run

    def _execute(self, x: Optional[jax.Array] = None, *, chain: bool = True
                 ) -> Tuple[jax.Array, ExecutionReport]:
        x0: jax.Array = (self.input_template() if x is None
                         else jnp.asarray(x, self.dtype))
        acts: Dict[str, _Act] = {}
        remaining = {n.id: len(self.graph.consumers(n.id))
                     for n in self.graph}
        timings: List[MeasurementRecord] = []
        reshard = elided = 0
        host = platform.node()
        prov = self.plan.provenance

        def materialized(src: Optional[str]) -> jax.Array:
            """The plain (gathered) activation of a producer.  A stacked
            output is gathered ONCE and written back, so fan-out costs a
            single reshard no matter how many consumers follow."""
            nonlocal reshard
            if src is None:
                return x0
            act = acts[src]
            if isinstance(act, _Stacked):
                act, r = self._materialize(act)
                reshard += r
                acts[src] = act
            return act

        for i, (node, spec) in enumerate(zip(self.graph, self.specs)):
            w = self.params[i]
            src = node.inputs[0] if node.inputs else None
            t0 = time.perf_counter()
            chained = False
            if spec.unit == "pool":
                mode = "pool"
                out = self._pool(materialized(src), spec.pool_bytes)
            elif spec.unit == "add":
                mode = "add"
                parts = [materialized(s) for s in node.inputs]
                shapes = {tuple(p.shape) for p in parts}
                if len(shapes) != 1:
                    raise ValueError(
                        f"add node {node.id!r} joins mismatched shapes "
                        f"{sorted(shapes)}")
                out = parts[0]
                for p in parts[1:]:
                    out = out + p
            else:
                do_split = self.split_capable and spec.coexec
                x_plan = None
                prod_act = x0 if src is None else acts[src]
                # gather-elision as a graph property: consume the
                # producer's group-local stack iff we are its SOLE
                # consumer, we split too, and the shapes chain exactly
                if (isinstance(prod_act, _Stacked) and chain and do_split
                        and self._chains(prod_act, spec)
                        and len(self.graph.consumers(src)) == 1):
                    x_in, x_plan = prod_act.data, prod_act.split
                    chained = True
                    elided += 1
                else:
                    x_in = self._adapt(materialized(src), spec)
                if do_split:
                    mode = "coexec"
                    op = spec.op
                    split, packed = self._splits[i]
                    if spec.unit == "linear":
                        y = coexec_matmul(x_in, packed, split, self.mesh,
                                          gather=False, x_plan=x_plan)
                        out = _Stacked(y, split, (op.L, op.C_out))
                    elif spec.unit == "conv":
                        y = coexec_conv2d(x_in, packed, split, self.mesh,
                                          stride=op.S, gather=False,
                                          x_plan=x_plan)
                        # SAME conv rounds up; crop the stack to the
                        # declared (floor) shape so chaining stays exact
                        y = y[:, :, :op.H_out, :op.W_out, :]
                        b = x_in.shape[1] if chained else x_in.shape[0]
                        out = _Stacked(y, split,
                                       (b, op.H_out, op.W_out, op.C_out))
                    else:       # typed axis: registered split lowering
                        low = registry.get_split_lowering(spec.unit,
                                                          spec.axis)
                        y = low.run(x_in, packed, split, self.mesh, op,
                                    spec.c_fast, gather=False,
                                    x_plan=x_plan,
                                    use_pallas=self.use_pallas,
                                    interpret=self.interpret,
                                    tile=spec.tile)
                        if spec.axis == "kv-block":
                            # non-stackable: the lowering merged its
                            # softmax partials and materialized internally
                            out = y
                        else:
                            shape = tuple(registry.get(
                                spec.unit).output_shape(op))
                            out = _Stacked(y, split, shape)
                    if isinstance(out, _Stacked) and not chain:
                        out, r = self._materialize(out)  # sync every op
                        reshard += r
                else:
                    mode = "exclusive"
                    out = self._dense(x_in, w, spec)
            acts[node.id] = out
            jax.block_until_ready(out.data if isinstance(out, _Stacked)
                                  else out)
            timings.append(MeasurementRecord(
                index=i, unit=spec.unit, label=spec_label(spec), mode=mode,
                c_fast=spec.c_fast, c_slow=spec.c_slow,
                chained_input=chained,
                gathered_output=not isinstance(out, _Stacked),
                wall_us=(time.perf_counter() - t0) * 1e6,
                pred_us=spec.pred_total_us,
                op=spec.op, source=SOURCE_EXECUTOR, device=prov.device,
                host=host, plan_key=self.plan.key,
                network_fingerprint=prov.network_fingerprint,
                node_id=node.id))
            # free consumed producers (keep the graph output alive)
            for s in node.inputs:
                remaining[s] -= 1
                if remaining[s] == 0:
                    acts.pop(s, None)

        # the terminal sync point: with chaining, the last co-executed op's
        # gather is deferred to here — time it and charge it to that op so
        # chained and gather-every-op wall totals stay comparable
        t0 = time.perf_counter()
        y, r = self._materialize(acts[self.graph.output.id])
        jax.block_until_ready(y)
        reshard += r
        if timings and r:
            timings[-1].gathered_output = True
            timings[-1].wall_us += (time.perf_counter() - t0) * 1e6
        report = ExecutionReport(
            device=prov.device,
            network_fingerprint=prov.network_fingerprint,
            chain=chain, split_capable=self.split_capable, timings=timings,
            reshard_points=reshard, elided=elided,
            # one block_until_ready per node plus the terminal one
            sync_points=len(timings) + 1)
        return y, report

    def _execute_fused(self, x: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, ExecutionReport]:
        """The segment walk: one jitted program (and one device sync) per
        fused segment, eager singletons for pool/exclusive nodes.

        A segment's wall time cannot be split per member by measurement —
        the whole point is that the members no longer sync — so each
        member record carries the segment wall attributed **pro-rata by
        predicted latency** (equal shares when the segment has no
        prediction), flagged `source="fused"` and tagged with its segment
        index.  Summing member walls recovers the segment wall exactly,
        so report totals stay comparable with the per-node walk, and
        `Calibrator.fit` consumes the records unchanged.
        """
        x0: jax.Array = (self.input_template() if x is None
                         else jnp.asarray(x, self.dtype))
        programs = self.segment_programs(tuple(x0.shape))
        pos = {n.id: i for i, n in enumerate(self.graph)}
        acts: Dict[Optional[str], jax.Array] = {None: x0}
        timings: List[MeasurementRecord] = []
        segment_wall: List[float] = []
        reshard = elided = 0
        host = platform.node()
        prov = self.plan.provenance

        for sp in programs:
            t0 = time.perf_counter()
            if sp.fn is not None:
                out = sp.fn([acts[s] for s in sp.ext_inputs], sp.weights)
            else:
                nid = sp.node_ids[0]
                spec = self.specs[pos[nid]]
                src_val = acts[sp.ext_inputs[0]]
                if sp.modes[nid] == "pool":
                    out = self._pool(src_val, spec.pool_bytes)
                elif sp.modes[nid] == "coexec":
                    # typed-axis split: runs as an eager exclusive-segment
                    # singleton so its shard_map program is the sole
                    # compilation unit (fp32 bit-identity vs the oracle);
                    # kv-block additionally merges/materializes internally
                    split, packed = self._splits[pos[nid]]
                    low = registry.get_split_lowering(spec.unit, spec.axis)
                    out = low.run(self._adapt(src_val, spec), packed,
                                  split, self.mesh, spec.op, spec.c_fast,
                                  use_pallas=self.use_pallas,
                                  interpret=self.interpret,
                                  tile=spec.tile)
                else:
                    out = self._dense(self._adapt(src_val, spec),
                                      self.params[pos[nid]], spec)
            jax.block_until_ready(out)
            wall = (time.perf_counter() - t0) * 1e6
            segment_wall.append(wall)
            reshard += sp.gathers
            elided += sp.elided
            # convexity: only a segment's last node is consumed downstream
            acts[sp.node_ids[-1]] = out
            preds = [self.specs[pos[n]].pred_total_us for n in sp.node_ids]
            total = sum(preds)
            for nid, pred in zip(sp.node_ids, preds):
                spec = self.specs[pos[nid]]
                share = (wall * pred / total if total > 0.0
                         else wall / len(preds))
                timings.append(MeasurementRecord(
                    index=pos[nid], unit=spec.unit, label=spec_label(spec),
                    mode=sp.modes[nid], c_fast=spec.c_fast,
                    c_slow=spec.c_slow, chained_input=sp.chained[nid],
                    gathered_output=sp.gathered[nid], wall_us=share,
                    pred_us=spec.pred_total_us, op=spec.op,
                    source=SOURCE_FUSED, device=prov.device, host=host,
                    plan_key=self.plan.key,
                    network_fingerprint=prov.network_fingerprint,
                    node_id=nid, segment=sp.index))

        y = acts[self.graph.output.id]
        report = ExecutionReport(
            device=prov.device,
            network_fingerprint=prov.network_fingerprint,
            chain=True, split_capable=self.split_capable, timings=timings,
            reshard_points=reshard, elided=elided, fused=True,
            sync_points=len(programs), segment_wall_us=segment_wall)
        return y, report

    def run_oracle(self, x: Optional[jax.Array] = None) -> jax.Array:
        """The unsplit reference: every node dense, identical params and
        shape adaptation — what split execution must match elementwise."""
        x0 = (self.input_template() if x is None
              else jnp.asarray(x, self.dtype))
        acts: Dict[str, jax.Array] = {}
        for node, spec, w in zip(self.graph, self.specs, self.params):
            src = acts[node.inputs[0]] if node.inputs else x0
            if spec.unit == "pool":
                acts[node.id] = self._pool(src, spec.pool_bytes)
            elif spec.unit == "add":
                out = acts[node.inputs[0]]
                for s in node.inputs[1:]:
                    out = out + acts[s]
                acts[node.id] = out
            else:
                acts[node.id] = self._dense(self._adapt(src, spec), w, spec)
        return acts[self.graph.output.id]


# --------------------------------------------------------------------- CLI

def main(argv: Optional[Sequence[str]] = None) -> int:
    """Deprecated CLI shim: forwards to `python -m repro execute`.

    Flags are a strict subset of the unified CLI's, and the provenance it
    builds is identical — it warm-hits the same plan-cache entries.
    """
    import sys

    from repro.api import _warn_once
    from repro.cli import main as _cli_main

    _warn_once("python -m repro.runtime.executor", "python -m repro execute")
    rest = list(sys.argv[1:] if argv is None else argv)
    return _cli_main(["execute", *rest])


if __name__ == "__main__":
    raise SystemExit(main())
