"""The segment compiler: lower a plan's DAG schedule into a handful of
jitted programs.

`PlanExecutor._execute` walks the graph node-by-node in Python — one
shard_map dispatch plus one device sync per op.  That per-boundary cost is
exactly what the paper's SVM synchronization (and our gather-elision) is
meant to kill, but elision alone still pays Python dispatch between every
pair of chained ops.  This module closes the gap: the plan's
`segment_partition()` (see `repro.graph.ir.Graph.segments`) groups the
schedule into maximal same-mesh runs — co-executed ops whose outputs chain
group-locally, plus the residual `add` joins between them — and
`compile_segments` lowers each fused run into ONE `jax.jit` program:

  * chained edges consume the producer's group-local `(2, ..., c_pad)`
    stack via `x_plan=` exactly as the eager walk does (the reconstruction
    is fused into the consumer's shard_map program);
  * a stack consumed by an `add` (or by a non-chaining consumer) is
    reconstructed *inside* the program with `gather_stacked_traced` — the
    jit-safe spelling of the same all-gather;
  * the segment's single published output is materialized at the boundary,
    so one fused segment issues exactly one device sync no matter how many
    ops it contains.

Pool and exclusive (unsplit-kind or exclusively-placed) nodes stay on the
eager per-node path as singleton segments: they are true reshard points
and gain nothing from fusion.

The static layout pass mirrors `PlanExecutor._execute`'s decisions over
shapes only (same chaining predicate, same adaptation, same crops), so the
emitted program computes bit-identical values to the unfused walk; weights
are passed as traced arguments — never baked in as constants — so jit
cannot constant-fold them differently from eager execution.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.coexec import (coexec_conv2d, coexec_matmul,
                               gather_stacked_traced)
from repro.graph.ir import SEGMENT_FUSED, SEGMENT_POOL
from repro.kernels import registry


@dataclasses.dataclass
class SegmentProgram:
    """One executable segment of the fused walk.

    Fused segments carry the jitted `fn(ext_vals, weights)` program plus
    statically-known gather/elision counts; pool/exclusive singletons have
    `fn=None` and run through the executor's eager per-node helpers.
    `ext_inputs` names the producers the program reads (in order; `None`
    is the graph input), and the per-node flag maps feed the measurement
    records of the member nodes.
    """

    index: int                           # position in the partition
    kind: str                            # fused | pool | exclusive
    node_ids: Tuple[str, ...]
    ext_inputs: Tuple[Optional[str], ...]
    gathers: int                         # reshards issued by this segment
    elided: int                          # chained (group-local) edges inside
    chained: Dict[str, bool]             # node id -> consumed chained input
    gathered: Dict[str, bool]            # node id -> output materialized
    modes: Dict[str, str]                # node id -> measurement mode
    fn: Optional[Callable] = None        # jitted program (fused only)
    weights: Optional[List[jax.Array]] = None


def _eval_shape(fn, in_shape: Tuple[int, ...], dtype) -> Tuple[int, ...]:
    """Output shape of a single-array function without running it."""
    out = jax.eval_shape(fn, jax.ShapeDtypeStruct(tuple(in_shape), dtype))
    return tuple(out.shape)


def compile_segments(exe, x_shape: Tuple[int, ...]) -> List[SegmentProgram]:
    """Lower the executor's plan into segment programs for input `x_shape`.

    The layout pass walks the partition in order, tracking each value's
    state (materialized shape vs group-local stack) exactly as the eager
    walk would, and records one instruction per fused-segment member; the
    emission pass replays those instructions over traced values inside
    `jax.jit`.  Programs depend on the input shape (chaining is
    shape-exact), hence the per-shape memoization in `PlanExecutor`.
    """
    graph, dtype = exe.graph, exe.dtype
    partition = exe.plan.segment_partition()
    pos = {n.id: i for i, n in enumerate(graph)}

    # materialized shape of every published (cross-segment) value
    plain_shape: Dict[Optional[str], Tuple[int, ...]] = {None: tuple(x_shape)}
    programs: List[SegmentProgram] = []
    for k, seg in enumerate(partition):
        if seg.kind != SEGMENT_FUSED:
            programs.append(_layout_singleton(exe, k, seg, plain_shape))
            continue

        seg_ids = set(seg.node_ids)
        stacked: Dict[str, Tuple[Any, Tuple[int, ...]]] = {}
        local_shape: Dict[str, Tuple[int, ...]] = {}
        instrs: List[Dict[str, Any]] = []
        ext: List[Optional[str]] = []
        weights: List[jax.Array] = []
        gathers = elided = 0
        chained_f: Dict[str, bool] = {}
        modes: Dict[str, str] = {}

        def plain_in(src: Optional[str]) -> Tuple[int, ...]:
            """Shape of `src` consumed as a materialized value (counts the
            interior gather when it is a still-stacked segment member)."""
            nonlocal gathers
            if src in stacked:
                _, lsh = stacked.pop(src)
                gathers += 1
                local_shape[src] = lsh
                return lsh
            if src in local_shape:
                return local_shape[src]
            if src not in ext:
                ext.append(src)
            return plain_shape[src]

        for nid in seg.node_ids:
            node = graph.node(nid)
            i = pos[nid]
            spec = exe.specs[i]
            if spec.unit == "add":
                shapes = {tuple(plain_in(s)) for s in node.inputs}
                if len(shapes) != 1:
                    raise ValueError(
                        f"add node {nid!r} joins mismatched shapes "
                        f"{sorted(shapes)}")
                local_shape[nid] = shapes.pop()
                instrs.append({"id": nid, "kind": "add",
                               "srcs": tuple(node.inputs)})
                modes[nid] = "add"
                chained_f[nid] = False
                continue
            src = node.inputs[0] if node.inputs else None
            do_split = exe.split_capable and spec.coexec
            op = spec.op
            # the eager walk's chaining predicate, over static shapes
            ch = False
            if do_split and src in stacked:
                lsh = stacked[src][1]
                if spec.unit == "conv":
                    ch = tuple(lsh) == (1, op.H_in, op.W_in, op.C_in)
                else:    # 2D contracts: linear, attention, ssm
                    ch = tuple(lsh) == tuple(
                        registry.get(spec.unit).input_shape(op))
                ch = ch and len(graph.consumers(src)) == 1
            if ch:
                _, lsh = stacked.pop(src)
                elided += 1
                in_shape = lsh
            else:
                in_shape = plain_in(src)
            chained_f[nid] = ch
            if do_split:
                split, packed = exe._splits[i]
                slot = len(weights)
                weights.append(packed)
                if spec.unit == "linear":
                    out_l: Tuple[int, ...] = (op.L, op.C_out)
                elif spec.unit == "conv":
                    b = (in_shape[0] if ch else
                         _eval_shape(lambda v: exe._adapt(v, spec),
                                     in_shape, dtype)[0])
                    out_l = (b, op.H_out, op.W_out, op.C_out)
                else:    # head-/state-split attention, ssm
                    out_l = tuple(registry.get(spec.unit).output_shape(op))
                stacked[nid] = (split, out_l)
                modes[nid] = "coexec"
                instrs.append({"id": nid, "kind": "op", "mode": "coexec",
                               "src": src, "chained": ch, "split": split,
                               "slot": slot, "spec": spec, "shape": out_l})
            else:
                w = exe.params[i]
                slot = len(weights)
                weights.append(w)
                local_shape[nid] = _eval_shape(
                    lambda v: exe._dense(exe._adapt(v, spec), w, spec),
                    in_shape, dtype)
                modes[nid] = "exclusive"
                instrs.append({"id": nid, "kind": "op", "mode": "exclusive",
                               "src": src, "chained": False, "slot": slot,
                               "spec": spec})

        last = seg.node_ids[-1]
        if last in stacked:                   # boundary gather
            gathers += 1
            local_shape[last] = stacked.pop(last)[1]
        if stacked:
            raise AssertionError(             # convexity guarantees this
                f"segment {seg.node_ids} leaks stacked values {set(stacked)}")
        plain_shape[last] = tuple(local_shape[last])
        gathered_f = {nid: True for nid in seg.node_ids}
        for ins in instrs:
            if ins.get("chained"):
                gathered_f[ins["src"]] = False
        programs.append(SegmentProgram(
            index=k, kind=SEGMENT_FUSED, node_ids=seg.node_ids,
            ext_inputs=tuple(ext), gathers=gathers, elided=elided,
            chained=chained_f, gathered=gathered_f, modes=modes,
            fn=_emit(exe, instrs, tuple(ext)), weights=weights))
    return programs


def _layout_singleton(exe, index: int, seg, plain_shape) -> SegmentProgram:
    """Pool/exclusive singleton: stays eager, only its shape is tracked."""
    nid = seg.node_ids[0]
    graph = exe.graph
    node = graph.node(nid)
    i = [j for j, n in enumerate(graph) if n.id == nid][0]
    spec = exe.specs[i]
    src = node.inputs[0] if node.inputs else None
    if seg.kind == SEGMENT_POOL:
        mode = "pool"
        out_shape = _eval_shape(lambda v: exe._pool(v, spec.pool_bytes),
                                plain_shape[src], exe.dtype)
    elif exe.split_capable and spec.coexec:
        # typed-axis split (head / kv-block / ssm-state): co-executes, but
        # outside fused segments — each lowering stays its own compilation
        # unit so XLA fusion context cannot perturb fp32 rounding
        mode = "coexec"
        out_shape = tuple(registry.get(spec.unit).output_shape(spec.op))
    else:
        mode = "exclusive"
        w = exe.params[i]
        out_shape = _eval_shape(
            lambda v: exe._dense(exe._adapt(v, spec), w, spec),
            plain_shape[src], exe.dtype)
    plain_shape[nid] = out_shape
    return SegmentProgram(
        index=index, kind=seg.kind, node_ids=seg.node_ids,
        ext_inputs=(src,), gathers=0, elided=0, chained={nid: False},
        gathered={nid: True}, modes={nid: mode})


def _emit(exe, instrs: List[Dict[str, Any]],
          ext_keys: Tuple[Optional[str], ...]) -> Callable:
    """Close the instruction list into one jitted program.

    Signature: `fn(ext_vals, weights) -> materialized segment output`,
    where `ext_vals` follows `ext_keys` and `weights` the instruction
    slots — both traced arguments, so no activation or parameter is ever
    baked into the compiled computation as a constant.
    """
    from repro.runtime.executor import _Stacked
    mesh = exe.mesh

    def program(ext_vals, weights):
        env: Dict[Optional[str], Any] = {}
        ext = dict(zip(ext_keys, ext_vals))

        def plain(src):
            v = env[src] if src in env else ext[src]
            if isinstance(v, _Stacked):     # interior reshard, fused in
                v = gather_stacked_traced(v.data, v.split, mesh)
                env[src] = v
            return v

        for ins in instrs:
            if ins["kind"] == "add":
                parts = [plain(s) for s in ins["srcs"]]
                out = parts[0]
                for p in parts[1:]:
                    out = out + p
            else:
                spec = ins["spec"]
                op = spec.op
                if ins["mode"] == "coexec":
                    if ins["chained"]:
                        prod = env[ins["src"]]
                        x_in, x_plan = prod.data, prod.split
                    else:
                        x_in = exe._adapt(plain(ins["src"]), spec)
                        x_plan = None
                    split = ins["split"]
                    packed = weights[ins["slot"]]
                    if spec.unit == "linear":
                        y = coexec_matmul(x_in, packed, split, mesh,
                                          gather=False, x_plan=x_plan)
                    elif spec.unit == "conv":
                        y = coexec_conv2d(x_in, packed, split, mesh,
                                          stride=op.S, gather=False,
                                          x_plan=x_plan)
                        # SAME conv rounds up; crop to the declared shape
                        y = y[:, :, :op.H_out, :op.W_out, :]
                    else:    # head-/state-split attention, ssm
                        low = registry.get_split_lowering(spec.unit,
                                                          spec.axis)
                        y = low.run(x_in, packed, split, mesh, op,
                                    spec.c_fast, gather=False,
                                    x_plan=x_plan,
                                    use_pallas=exe.use_pallas,
                                    interpret=exe.interpret,
                                    tile=spec.tile)
                    out = _Stacked(y, split, ins["shape"])
                else:
                    out = exe._dense(exe._adapt(plain(ins["src"]), spec),
                                     weights[ins["slot"]], spec)
            env[ins["id"]] = out
        return plain(instrs[-1]["id"])

    return jax.jit(program)
