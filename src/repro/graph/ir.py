"""The typed op-graph IR: one network representation for the whole pipeline.

The paper's networks were a flat ``List[Unit]`` of ("conv"|"linear"|"pool",
payload) tuples — linear chains only, which shut transformer/SSM blocks out
of the planner even though their kernels were already registered.  This
module replaces that list with a real IR:

  * `Node(id, kind, op, inputs)` — one scheduling unit.  `kind` is either
    a kernel-registry op kind ("conv", "linear", "attention", "ssm") with
    its `op` payload, or a structural kind: "pool" (carries `pool_bytes`,
    always GPU-side, as in the paper) and "add" (elementwise residual
    join, >= 2 inputs).
  * `Graph` — validated, topologically ordered, shape-inferred, and
    JSON-serializable.  Edges are explicit (`Node.inputs`), so fan-out is
    a first-class property: the executor gathers a shared split output
    exactly once, and gather-elision becomes "the sole consumer is a
    compatible split node" instead of an adjacent-index special case.

`fingerprint()` is content-addressed (node *positions*, not names, enter
the digest — renaming ids never invalidates a plan cache) and versioned
for compatibility: a graph that is exactly a legacy unit chain fingerprints
identically to `repro.runtime.plan.network_fingerprint(units)`, so every
pre-IR `PlanProvenance.network_fingerprint` key stays warm; any real DAG
(fan-out, residual adds, attention/SSM nodes) digests under the
``graph``-tagged canonical form instead.

This module is deliberately jax-free: importing it (or planning over it)
never pulls in execution machinery.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import (Any, Collection, Dict, FrozenSet, Iterator, List,
                    Optional, Sequence, Tuple)

from repro.core.networks import Unit, pool_out_edge
from repro.core.types import Op
from repro.kernels import registry

# v2: attention/SSM nodes became plannable (axis/mode decisions).  Bumping
# invalidates DAG-plan fingerprints — their cached plans would now plan
# differently — while unit-chain fingerprints (legacy canonical form, which
# predates and omits the version) stay warm for pure conv/linear networks.
GRAPH_SCHEMA_VERSION = 2

#: node kinds with no kernel-registry op payload
STRUCTURAL_KINDS = ("pool", "add")


@dataclasses.dataclass(frozen=True)
class Node:
    """One scheduling unit of the op graph.

    `inputs` name the producing nodes (explicit edges).  A node with no
    inputs is a source: it reads the graph input.  Op-kind nodes take at
    most one input, "pool" exactly one, "add" at least two.
    """

    id: str
    kind: str
    op: Optional[Op] = None
    pool_bytes: int = 0
    inputs: Tuple[str, ...] = ()

    def __post_init__(self):
        if not self.id or not isinstance(self.id, str):
            raise ValueError(f"node id must be a non-empty string, "
                             f"got {self.id!r}")
        object.__setattr__(self, "inputs", tuple(self.inputs))
        if self.kind in STRUCTURAL_KINDS:
            if self.op is not None:
                raise ValueError(f"node {self.id!r}: structural kind "
                                 f"{self.kind!r} carries no op")
            if self.kind == "pool":
                if self.pool_bytes <= 0:
                    raise ValueError(
                        f"node {self.id!r}: pool needs a positive byte "
                        f"count, got {self.pool_bytes}")
                if len(self.inputs) != 1:
                    raise ValueError(f"node {self.id!r}: pool takes exactly "
                                     f"one input, got {len(self.inputs)}")
            elif len(self.inputs) < 2:
                raise ValueError(f"node {self.id!r}: add joins >= 2 inputs, "
                                 f"got {len(self.inputs)}")
            return
        entry = registry.get(self.kind)      # raises on unknown kinds
        if self.op is None:
            raise ValueError(f"node {self.id!r}: kind {self.kind!r} needs "
                             f"an op payload")
        if registry.op_kind(self.op) != entry.kind:
            raise ValueError(
                f"node {self.id!r}: op is {registry.op_kind(self.op)!r} "
                f"but the node kind is {self.kind!r}")
        if len(self.inputs) > 1:
            raise ValueError(f"node {self.id!r}: op nodes take at most one "
                             f"input, got {len(self.inputs)}")

    @property
    def splittable(self) -> bool:
        """Whether the partitioner may channel-split this node."""
        return self.op is not None and registry.get(self.kind).splittable

    def label(self) -> str:
        if self.kind == "pool":
            return f"pool {self.pool_bytes}B"
        if self.kind == "add":
            return f"add({len(self.inputs)})"
        return registry.op_label(self.op)

    # -------------------------------------------------------------- codecs
    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"id": self.id, "kind": self.kind,
                             "inputs": list(self.inputs)}
        if self.op is not None:
            d["op"] = registry.op_to_json(self.op)
        if self.kind == "pool":
            d["bytes"] = self.pool_bytes
        return d

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Node":
        return Node(id=d["id"], kind=d["kind"],
                    op=(registry.op_from_json(d["op"])
                        if d.get("op") is not None else None),
                    pool_bytes=int(d.get("bytes", 0)),
                    inputs=tuple(d.get("inputs", ())))


#: segment kinds — "fused" runs as one jitted program, the others are
#: per-node eager singletons (true reshard/dispatch boundaries)
SEGMENT_FUSED = "fused"
SEGMENT_POOL = "pool"
SEGMENT_EXCLUSIVE = "exclusive"


@dataclasses.dataclass(frozen=True)
class Segment:
    """One contiguous run of a segment partition (see `Graph.segments`).

    A "fused" segment is a maximal same-mesh run of co-executed nodes and
    residual adds that lowers to a single jitted program; "pool" and
    "exclusive" segments are singletons that stay on the eager per-node
    path (pooling, unsplit kinds, and exclusively-placed ops are true
    dispatch boundaries).
    """

    kind: str                           # fused | pool | exclusive
    node_ids: Tuple[str, ...]

    def __post_init__(self):
        if self.kind not in (SEGMENT_FUSED, SEGMENT_POOL,
                             SEGMENT_EXCLUSIVE):
            raise ValueError(f"unknown segment kind {self.kind!r}")
        if not self.node_ids:
            raise ValueError("a segment needs at least one node")
        object.__setattr__(self, "node_ids", tuple(self.node_ids))

    def __len__(self) -> int:
        return len(self.node_ids)


class Graph:
    """A validated, topologically ordered op graph.

    Construction validates the node set (unique ids, known kinds, arity,
    existing inputs, acyclicity, exactly one output node) and stores the
    nodes in a deterministic topological order — Kahn's algorithm that
    always emits the earliest *given* ready node, so a graph built in
    schedule order keeps that order.  Iteration, planning, and execution
    all walk `self.nodes` and therefore agree on positions.
    """

    def __init__(self, nodes: Sequence[Node]):
        given = list(nodes)
        if not given:
            raise ValueError("a graph needs at least one node")
        by_id: Dict[str, Node] = {}
        for n in given:
            if n.id in by_id:
                raise ValueError(f"duplicate node id {n.id!r}")
            by_id[n.id] = n
        consumers: Dict[str, List[str]] = {n.id: [] for n in given}
        for n in given:
            for src in n.inputs:
                if src not in by_id:
                    raise ValueError(f"node {n.id!r} consumes unknown node "
                                     f"{src!r}")
                if src == n.id:
                    raise ValueError(f"node {n.id!r} consumes itself")
                consumers[src].append(n.id)
        outputs = [n.id for n in given if not consumers[n.id]]
        if len(outputs) != 1:
            raise ValueError(
                f"a graph needs exactly one output node (no consumers); "
                f"got {outputs}")
        # structural kinds can never be sources: Node arity validation
        # already guarantees pool/add nodes carry inputs, so every source
        # is an op node with a declared input shape

        # deterministic Kahn: emit the earliest given ready node
        emitted: Dict[str, int] = {}
        order: List[Node] = []
        while len(order) < len(given):
            progressed = False
            for n in given:
                if n.id in emitted:
                    continue
                if all(src in emitted for src in n.inputs):
                    emitted[n.id] = len(order)
                    order.append(n)
                    progressed = True
            if not progressed:
                cyclic = sorted(set(by_id) - set(emitted))
                raise ValueError(f"graph has a cycle through {cyclic}")

        self.nodes: Tuple[Node, ...] = tuple(order)
        self._by_id = by_id
        self._consumers = {nid: tuple(c) for nid, c in consumers.items()}
        self._out_shapes: Dict[str, Tuple[int, ...]] = {}

    # ----------------------------------------------------------- accessors
    def __len__(self) -> int:
        return len(self.nodes)

    def __iter__(self) -> Iterator[Node]:
        return iter(self.nodes)

    def node(self, node_id: str) -> Node:
        try:
            return self._by_id[node_id]
        except KeyError:
            raise KeyError(f"no node {node_id!r}; "
                           f"ids: {[n.id for n in self.nodes]}") from None

    def consumers(self, node_id: str) -> Tuple[str, ...]:
        """Ids of the nodes consuming `node_id`'s output."""
        self.node(node_id)
        return self._consumers[node_id]

    def sole_consumer(self, node_id: str) -> Optional[Node]:
        """The single consumer of a node's output, or None on fan-out /
        graph output — the gather-elision predicate's first half."""
        cons = self.consumers(node_id)
        if len(cons) != 1:
            return None
        return self._by_id[cons[0]]

    @property
    def output(self) -> Node:
        # every node feeds the unique sink (single-output validation), so
        # the sink is always last in topological order
        return self.nodes[-1]

    @property
    def sources(self) -> Tuple[Node, ...]:
        return tuple(n for n in self.nodes if not n.inputs)

    def op_nodes(self) -> List[Node]:
        """Nodes carrying a kernel-registry op, in topological order."""
        return [n for n in self.nodes if n.op is not None]

    def splittable_nodes(self) -> List[Node]:
        """The partitioner's domain: channel-splittable op nodes."""
        return [n for n in self.nodes if n.splittable]

    # ----------------------------------------------------- shape inference
    def input_shape(self, node_id: str) -> Optional[Tuple[int, ...]]:
        """Declared input shape of an op node (None for pool/add, whose
        input is whatever their producers emit)."""
        n = self.node(node_id)
        if n.op is None:
            return None
        return tuple(registry.get(n.kind).input_shape(n.op))

    def output_shape(self, node_id: str) -> Tuple[int, ...]:
        """Inferred output shape of a node.  Op nodes declare theirs via
        the kernel registry; pool recovers its spatial extent from the
        recorded byte count and the producer's channel count; add emits
        its producers' (equal) shape."""
        if node_id in self._out_shapes:
            return self._out_shapes[node_id]
        n = self.node(node_id)
        if n.op is not None:
            shape = tuple(registry.get(n.kind).output_shape(n.op))
        elif n.kind == "pool":
            prev = self.output_shape(n.inputs[0])
            c_prev = int(prev[-1])
            edge = pool_out_edge(n.pool_bytes, c_prev)
            shape = (edge, edge, c_prev)
        else:                                   # add
            shapes = {self.output_shape(src) for src in n.inputs}
            if len(shapes) != 1:
                raise ValueError(
                    f"add node {n.id!r} joins mismatched shapes "
                    f"{sorted(shapes)}")
            shape = shapes.pop()
        self._out_shapes[node_id] = shape
        return shape

    def check_shapes(self) -> None:
        """Strict edge validation: every op node's declared input shape
        must equal its producer's inferred output shape.  Legacy unit
        chains are deliberately *not* held to this (ResNet projection
        shortcuts re-materialize shapes at runtime); graphs built by
        `from_model` pass it.

        One principled relaxation: attention/ssm ops are charged
        *per sequence* (their typed ops carry no batch axis), so an edge
        touching one may carry a whole batch of rows — trailing dims must
        match exactly and the leading dims must divide (the executor
        re-materializes to the declared contract at such boundaries)."""
        for n in self.nodes:
            self.output_shape(n.id)             # forces add-join checks
            declared = self.input_shape(n.id)
            if declared is None or not n.inputs:
                continue
            src = self.node(n.inputs[0])
            produced = self.output_shape(n.inputs[0])
            if tuple(produced) == tuple(declared):
                continue
            per_seq = n.kind in ("attention", "ssm") or \
                src.kind in ("attention", "ssm")
            a, b = tuple(produced), tuple(declared)
            if per_seq and len(a) == len(b) and a[1:] == b[1:] and \
                    min(a[0], b[0]) > 0 and max(a[0], b[0]) % \
                    min(a[0], b[0]) == 0:
                continue
            raise ValueError(
                f"edge {n.inputs[0]!r} -> {n.id!r}: producer emits "
                f"{tuple(produced)} but the consumer declares "
                f"{tuple(declared)}")

    # --------------------------------------------------------- segmentation
    def _chains_edge(self, producer: Node, consumer: Node) -> bool:
        """Whether the producer->consumer edge can stay group-local: the
        consumer is an op node whose declared input shape equals the
        producer's inferred output shape exactly (any adaptation is a true
        reshard boundary)."""
        declared = self.input_shape(consumer.id)
        if declared is None:
            return consumer.kind == "add"       # adds join materialized-
        return tuple(self.output_shape(producer.id)) == tuple(declared)

    def segments(self, coexec: Collection[str]) -> List[Segment]:
        """Partition the topological order into executable segments.

        `coexec` names the nodes the plan co-executes (channel-split).
        Fusable nodes — co-executed ops and residual "add" joins — merge
        into maximal "fused" runs; every other node (pool, exclusive or
        unsplit op kinds) is a singleton segment.  A fused run is cut
        after a node exactly at the unfused executor's materialization
        points:

          * fan-out or graph output (`len(consumers) != 1` — a shared
            split output is gathered once),
          * the sole consumer is not fusable (pool/exclusive boundary),
          * the sole consumer declares an input shape that differs from
            the producer's output (shape-adaptation boundary),

        plus a convexity pass: every non-final node of a fused run must
        have all of its consumers inside the run (the run has a single
        published output), so runs broken up by interleaved non-fusable
        nodes split rather than leak interior values.

        The returned segments cover `self.nodes` exactly, in order.
        """
        coexec = frozenset(coexec)

        def fusable(n: Node) -> bool:
            return n.id in coexec or n.kind == "add"

        runs: List[Tuple[str, List[Node]]] = []
        cur: List[Node] = []
        for n in self.nodes:
            if not fusable(n):
                if cur:
                    runs.append((SEGMENT_FUSED, cur))
                    cur = []
                kind = SEGMENT_POOL if n.kind == "pool" else SEGMENT_EXCLUSIVE
                runs.append((kind, [n]))
                continue
            cur.append(n)
            cons = self.consumers(n.id)
            cut = len(cons) != 1
            if not cut:
                nxt = self._by_id[cons[0]]
                cut = not fusable(nxt) or not self._chains_edge(n, nxt)
            if cut:
                runs.append((SEGMENT_FUSED, cur))
                cur = []
        if cur:
            runs.append((SEGMENT_FUSED, cur))

        def convex(run: List[Node]) -> List[List[Node]]:
            ids = {n.id for n in run}
            for i, n in enumerate(run[:-1]):
                if not all(c in ids for c in self.consumers(n.id)):
                    return convex(run[:i + 1]) + convex(run[i + 1:])
            return [run]

        out: List[Segment] = []
        for kind, run in runs:
            parts = convex(run) if kind == SEGMENT_FUSED else [run]
            out += [Segment(kind=kind, node_ids=tuple(n.id for n in part))
                    for part in parts]
        return out

    def elided(self, coexec: Collection[str]) -> FrozenSet[str]:
        """The co-executed nodes whose output stays group-local in the
        chained walk: their sole consumer is a co-executed op node whose
        declared input shape matches exactly (the executor's gather-elision
        predicate as a pure graph property, for batch-1 activations)."""
        coexec = frozenset(coexec)
        out = set()
        for n in self.nodes:
            if n.id not in coexec:
                continue
            u = self.sole_consumer(n.id)
            if (u is not None and u.id in coexec and u.op is not None
                    and self._chains_edge(n, u)):
                out.add(n.id)
        return frozenset(out)

    def materialization_points(self, coexec: Collection[str]
                               ) -> FrozenSet[str]:
        """The co-executed nodes whose split output must be gathered —
        exactly the segment boundaries the fused executor reshards at."""
        coexec = frozenset(coexec)
        return coexec - self.elided(coexec)

    # --------------------------------------------------------- unit compat
    def is_unit_chain(self) -> bool:
        """Whether this graph is exactly a legacy unit list: a linear
        chain of conv/linear/pool nodes (the pre-IR representable set)."""
        prev: Optional[Node] = None
        for n in self.nodes:
            if n.kind not in ("conv", "linear", "pool"):
                return False
            want = () if prev is None else (prev.id,)
            if n.inputs != want:
                return False
            if prev is not None and len(self._consumers[prev.id]) != 1:
                return False
            prev = n
        return True

    def to_units(self) -> List[Unit]:
        """Lower back to the legacy unit list (unit chains only)."""
        if not self.is_unit_chain():
            raise ValueError(
                "graph is not a legacy unit chain (fan-out, add joins, or "
                "attention/ssm nodes have no List[Unit] spelling)")
        return [(n.kind, n.pool_bytes if n.kind == "pool" else n.op)
                for n in self.nodes]

    # ---------------------------------------------------------- fingerprint
    def fingerprint(self) -> str:
        """Content-addressed digest of the graph structure.

        Unit chains reproduce `runtime.plan.network_fingerprint(units)`
        bit-for-bit — the versioned compatibility rule that keeps every
        legacy plan-cache entry warm.  Real DAGs canonicalize as
        ["graph", schema, [[kind, payload, input positions], ...]] with
        nodes addressed by topological position, so renaming ids never
        changes the digest.
        """
        if self.is_unit_chain():
            canon: Any = []
            for n in self.nodes:
                if n.kind == "pool":
                    canon.append(["pool", int(n.pool_bytes)])
                else:
                    canon.append([n.kind, registry.op_to_json(n.op)])
        else:
            pos = {n.id: i for i, n in enumerate(self.nodes)}
            canon = ["graph", GRAPH_SCHEMA_VERSION,
                     [[n.kind,
                       (registry.op_to_json(n.op) if n.op is not None
                        else int(n.pool_bytes)),
                       [pos[src] for src in n.inputs]]
                      for n in self.nodes]]
        blob = json.dumps(canon, sort_keys=True, separators=(",", ":"))
        return hashlib.blake2b(blob.encode(), digest_size=12).hexdigest()

    # -------------------------------------------------------------- codecs
    def to_json(self) -> Dict[str, Any]:
        return {"schema_version": GRAPH_SCHEMA_VERSION,
                "nodes": [n.to_json() for n in self.nodes]}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Graph":
        return Graph([Node.from_json(n) for n in d["nodes"]])

    def __repr__(self) -> str:
        kinds: Dict[str, int] = {}
        for n in self.nodes:
            kinds[n.kind] = kinds.get(n.kind, 0) + 1
        body = ", ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
        return (f"Graph({len(self.nodes)} nodes: {body}, "
                f"fingerprint={self.fingerprint()})")


def from_units(units: Sequence[Unit]) -> Graph:
    """Lower a legacy unit list into a linear-chain graph.

    Node ids are canonical positions ("n0", "n1", ...), which is what lets
    plans over these graphs serialize in the legacy schedule format (and
    legacy plans reconstruct their graph) with zero ambiguity.
    """
    nodes: List[Node] = []
    prev: Tuple[str, ...] = ()
    for i, (kind, payload) in enumerate(units):
        nid = f"n{i}"
        if kind == "pool":
            nodes.append(Node(id=nid, kind="pool",
                              pool_bytes=int(payload), inputs=prev))
        else:
            nodes.append(Node(id=nid, kind=kind, op=payload, inputs=prev))
        prev = (nid,)
    return Graph(nodes)
