"""Graph frontends: build planner-ready graphs from model configurations.

`from_model(name_or_config)` lowers a `repro.models.config.ModelConfig`
into a decoder-block op graph — the workload class "Characterizing Mobile
SoC for Accelerating Heterogeneous LLM Inference" identifies as the next
co-execution target:

  * **attention blocks** — q projection (splittable linear), a decode
    "attention" node over the block's KV cache (`kernels/decode_attention`,
    exclusive), o projection, residual add, then the MLP pair (up/down
    projections, both splittable) with its own residual;
  * **SSM blocks** (`ssm_kind` configs) — inner projection, a chunked-SSD
    "ssm" node (`kernels/ssd_chunk`, exclusive), out projection, residual;
  * **hybrid** (`attn_every`, zamba-style) — SSM blocks with a shared
    attention block every `attn_every` layers.

The residual edges give every block real fan-out (the block input feeds
both the first projection and the residual add), which is exactly what the
executor's gather-once rule is for.  MoE routing and normalization layers
are not modeled — they are latency-negligible at decode batch 1 next to
the projections this planner splits.

Model names resolve through `repro.models.registry` (ARCH_IDS + aliases);
`TINY_CONFIGS` adds CPU-smoke-sized decoder configs ("tiny_decoder",
"tiny_ssm", "tiny_hybrid") used by tests and the CI graph smoke.
"""
from __future__ import annotations

from typing import List, Tuple, Union

from repro.core.types import AttnOp, LinearOp, SSMOp
from repro.graph.ir import Graph, Node
from repro.models.config import ModelConfig

#: CPU-smoke-sized decoder configs, planable+executable in seconds
TINY_CONFIGS = {
    "tiny_decoder": ModelConfig(
        name="tiny_decoder", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256),
    "tiny_ssm": ModelConfig(
        name="tiny_ssm", family="ssm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        attn_kind="none", ssm_kind="mamba2", ssm_state=16, ssm_head_dim=32),
    "tiny_hybrid": ModelConfig(
        name="tiny_hybrid", family="hybrid", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        ssm_kind="mamba2", ssm_state=16, ssm_head_dim=32, attn_every=2),
}


def model_names() -> List[str]:
    """Every name `from_model` resolves (tiny configs + model registry)."""
    from repro.models.registry import ALIASES, ARCH_IDS
    return sorted(set(TINY_CONFIGS) | set(ARCH_IDS) | set(ALIASES))


def resolve_config(name_or_config: Union[str, ModelConfig]) -> ModelConfig:
    if isinstance(name_or_config, ModelConfig):
        return name_or_config
    if name_or_config in TINY_CONFIGS:
        return TINY_CONFIGS[name_or_config]
    from repro.models.registry import ALIASES, ARCH_IDS
    if name_or_config in ARCH_IDS or name_or_config in ALIASES:
        from repro.models.registry import get_config
        return get_config(name_or_config)
    raise ValueError(f"unknown model {name_or_config!r}; "
                     f"choices: {model_names()}")


def _attention_block(prev: str, i: int, cfg: ModelConfig, cache_len: int,
                     batch: int, nodes: List[Node]) -> str:
    d = cfg.d_model
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window or 0
    nodes += [
        Node(id=f"b{i}.q_proj", kind="linear",
             op=LinearOp(batch, d, h * hd), inputs=(prev,)),
        Node(id=f"b{i}.attn", kind="attention",
             op=AttnOp(H=h, S=cache_len, KV=kv, hd=hd, window=window),
             inputs=(f"b{i}.q_proj",)),
        Node(id=f"b{i}.o_proj", kind="linear",
             op=LinearOp(batch, h * hd, d), inputs=(f"b{i}.attn",)),
        Node(id=f"b{i}.attn_res", kind="add",
             inputs=(prev, f"b{i}.o_proj")),
        Node(id=f"b{i}.mlp_up", kind="linear",
             op=LinearOp(batch, d, cfg.d_ff), inputs=(f"b{i}.attn_res",)),
        Node(id=f"b{i}.mlp_down", kind="linear",
             op=LinearOp(batch, cfg.d_ff, d), inputs=(f"b{i}.mlp_up",)),
        Node(id=f"b{i}.mlp_res", kind="add",
             inputs=(f"b{i}.attn_res", f"b{i}.mlp_down")),
    ]
    return f"b{i}.mlp_res"


def _ssm_block(prev: str, i: int, cfg: ModelConfig, tokens: int,
               batch: int, nodes: List[Node]) -> str:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim or 64
    heads = max(1, d_in // hd)
    d_in = heads * hd                     # re-align to whole heads
    n = cfg.ssm_state or 16
    rows = tokens * batch
    nodes += [
        Node(id=f"b{i}.in_proj", kind="linear",
             op=LinearOp(rows, d, d_in), inputs=(prev,)),
        Node(id=f"b{i}.ssm", kind="ssm",
             op=SSMOp(T=tokens, H=heads, hd=hd, N=n),
             inputs=(f"b{i}.in_proj",)),
        Node(id=f"b{i}.out_proj", kind="linear",
             op=LinearOp(rows, d_in, d), inputs=(f"b{i}.ssm",)),
        Node(id=f"b{i}.res", kind="add",
             inputs=(prev, f"b{i}.out_proj")),
    ]
    return f"b{i}.res"


def from_model(name_or_config: Union[str, ModelConfig], *,
               blocks: int = 1, cache_len: int = 128,
               tokens: int = 1, batch: int = 1) -> Graph:
    """Build a decoder-block graph for one decode step of a model config.

    * `blocks` — decoder blocks to chain (default 1: the per-block
      workload is what the planner splits; totals scale linearly).
    * `cache_len` — KV-cache length the attention nodes attend over
      (the latency-dominant decode knob).
    * `tokens` — tokens scanned per step by SSM blocks (1 = pure decode;
      larger values model chunked prefill, where the scan is long enough
      for a state-split to pay for its sync).
    * `batch` — decode sequences per step (serving buckets).  Batch rows
      fold into the row dimension of every projection — the splittable,
      latency-dominant work — while attention/ssm nodes stay charged
      per-sequence (their typed ops carry no batch axis; the exclusive
      kernel cost scales linearly and does not move split decisions).

    The entry node is a shared embedding-row projection (splittable), so
    every graph has a well-defined (batch, d_model) input contract.  The
    resulting graph passes strict `check_shapes()`.  Distinct (batch,
    cache_len) buckets produce distinct content-addressed fingerprints,
    so a plan portfolio's entries never alias in the plan cache.
    """
    cfg = resolve_config(name_or_config)
    tokens = max(1, tokens)
    batch = max(1, batch)
    if tokens > 1 and (not cfg.ssm_kind or cfg.attn_every):
        raise ValueError(
            "tokens > 1 (chunked prefill) is only modeled for pure-SSM "
            "configs; attention blocks decode one position at a time")
    d = cfg.d_model
    nodes: List[Node] = [
        Node(id="embed", kind="linear", op=LinearOp(tokens * batch, d, d),
             inputs=()),
    ]
    prev = "embed"
    for i in range(max(1, blocks)):
        if cfg.ssm_kind and cfg.attn_every:
            is_attn = (i % cfg.attn_every) == (cfg.attn_every - 1)
        elif cfg.ssm_kind:
            is_attn = False
        else:
            is_attn = True
        if is_attn and cfg.attn_kind != "none":
            prev = _attention_block(prev, i, cfg, cache_len, batch, nodes)
        else:
            prev = _ssm_block(prev, i, cfg, tokens, batch, nodes)
    graph = Graph(nodes)
    graph.check_shapes()
    return graph


def fan_out_demo(c: int = 48) -> Tuple[Graph, str]:
    """A minimal fan-out graph (one producer, two consumers, one join) —
    the executor's gather-once acceptance shape.  Returns (graph, id of
    the fanned-out producer)."""
    nodes = [
        Node(id="a", kind="linear", op=LinearOp(4, 32, c), inputs=()),
        Node(id="left", kind="linear", op=LinearOp(4, c, c),
             inputs=("a",)),
        Node(id="right", kind="linear", op=LinearOp(4, c, c),
             inputs=("a",)),
        Node(id="join", kind="add", inputs=("left", "right")),
    ]
    return Graph(nodes), "a"
