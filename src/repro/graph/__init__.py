"""`repro.graph` — the typed op-graph IR.

`Graph` / `Node` (ir.py) replace the flat legacy ``List[Unit]`` as the
network representation the planner, plan cache, executor, and measurement
layers consume.  Frontends lower into it:

  * `from_units(units)` — exact compat path for the paper's conv nets
    (fingerprint-identical to the legacy unit-list digest, so existing
    plan caches stay warm);
  * `from_model(name_or_config)` — decoder-block graphs (attention via
    `kernels/decode_attention`, SSM via `kernels/ssd_chunk`) from
    `repro.models` configs;
  * direct `Graph([Node(...), ...])` construction.

Exports resolve lazily (PEP 562): importing `repro.graph` (or building
graphs from units) never imports jax or the model zoo — `from_model`
resolves the model registry on first use.
"""
import importlib

_EXPORTS = {
    "GRAPH_SCHEMA_VERSION": "repro.graph.ir",
    "STRUCTURAL_KINDS": "repro.graph.ir",
    "Graph": "repro.graph.ir",
    "Node": "repro.graph.ir",
    "SEGMENT_EXCLUSIVE": "repro.graph.ir",
    "SEGMENT_FUSED": "repro.graph.ir",
    "SEGMENT_POOL": "repro.graph.ir",
    "Segment": "repro.graph.ir",
    "from_units": "repro.graph.ir",
    "TINY_CONFIGS": "repro.graph.frontends",
    "fan_out_demo": "repro.graph.frontends",
    "from_model": "repro.graph.frontends",
    "model_names": "repro.graph.frontends",
    "resolve_config": "repro.graph.frontends",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    if name in _EXPORTS:
        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return __all__
