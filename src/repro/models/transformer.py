"""Generic decoder-only transformer stack.

Layer heterogeneity (dense vs MoE, local vs global attention, MLA) is
expressed as a repeating *pattern* of block kinds; parameters for each
pattern position are stacked over the repeat axis so the whole stack runs
as one `lax.scan` per pattern position — this keeps HLO size and compile
time bounded even for 126-layer models lowered on 512 host devices.

Examples:
  llama3-405b:   prologue=[]            pattern=[gqa+mlp] x126
  deepseek-v2:   prologue=[mla+mlp]     pattern=[mla+moe] x26
  llama4-scout:  prologue=[]            pattern=[gqa+mlp, gqa+moe] x24
  gemma3-12b:    prologue=[]            pattern=[5 x local(gqa+mlp),
                                                 1 x global(gqa+mlp)] x8
"""
from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (AttnSpec, attention_decode, attention_full,
                                 init_attention, init_mlp, mlp, rms_norm)
from repro.sharding.ctx import batch_axes, constrain

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class BlockKind:
    attn: str                    # 'gqa' | 'mla'
    ffn: str                     # 'mlp' | 'moe'
    window: int = 0              # sliding window (0 = full)


def layer_program(cfg: ModelConfig) -> Tuple[List[BlockKind],
                                             List[BlockKind], int]:
    """Returns (prologue_blocks, pattern_blocks, n_repeats)."""
    window = cfg.sliding_window
    attn = cfg.attn_kind
    if cfg.is_moe:
        if cfg.first_dense_layers:
            pro = [BlockKind(attn, "mlp")] * cfg.first_dense_layers
            n = cfg.n_layers - cfg.first_dense_layers
            return pro, [BlockKind(attn, "moe")], n
        if cfg.moe_interleave > 1:
            pat = [BlockKind(attn, "mlp")] * (cfg.moe_interleave - 1) \
                + [BlockKind(attn, "moe")]
            assert cfg.n_layers % cfg.moe_interleave == 0
            return [], pat, cfg.n_layers // cfg.moe_interleave
        return [], [BlockKind(attn, "moe")], cfg.n_layers
    if cfg.local_global_ratio:
        r = cfg.local_global_ratio
        pat = [BlockKind(attn, "mlp", window=window)] * r \
            + [BlockKind(attn, "mlp", window=0)]
        assert cfg.n_layers % (r + 1) == 0
        return [], pat, cfg.n_layers // (r + 1)
    return [], [BlockKind(attn, "mlp", window=window)], cfg.n_layers


def _attn_spec(cfg: ModelConfig, window: int) -> AttnSpec:
    return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.head_dim, qkv_bias=cfg.qkv_bias,
                    qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta,
                    sliding_window=window)


# ------------------------------------------------------------------ blocks
def init_block(rng, cfg: ModelConfig, kind: BlockKind, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    p: Params = {"ln1": jnp.ones((cfg.d_model,), dtype),
                 "ln2": jnp.ones((cfg.d_model,), dtype)}
    if kind.attn == "mla":
        p["attn"] = mla_mod.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = init_attention(k1, cfg.d_model,
                                   _attn_spec(cfg, kind.window), dtype)
    if kind.ffn == "moe":
        p["ffn"] = moe_mod.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def block_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  kind: BlockKind) -> Tuple[jax.Array, jax.Array]:
    x = constrain(x, batch_axes(), None, None)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind.attn == "mla":
        h = mla_mod.mla_full(p["attn"], h, cfg)
    else:
        h = attention_full(p["attn"], h, _attn_spec(cfg, kind.window))
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind.ffn == "moe":
        h, aux = moe_mod.moe_layer(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return x + h, aux


def block_prefill(p: Params, x: jax.Array, cfg: ModelConfig, kind: BlockKind,
                  start=None) -> Tuple[jax.Array, jax.Array,
                                       Tuple[jax.Array, jax.Array]]:
    """Like block_forward but also returns the (k, v)-like pair to cache."""
    from repro.models.layers import attention_prefill
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind.attn == "mla":
        h, kv = mla_mod.mla_prefill(p["attn"], h, cfg)
    else:
        h, kv = attention_prefill(p["attn"], h, _attn_spec(cfg, kind.window),
                                  start=start)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if kind.ffn == "moe":
        h, aux = moe_mod.moe_layer(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return x + h, aux, kv


def block_decode(p: Params, x: jax.Array, cfg: ModelConfig, kind: BlockKind,
                 cache: Tuple[jax.Array, jax.Array], pos: jax.Array,
                 start=None) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if kind.attn == "mla":
        h, ck, cv = mla_mod.mla_decode(p["attn"], h, cfg, cache[0], cache[1],
                                       pos)
    else:
        h, ck, cv = attention_decode(p["attn"], h, _attn_spec(cfg,
                                                              kind.window),
                                     cache[0], cache[1], pos, start=start)
    x = x + h
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind.ffn == "moe":
        h, _ = moe_mod.moe_layer(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return x + h, (ck, cv)


# ------------------------------------------------------------------- model
class TransformerModel:
    """Decoder-only LM with the uniform Model API (see registry.py)."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.prologue, self.pattern, self.n_repeats = layer_program(cfg)
        dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]
        self.dtype = dt

    # ------------------------------------------------------------- params
    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_out, k_pro, k_pat = jax.random.split(rng, 4)
        params: Params = {
            "embed": jax.random.normal(
                k_emb, (cfg.vocab_size, cfg.d_model), self.dtype) * 0.02,
            "unembed": jax.random.normal(
                k_out, (cfg.d_model, cfg.vocab_size), self.dtype)
            * (float(1.0 / np.sqrt(cfg.d_model))),
            "ln_f": jnp.ones((cfg.d_model,), self.dtype),
        }
        params["prologue"] = [
            init_block(k, cfg, kind, self.dtype)
            for k, kind in zip(jax.random.split(k_pro,
                                                max(1, len(self.prologue))),
                               self.prologue)]
        # pattern params: one stacked pytree per pattern position
        pat = []
        for i, kind in enumerate(self.pattern):
            keys = jax.random.split(jax.random.fold_in(k_pat, i),
                                    self.n_repeats)
            per_layer = [init_block(k, cfg, kind, self.dtype) for k in keys]
            pat.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer))
        params["pattern"] = pat
        return params

    # ------------------------------------------------------------ forward
    def forward(self, params: Params, tokens: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
        """tokens (B, T) -> (logits (B,T,V), aux_loss)."""
        cfg = self.cfg
        x = constrain(params["embed"][tokens], batch_axes(), None, None)
        aux_total = jnp.zeros((), jnp.float32)
        for p, kind in zip(params["prologue"], self.prologue):
            x, aux = block_forward(p, x, cfg, kind)
            aux_total += aux

        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)

        @functools.partial(jax.checkpoint, prevent_cse=False, policy=policy)
        def scan_body(carry, layer_params):
            # rematerialized: backward saves only the per-layer carry, not
            # the block-internal activations (critical at 4k x 256 batch)
            x, aux_total = carry
            for p, kind in zip(layer_params, self.pattern):
                x, aux = block_forward(p, x, cfg, kind)
                aux_total += aux
            return (x, aux_total), None

        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, aux_total), tuple(params["pattern"]))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = constrain(x @ params["unembed"], batch_axes(), None,
                           "model")
        return logits, aux_total

    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        tokens = batch["tokens"]
        labels = batch["labels"]
        logits, aux = self.forward(params, tokens)
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return nll.mean() + 0.01 * aux

    # ------------------------------------------------------------ serving
    def cache_spec(self, batch: int, max_len: int):
        """Shapes/dtypes of the KV cache pytree."""
        cfg = self.cfg
        if cfg.attn_kind == "mla":
            k_shape = (batch, max_len, cfg.kv_lora_rank)
            v_shape = (batch, max_len, cfg.qk_rope_head_dim)
        else:
            k_shape = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
            v_shape = k_shape
        per_block = lambda: (jnp.zeros(k_shape, self.dtype),   # noqa: E731
                             jnp.zeros(v_shape, self.dtype))
        pro = [per_block() for _ in self.prologue]
        pat = [jax.tree.map(
            lambda x: jnp.zeros((self.n_repeats,) + x.shape, x.dtype),
            per_block()) for _ in self.pattern]
        return {"prologue": pro, "pattern": pat}

    def init_cache(self, batch: int, max_len: int):
        return self.cache_spec(batch, max_len)

    @property
    def pad_aware(self) -> bool:
        """True when prefill/decode accept a per-row `start` pad boundary
        (the GQA attention path; MLA caches latents and cannot mask pads
        without re-deriving per-row keys)."""
        kinds = self.prologue + self.pattern
        return all(k.attn != "mla" for k in kinds)

    # decode_step accepts a (B,) pos vector (one timeline per batch slot)
    # on the same attention paths that support pad masking
    per_slot_pos = pad_aware

    def _check_padded(self, start) -> None:
        if start is not None and not self.pad_aware:
            raise ValueError("per-row start masking requires pad_aware "
                             "attention (gqa); this stack contains mla")

    def prefill(self, params: Params, tokens: jax.Array, cache,
                start=None) -> Tuple[jax.Array, Any]:
        """Full-sequence causal pass that also fills the KV cache for the
        first T positions.  Returns (last-position logits, filled cache).
        `start` (B,) marks each row's first real token in a left-padded
        batch; positions before it are masked out of every softmax."""
        cfg = self.cfg
        self._check_padded(start)
        x = params["embed"][tokens]

        def fill(c, kv):
            return jax.lax.dynamic_update_slice(
                c, kv.astype(c.dtype), (0,) * c.ndim)

        new_pro = []
        for p, kind, c in zip(params["prologue"], self.prologue,
                              cache["prologue"]):
            x, _, kv = block_prefill(p, x, cfg, kind, start=start)
            new_pro.append((fill(c[0], kv[0]), fill(c[1], kv[1])))

        def scan_body(x, scanned):
            layer_params, layer_cache = scanned
            new_cache = []
            for p, kind, c in zip(layer_params, self.pattern, layer_cache):
                x, _, kv = block_prefill(p, x, cfg, kind, start=start)
                new_cache.append((fill(c[0], kv[0]), fill(c[1], kv[1])))
            return x, tuple(new_cache)

        x, new_pat = jax.lax.scan(
            scan_body, x, (tuple(params["pattern"]),
                           tuple(cache["pattern"])))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = x[:, -1, :] @ params["unembed"]
        return logits, {"prologue": new_pro, "pattern": list(new_pat)}

    def decode_step(self, params: Params, tokens: jax.Array, cache,
                    pos: jax.Array, start=None) -> Tuple[jax.Array, Any]:
        """tokens (B,1); pos: scalar int32 — position being written — or a
        (B,) vector when each batch slot runs its own timeline (continuous
        batching).  `start` (B,) masks cache entries before each row's
        first real token (left-padded batches)."""
        cfg = self.cfg
        self._check_padded(start)
        if jnp.ndim(pos) == 1 and not self.per_slot_pos:
            raise ValueError("per-slot pos vector requires gqa attention; "
                             "this stack contains mla")
        x = params["embed"][tokens]
        new_pro = []
        for p, kind, c in zip(params["prologue"], self.prologue,
                              cache["prologue"]):
            x, c2 = block_decode(p, x, cfg, kind, c, pos, start=start)
            new_pro.append(c2)

        def scan_body(x, scanned):
            layer_params, layer_cache = scanned
            new_cache = []
            for p, kind, c in zip(layer_params, self.pattern, layer_cache):
                x, c2 = block_decode(p, x, cfg, kind, c, pos, start=start)
                new_cache.append(c2)
            return x, tuple(new_cache)

        x, new_pat = jax.lax.scan(
            scan_body, x, (tuple(params["pattern"]),
                           tuple(cache["pattern"])))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        logits = x @ params["unembed"]
        return logits[:, 0, :], {"prologue": new_pro,
                                 "pattern": list(new_pat)}
