"""Whisper-style encoder-decoder transformer (arXiv:2212.04356).

Per the assignment carve-out, the mel-spectrogram + conv feature extractor
frontend is a STUB: the model consumes precomputed frame embeddings of shape
(B, encoder_seq, d_model).  Positional information is sinusoidal for both
encoder and decoder (the reference uses learned decoder embeddings; noted
in DESIGN.md — sinusoidal keeps the 32k/500k decode shapes lowerable
without a giant learned table).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (FLASH_THRESHOLD, DECODE_FLASH_THRESHOLD,
                                 AttnSpec, _causal_mask, _project_qkv,
                                 attention_scores, init_attention, init_mlp,
                                 mlp, rms_norm)

Params = Dict[str, Any]


def sinusoidal_positions(t0: int, t1: int, d: int) -> jax.Array:
    pos = jnp.arange(t0, t1, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def init_cross_attention(rng, d_model: int, spec: AttnSpec, dtype) -> Params:
    return init_attention(rng, d_model, spec, dtype)


def cross_attention(p: Params, x: jax.Array, kv_k: jax.Array,
                    kv_v: jax.Array, spec: AttnSpec) -> jax.Array:
    """x: (B,T,D) queries; kv_k/kv_v: (B,S,kv,hd) precomputed from enc."""
    b, t, _ = x.shape
    h, hd = spec.n_heads, spec.head_dim
    q = (x @ p["wq"]).reshape(b, t, h, hd)
    mask = jnp.ones((t, kv_k.shape[1]), bool)
    out = attention_scores(q, kv_k, kv_v, mask)
    return out.reshape(b, t, -1) @ p["wo"]


def cross_kv(p: Params, enc: jax.Array, spec: AttnSpec):
    b, s, _ = enc.shape
    kv, hd = spec.n_kv_heads, spec.head_dim
    k = (enc @ p["wk"]).reshape(b, s, kv, hd)
    v = (enc @ p["wv"]).reshape(b, s, kv, hd)
    return k, v


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.dtype = {"bfloat16": jnp.bfloat16,
                      "float32": jnp.float32}[cfg.dtype]

    def _spec(self, causal: bool) -> AttnSpec:
        cfg = self.cfg
        return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)

    def init(self, rng) -> Params:
        cfg = self.cfg
        d = cfg.d_model
        k_emb, k_out, k_enc, k_dec = jax.random.split(rng, 4)
        spec = self._spec(False)

        enc_blocks = []
        for k in jax.random.split(k_enc, cfg.encoder_layers):
            k1, k2 = jax.random.split(k)
            enc_blocks.append({
                "ln1": jnp.ones((d,), self.dtype),
                "ln2": jnp.ones((d,), self.dtype),
                "attn": init_attention(k1, d, spec, self.dtype),
                "mlp": init_mlp(k2, d, cfg.d_ff, self.dtype),
            })
        dec_blocks = []
        for k in jax.random.split(k_dec, cfg.n_layers):
            k1, k2, k3 = jax.random.split(k, 3)
            dec_blocks.append({
                "ln1": jnp.ones((d,), self.dtype),
                "ln2": jnp.ones((d,), self.dtype),
                "ln3": jnp.ones((d,), self.dtype),
                "self_attn": init_attention(k1, d, spec, self.dtype),
                "cross_attn": init_cross_attention(k2, d, spec, self.dtype),
                "mlp": init_mlp(k3, d, cfg.d_ff, self.dtype),
            })
        return {
            "embed": jax.random.normal(k_emb, (cfg.vocab_size, d),
                                       self.dtype) * 0.02,
            "unembed": jax.random.normal(k_out, (d, cfg.vocab_size),
                                         self.dtype) * (float(1 / np.sqrt(d))),
            "ln_enc": jnp.ones((d,), self.dtype),
            "ln_dec": jnp.ones((d,), self.dtype),
            "encoder": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
            "decoder": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_blocks),
        }

    # ------------------------------------------------------------- encode
    def encode(self, params: Params, frames: jax.Array) -> jax.Array:
        """frames: (B, S_enc, D) stubbed conv-frontend embeddings."""
        cfg = self.cfg
        b, s, d = frames.shape
        x = frames.astype(self.dtype) \
            + sinusoidal_positions(0, s, d).astype(self.dtype)
        spec = self._spec(False)
        full = jnp.ones((s, s), bool)

        def body(x, p):
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            positions = jnp.zeros((b, s), jnp.int32)  # no rope in whisper
            q, k, v = _project_qkv(p["attn"], h, spec, positions)
            h = attention_scores(q, k, v, full).reshape(b, s, -1) \
                @ p["attn"]["wo"]
            x = x + h
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            return x + mlp(p["mlp"], h), None

        x, _ = jax.lax.scan(body, x, params["encoder"])
        return rms_norm(x, params["ln_enc"], cfg.norm_eps)

    # ------------------------------------------------------------ decoder
    def _dec_embed(self, params, tokens, pos0: int = 0):
        assert pos0 == 0
        d = self.cfg.d_model
        x = params["embed"][tokens]
        pe = sinusoidal_positions(0, tokens.shape[1], d)
        return x + pe.astype(x.dtype)

    def _decoder_stack(self, params, x, enc, mode, cache=None, pos=None):
        cfg = self.cfg
        b, t, d = x.shape
        spec = self._spec(True)
        ck_full, cv_full = cross_kv_all(params["decoder"]["cross_attn"],
                                        enc, spec)

        def body_train(x, scanned):
            p, ck, cv = scanned
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            positions = jnp.zeros((b, t), jnp.int32)
            q, k, v = _project_qkv(p["self_attn"], h, spec, positions)
            if t >= FLASH_THRESHOLD:
                from repro.models.flash import flash_full
                h = flash_full(q, k, v)
            else:
                h = attention_scores(q, k, v, _causal_mask(t, t))
            h = h.reshape(b, t, -1) @ p["self_attn"]["wo"]
            x = x + h
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            h = cross_attention(p["cross_attn"], h, ck, cv, spec)
            x = x + h
            h = rms_norm(x, p["ln3"], cfg.norm_eps)
            return x + mlp(p["mlp"], h), None

        if mode == "train":
            x, _ = jax.lax.scan(body_train, x,
                                (params["decoder"], ck_full, cv_full))
            return x, None

        def body_serve(x, scanned):
            p, ck, cv, sk, sv = scanned
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            positions = jnp.zeros((b, t), jnp.int32)
            q, k, v = _project_qkv(p["self_attn"], h, spec, positions)
            if mode == "prefill":
                sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype),
                                                  (0, 0, 0, 0))
                sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype),
                                                  (0, 0, 0, 0))
                if t >= FLASH_THRESHOLD:
                    from repro.models.flash import flash_full
                    h = flash_full(q, k, v)
                else:
                    h = attention_scores(q, k, v, _causal_mask(t, t))
            else:
                sk = jax.lax.dynamic_update_slice(sk, k.astype(sk.dtype),
                                                  (0, pos, 0, 0))
                sv = jax.lax.dynamic_update_slice(sv, v.astype(sv.dtype),
                                                  (0, pos, 0, 0))
                if sk.shape[1] >= DECODE_FLASH_THRESHOLD:
                    from repro.models.flash import flash_decode
                    h = flash_decode(q, sk.astype(q.dtype),
                                     sv.astype(q.dtype), pos)
                else:
                    mask = (jnp.arange(sk.shape[1]) <= pos)[None, :]
                    h = attention_scores(q, sk.astype(q.dtype),
                                         sv.astype(q.dtype), mask)
            h = h.reshape(b, t, -1) @ p["self_attn"]["wo"]
            x = x + h
            h = rms_norm(x, p["ln2"], cfg.norm_eps)
            h = cross_attention(p["cross_attn"], h, ck, cv, spec)
            x = x + h
            h = rms_norm(x, p["ln3"], cfg.norm_eps)
            return x + mlp(p["mlp"], h), (sk, sv)

        x, (sk, sv) = jax.lax.scan(
            body_serve, x, (params["decoder"], ck_full, cv_full,
                            cache["self_k"], cache["self_v"]))
        return x, {"self_k": sk, "self_v": sv, "enc": enc}

    # ---------------------------------------------------------------- api
    def loss(self, params: Params, batch) -> jax.Array:
        enc = self.encode(params, batch["frames"])
        x = self._dec_embed(params, batch["tokens"], 0)
        x, _ = self._decoder_stack(params, x, enc, "train")
        x = rms_norm(x, params["ln_dec"], self.cfg.norm_eps)
        logits = (x @ params["unembed"]).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return nll.mean()

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        L = cfg.n_layers
        return {
            "self_k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), self.dtype),
            "self_v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), self.dtype),
            "enc": jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                             self.dtype),
        }

    def prefill(self, params: Params, tokens: jax.Array, cache,
                frames: jax.Array):
        enc = self.encode(params, frames)
        x = self._dec_embed(params, tokens, 0)
        x, cache = self._decoder_stack(params, x, enc, "prefill",
                                       cache=cache)
        x = rms_norm(x, params["ln_dec"], self.cfg.norm_eps)
        return x[:, -1, :] @ params["unembed"], cache

    def decode_step(self, params: Params, tokens: jax.Array, cache,
                    pos: jax.Array):
        x = params["embed"][tokens] \
            + _sin_pos_dynamic(pos, self.cfg.d_model).astype(self.dtype)
        x, cache = self._decoder_stack(params, x, cache["enc"], "decode",
                                       cache=cache, pos=pos)
        x = rms_norm(x, params["ln_dec"], self.cfg.norm_eps)
        return x[:, 0, :] @ params["unembed"], cache


def cross_kv_all(cross_params, enc, spec):
    """Vectorized cross K/V for all decoder layers (L, B, S, kv, hd)."""
    def one(p):
        return cross_kv(p, enc, spec)
    return jax.vmap(lambda p: one(p))(cross_params)


def _sin_pos_dynamic(pos, d: int) -> jax.Array:
    """Sinusoidal embedding of one dynamic position: (1, 1, d)."""
    posf = jnp.asarray(pos, jnp.float32)
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)
    angle = posf / jnp.power(10000.0, dim / d)            # (d/2,)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])[None, None, :]
