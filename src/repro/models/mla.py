"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

K/V are projected through a low-rank latent `c_kv` (kv_lora_rank); the KV
cache stores only (c_kv, k_rope) — a ~4-8x cache compression.  Decode uses
the *absorbed* formulation: W_uk is folded into the query so attention runs
directly in the latent space, and W_uv is applied to the latent context.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rms_norm
from repro.sharding.ctx import batch_axes, constrain

Params = Dict[str, jax.Array]
_NEG_INF = -1e30


def init_mla(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, h, r = cfg.d_model, cfg.n_heads, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(rng, 6)
    s = float(1.0 / np.sqrt(d))
    sr = float(1.0 / np.sqrt(r))
    return {
        "wq": jax.random.normal(ks[0], (d, h * (nd + rd)), dtype) * s,
        "w_dkv": jax.random.normal(ks[1], (d, r), dtype) * s,
        "w_krope": jax.random.normal(ks[2], (d, rd), dtype) * s,
        "w_uk": jax.random.normal(ks[3], (r, h, nd), dtype) * sr,
        "w_uv": jax.random.normal(ks[4], (r, h, vd), dtype) * sr,
        "wo": jax.random.normal(ks[5], (h * vd, d), dtype)
        * (float(1.0 / np.sqrt(h * vd))),
        "kv_norm": jnp.ones((r,), dtype),
    }


def _queries(p: Params, x: jax.Array, cfg: ModelConfig, positions):
    b, t, _ = x.shape
    h = cfg.n_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = constrain(x @ p["wq"], batch_axes(), None, "model")
    q = q.reshape(b, t, h, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p: Params, x: jax.Array, cfg: ModelConfig, positions):
    c_kv = rms_norm(x @ p["w_dkv"], p["kv_norm"], cfg.norm_eps)
    k_rope = x @ p["w_krope"]                       # single shared rope head
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def _attend_latent(p: Params, q_nope, q_rope, c_kv, k_rope, mask,
                   cfg: ModelConfig) -> jax.Array:
    """Absorbed attention in latent space.
    q_nope: (B,T,H,nd)  q_rope: (B,T,H,rd)
    c_kv:   (B,S,r)     k_rope: (B,S,rd)
    """
    scale = float(1.0 / np.sqrt(cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))
    q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, p["w_uk"])   # absorb W_uk
    scores = (jnp.einsum("bthr,bsr->bhts", q_lat, c_kv)
              + jnp.einsum("bthd,bsd->bhts", q_rope, k_rope)) * scale
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32),
                       _NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q_nope.dtype)
    ctx = jnp.einsum("bhts,bsr->bthr", probs, c_kv)           # latent context
    out = jnp.einsum("bthr,rhv->bthv", ctx, p["w_uv"])
    b, t = out.shape[:2]
    return out.reshape(b, t, -1) @ p["wo"]


_FLASH_THRESHOLD = 2048
_DECODE_FLASH_THRESHOLD = 8192


def _attend_auto(p: Params, q_nope, q_rope, c_kv, k_rope,
                 cfg: ModelConfig) -> jax.Array:
    """Causal latent attention; memory-bounded flash path for long seqs."""
    b, t = q_nope.shape[:2]
    if t >= _FLASH_THRESHOLD:
        from repro.models.flash import flash_latent_full
        scale = float(1.0 / np.sqrt(cfg.qk_nope_head_dim
                                    + cfg.qk_rope_head_dim))
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, p["w_uk"])
        ctx = flash_latent_full(q_lat, q_rope, c_kv, k_rope, scale)
        out = jnp.einsum("bthr,rhv->bthv", ctx, p["w_uv"])
        return out.reshape(b, t, -1) @ p["wo"]
    mask = jnp.arange(t)[None, :] <= jnp.arange(t)[:, None]
    return _attend_latent(p, q_nope, q_rope, c_kv, k_rope, mask, cfg)


def mla_full(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)
    return _attend_auto(p, q_nope, q_rope, c_kv, k_rope, cfg)


def mla_decode(p: Params, x: jax.Array, cfg: ModelConfig,
               cache_ckv: jax.Array, cache_krope: jax.Array,
               pos: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B,1,D); cache_ckv: (B,S,r); cache_krope: (B,S,rd)."""
    b = x.shape[0]
    s = cache_ckv.shape[1]
    positions = jnp.broadcast_to(pos[None], (b, 1))
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, c_kv.astype(cache_ckv.dtype), (0, pos, 0))
    cache_krope = jax.lax.dynamic_update_slice(
        cache_krope, k_rope.astype(cache_krope.dtype), (0, pos, 0))
    if s >= _DECODE_FLASH_THRESHOLD:
        from repro.models.flash import flash_latent_decode
        scale = float(1.0 / np.sqrt(cfg.qk_nope_head_dim
                                    + cfg.qk_rope_head_dim))
        q_lat = jnp.einsum("bthn,rhn->bthr", q_nope, p["w_uk"])
        ctx = flash_latent_decode(q_lat, q_rope, cache_ckv.astype(x.dtype),
                                  cache_krope.astype(x.dtype), pos, scale)
        out = jnp.einsum("bthr,rhv->bthv", ctx, p["w_uv"])
        out = out.reshape(b, 1, -1) @ p["wo"]
    else:
        mask = (jnp.arange(s) <= pos)[None, :]
        out = _attend_latent(p, q_nope, q_rope, cache_ckv.astype(x.dtype),
                             cache_krope.astype(x.dtype), mask, cfg)
    return out, cache_ckv, cache_krope


def mla_prefill(p: Params, x: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Causal MLA returning (out, (c_kv, k_rope)) for the compressed cache."""
    b, t, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(t), (b, t))
    q_nope, q_rope = _queries(p, x, cfg, positions)
    c_kv, k_rope = _latents(p, x, cfg, positions)
    out = _attend_auto(p, q_nope, q_rope, c_kv, k_rope, cfg)
    return out, (c_kv, k_rope)
