"""Model registry: ModelConfig -> model object with the uniform API.

API (duck-typed; see TransformerModel / RWKVModel / ZambaModel /
EncDecModel):
    init(rng) -> params
    loss(params, batch) -> scalar           batch: tokens/labels (+frames)
    init_cache(batch, max_len) -> cache
    prefill(params, tokens, cache[, frames]) -> (logits, cache)
    decode_step(params, tokens, cache, pos) -> (logits, cache)
"""
from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig
from repro.models.encdec import EncDecModel
from repro.models.rwkv import RWKVModel
from repro.models.transformer import TransformerModel
from repro.models.zamba import ZambaModel

ARCH_IDS: List[str] = [
    "deepseek_v2_lite",
    "chameleon_34b",
    "llama3_405b",
    "gemma3_12b",
    "llama4_scout",
    "whisper_large_v3",
    "codeqwen15_7b",
    "rwkv6_1b6",
    "zamba2_7b",
    "qwen25_32b",
]

# CLI aliases (--arch accepts either form)
ALIASES = {
    "deepseek-v2-lite-16b": "deepseek_v2_lite",
    "chameleon-34b": "chameleon_34b",
    "llama3-405b": "llama3_405b",
    "gemma3-12b": "gemma3_12b",
    "llama4-scout-17b-a16e": "llama4_scout",
    "whisper-large-v3": "whisper_large_v3",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "zamba2-7b": "zamba2_7b",
    "qwen2.5-32b": "qwen25_32b",
}


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def build_model(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        return EncDecModel(cfg)
    if cfg.ssm_kind == "rwkv6":
        return RWKVModel(cfg)
    if cfg.attn_every:
        return ZambaModel(cfg)
    return TransformerModel(cfg)


def build(arch: str):
    cfg = get_config(arch)
    return cfg, build_model(cfg)
