"""Zamba2-style hybrid: Mamba2 backbone + a weight-SHARED attention block
applied every `attn_every` layers (arXiv:2411.15242).

Simplifications vs. the reference model (noted in DESIGN.md):
  * the shared block's per-application LoRA adapters are omitted;
  * the shared block input is the residual stream (not concat[x, x0]).

Layer program: n_groups = n_layers // attn_every; each group = one shared
attention application followed by a scan over `attn_every` stacked Mamba2
layers.  The shared attention keeps one KV cache per application.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import (AttnSpec, attention_decode, attention_full,
                                 attention_prefill, init_attention,
                                 init_mlp, mlp, rms_norm)
from repro.models.ssm import init_mamba2, mamba2_mix, mamba2_state_shapes

Params = Dict[str, Any]


class ZambaModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.attn_every > 0 and cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.n_groups = cfg.n_layers // cfg.attn_every
        self.dtype = {"bfloat16": jnp.bfloat16,
                      "float32": jnp.float32}[cfg.dtype]

    def _attn_spec(self) -> AttnSpec:
        cfg = self.cfg
        return AttnSpec(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta)

    def init(self, rng) -> Params:
        cfg = self.cfg
        k_emb, k_out, k_sh, k_m = jax.random.split(rng, 4)
        k_sa, k_sm = jax.random.split(k_sh)
        mamba_blocks = []
        for k in jax.random.split(k_m, cfg.n_layers):
            mamba_blocks.append({
                "ln": jnp.ones((cfg.d_model,), self.dtype),
                "mixer": init_mamba2(k, cfg, self.dtype),
            })
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *mamba_blocks)
        # reshape to (n_groups, attn_every, ...)
        stacked = jax.tree.map(
            lambda x: x.reshape((self.n_groups, cfg.attn_every)
                                + x.shape[1:]), stacked)
        return {
            "embed": jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                       self.dtype) * 0.02,
            "unembed": jax.random.normal(k_out, (cfg.d_model,
                                                 cfg.vocab_size),
                                         self.dtype)
            * (float(1.0 / np.sqrt(cfg.d_model))),
            "ln_f": jnp.ones((cfg.d_model,), self.dtype),
            "shared_attn": {
                "ln1": jnp.ones((cfg.d_model,), self.dtype),
                "ln2": jnp.ones((cfg.d_model,), self.dtype),
                "attn": init_attention(k_sa, cfg.d_model, self._attn_spec(),
                                       self.dtype),
                "mlp": init_mlp(k_sm, cfg.d_model, cfg.d_ff, self.dtype),
            },
            "mamba": stacked,
        }

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        ssm_shape, conv_shape = mamba2_state_shapes(cfg, batch)
        g, k = self.n_groups, cfg.attn_every
        return {
            "attn_k": jnp.zeros((g, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), self.dtype),
            "attn_v": jnp.zeros((g, batch, max_len, cfg.n_kv_heads,
                                 cfg.head_dim), self.dtype),
            "ssm": jnp.zeros((g, k) + ssm_shape, jnp.float32),
            "conv": jnp.zeros((g, k) + conv_shape, self.dtype),
        }

    def _mamba_group(self, group_params, x, ssm_states, conv_states):
        cfg = self.cfg

        def body(x, scanned):
            p, s, c = scanned
            h = rms_norm(x, p["ln"], cfg.norm_eps)
            h, s2, c2 = mamba2_mix(p["mixer"], h, cfg, s, c)
            return x + h, (s2, c2)

        x, (s2, c2) = jax.lax.scan(body, x,
                                   (group_params, ssm_states, conv_states))
        return x, s2, c2

    def _shared_attn(self, params, x, mode, cache_k=None, cache_v=None,
                     pos=None):
        cfg = self.cfg
        p = params["shared_attn"]
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        if mode == "full":
            h = attention_full(p["attn"], h, self._attn_spec())
            kv = None
        elif mode == "prefill":
            h, kv = attention_prefill(p["attn"], h, self._attn_spec())
        else:
            h, ck, cv = attention_decode(p["attn"], h, self._attn_spec(),
                                         cache_k, cache_v, pos)
            kv = (ck, cv)
        x = x + h
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        return x + mlp(p["mlp"], h), kv

    # ------------------------------------------------------------ forward
    def forward(self, params: Params, tokens: jax.Array):
        x = params["embed"][tokens]
        cache = self.init_cache(tokens.shape[0], 1)
        for g in range(self.n_groups):
            x, _ = self._shared_attn(params, x, "full")
            gp = jax.tree.map(lambda a, g=g: a[g], params["mamba"])
            x, _, _ = self._mamba_group(gp, x, cache["ssm"][g],
                                        cache["conv"][g])
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        return x @ params["unembed"], jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch) -> jax.Array:
        logits, _ = self.forward(params, batch["tokens"])
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["labels"][..., None],
                                   axis=-1)[..., 0]
        return nll.mean()

    def _run(self, params, x, cache, mode, pos=None):
        new_ak, new_av, new_ssm, new_conv = [], [], [], []
        for g in range(self.n_groups):
            if mode == "prefill":
                x, kv = self._shared_attn(params, x, "prefill")
                k_full = jax.lax.dynamic_update_slice(
                    cache["attn_k"][g], kv[0].astype(self.dtype),
                    (0, 0, 0, 0))
                v_full = jax.lax.dynamic_update_slice(
                    cache["attn_v"][g], kv[1].astype(self.dtype),
                    (0, 0, 0, 0))
                new_ak.append(k_full)
                new_av.append(v_full)
            else:
                x, (ck, cv) = self._shared_attn(
                    params, x, "decode", cache["attn_k"][g],
                    cache["attn_v"][g], pos)
                new_ak.append(ck)
                new_av.append(cv)
            gp = jax.tree.map(lambda a, g=g: a[g], params["mamba"])
            x, s2, c2 = self._mamba_group(gp, x, cache["ssm"][g],
                                          cache["conv"][g])
            new_ssm.append(s2)
            new_conv.append(c2)
        new_cache = {"attn_k": jnp.stack(new_ak),
                     "attn_v": jnp.stack(new_av),
                     "ssm": jnp.stack(new_ssm),
                     "conv": jnp.stack(new_conv)}
        return x, new_cache

    def prefill(self, params: Params, tokens: jax.Array, cache):
        x = params["embed"][tokens]
        x, cache = self._run(params, x, cache, "prefill")
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        return x[:, -1, :] @ params["unembed"], cache

    def decode_step(self, params: Params, tokens: jax.Array, cache,
                    pos: jax.Array):
        x = params["embed"][tokens]
        x, cache = self._run(params, x, cache, "decode", pos)
        x = rms_norm(x, params["ln_f"], self.cfg.norm_eps)
        return x[:, 0, :] @ params["unembed"], cache
