"""Mixture-of-Experts layer with capacity-based einsum dispatch.

Expert-parallel execution is the MoE analogue of the paper's co-execution:
output "channels" (here: experts) are partitioned across compute groups.
The dispatch uses the GShard/Switch dense-einsum formulation — one-hot
dispatch/combine tensors with a fixed per-expert capacity — because it
(1) lowers to all-to-all-style collectives under pjit when the expert axis
is sharded, and (2) keeps compiled FLOPs proportional to top-k (not to the
total expert count).

Aux losses: switch load-balance loss + router z-loss (returned to the
training loop).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import init_mlp, mlp
from repro.sharding.ctx import constrain

Params = Dict[str, jax.Array]


def init_moe(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(rng, 5)
    s_in, s_out = float(1.0 / np.sqrt(d)), float(1.0 / np.sqrt(ff))
    p: Params = {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, ff), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, ff), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, ff, d), dtype) * s_out,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d,
                               cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def moe_layer(p: Params, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 1.25) -> Tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (y, aux_loss).

    Dispatch is scatter/gather-based: tokens are written into a per-expert
    capacity buffer via scatter-add and read back via gather.  The earlier
    GShard-style dense (N, E, C) one-hot einsum dispatch made the
    llama4-scout prefill_32k dry-run collective-bound with a 2% useful-FLOP
    ratio (N=1M tokens -> the dispatch/combine tensors dwarf the expert
    math); the scatter form moves O(N*k*D) bytes instead of O(N*E*C)
    (EXPERIMENTS.md §Perf iteration B).

    With cfg.moe_local_dispatch the whole dispatch+expert+combine runs
    under a partial-manual shard_map over the batch axes so the scatter
    stays shard-local with a per-shard capacity slice (§Perf B2); expert
    weights remain on the auto model axis.
    """
    b, t, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    n = b * t
    xt = x.reshape(n, d)

    if cfg.moe_local_dispatch:
        from repro.sharding.ctx import batch_axes, current_mesh
        from jax.sharding import PartitionSpec as P
        mesh = current_mesh()
        axes = tuple(a for a in batch_axes()
                     if mesh is not None and a in mesh.shape)
        shards = 1
        for a in axes:
            shards *= mesh.shape[a]
        if mesh is not None and shards > 1 and n % shards == 0 \
                and (n // shards) * k >= e:
            cap_local = max(1, int(capacity_factor * (n // shards) * k / e))

            def local(xt_l):
                y_l, aux_l = _moe_core(p, xt_l, cfg, cap_local)
                return y_l, aux_l[None]

            y, aux = jax.shard_map(
                local, mesh=mesh,
                in_specs=P(axes),
                out_specs=(P(axes), P(axes)),
                axis_names=set(axes), check_vma=False)(xt)
            return y.reshape(b, t, d), aux.mean()

    capacity = max(1, int(capacity_factor * n * k / e))
    y, aux = _moe_core(p, xt, cfg, capacity)
    return y.reshape(b, t, d), aux


def _moe_core(p: Params, xt: jax.Array, cfg: ModelConfig,
              capacity: int) -> Tuple[jax.Array, jax.Array]:
    """Scatter dispatch -> expert FFNs -> gather combine, over flat tokens."""
    n, d = xt.shape
    e, k = cfg.n_experts, cfg.experts_per_token

    logits = (xt.astype(jnp.float32) @ p["router"])          # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalize

    # position of each (token, slot) in its expert's queue, via cumsum over
    # the (N*k, E) one-hot — O(N*E) ints, no capacity dimension
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)   # (N,k,E)
    flat_oh = onehot.reshape(n * k, e)
    pos_flat = jnp.cumsum(flat_oh, axis=0) - flat_oh
    pos = jnp.einsum("me,me->m", pos_flat, flat_oh)             # (N*k,)
    pos = pos.reshape(n, k).astype(jnp.int32)
    keep = (pos < capacity)                                     # (N,k) bool

    # scatter tokens into the (E*C, D) buffer; dropped tokens target a
    # sink row that is sliced away
    slot = jnp.where(keep, expert_idx * capacity + pos, e * capacity)
    buf = jnp.zeros((e * capacity + 1, d), xt.dtype)
    buf = buf.at[slot.reshape(-1)].add(
        jnp.repeat(xt, k, axis=0) if k > 1 else xt)
    xin = buf[:-1].reshape(e, capacity, d)

    xin = constrain(xin, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", xin, p["w_up"])
    h = constrain(h, "model", None, None)
    yout = constrain(jnp.einsum("ecf,efd->ecd", h, p["w_down"]),
                     "model", None, None)

    # gather back and combine with renormalized gates
    out_flat = yout.reshape(e * capacity, d)
    gathered = out_flat[jnp.minimum(slot, e * capacity - 1)]    # (N,k,D)
    w_comb = (gate_vals * keep).astype(gathered.dtype)
    y = jnp.einsum("nkd,nk->nd", gathered, w_comb)
    y = y.astype(xt.dtype)

    if "shared" in p:
        y = y + mlp(p["shared"], xt)

    # Switch load-balance loss + z-loss
    me = probs.mean(0)                                        # (E,)
    ce = onehot.sum(1).mean(0)                                # fraction routed
    aux = e * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return y, aux
