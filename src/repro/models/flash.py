"""Memory-bounded attention via double-chunked online softmax (pure JAX).

Naive (T, S) score materialization is impossible at the assigned production
shapes (32k prefill => exabyte-scale scores for llama3-405b), so the full-
sequence and decode attention paths switch to these flash-style routines
above a sequence threshold:

  * flash_full:   outer lax.scan over query chunks, inner lax.scan over key
    chunks, running (max, sum, acc) per query row.  Live intermediates are
    (bq, bk) score tiles per (batch, head) — MBs, not TBs.
  * flash_decode: single query position against a long cache, scanned over
    key chunks (the jnp twin of kernels/decode_attention).

Causality and sliding windows are positional masks applied per tile; whole
tiles that are fully masked still execute (uniform scan) — the cost model
treats this as the TPU analogue of workgroup padding waste.

Each query-chunk step is wrapped in jax.checkpoint so training at 4k keeps
only O(T * D) residuals per layer instead of O(T * S) probabilities.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

_NEG_INF = -1e30


def _tile_mask(q0, k0, bq, bk, window):
    q_pos = q0 + jnp.arange(bq)[:, None]
    k_pos = k0 + jnp.arange(bk)[None, :]
    m = k_pos <= q_pos
    if window > 0:
        m &= k_pos > q_pos - window
    return m


def flash_full(q: jax.Array, k: jax.Array, v: jax.Array, *,
               window: int = 0, bq: int = 1024, bk: int = 1024) -> jax.Array:
    """Causal GQA attention. q: (B,T,H,hd); k/v: (B,S,KV,hd) -> (B,T,H,hd)."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    bq = min(bq, t)
    bk = min(bk, s)
    assert t % bq == 0 and s % bk == 0, (t, bq, s, bk)
    nq, nk = t // bq, s // bk
    scale = float(1.0 / np.sqrt(hd))

    # (nq, B, bq, KV, g, hd)
    qc = q.reshape(b, nq, bq, kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kc = k.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def q_step(carry, xs):
        del carry
        qi, q_idx = xs                               # (B,bq,KV,g,hd)
        qi = qi.astype(jnp.float32) * scale

        def k_step(state, ys):
            m_run, l_run, acc = state
            kj, vj, k_idx = ys                       # (B,bk,KV,hd)
            scores = jnp.einsum("bqhgd,bkhd->bhgqk", qi,
                                kj.astype(jnp.float32))
            mask = _tile_mask(q_idx * bq, k_idx * bk, bq, bk, window)
            scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
            m_new = jnp.maximum(m_run, scores.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kv, g, bq), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, kv, g, bq, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, out.astype(q.dtype)             # (B,KV,g,bq,hd)

    _, chunks = jax.lax.scan(q_step, None, (qc, jnp.arange(nq)))
    # (nq, B, KV, g, bq, hd) -> (B, T, H, hd)
    out = chunks.transpose(1, 0, 4, 2, 3, 5).reshape(b, t, h, hd)
    return out


def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array, pos, *,
                 window: int = 0, bk: int = 2048) -> jax.Array:
    """One-token decode. q: (B,1,H,hd); k/v: (B,S,KV,hd) -> (B,1,H,hd)."""
    b, _, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    g = h // kv
    bk = min(bk, s)
    assert s % bk == 0
    nk = s // bk
    scale = float(1.0 / np.sqrt(hd))
    qf = q.reshape(b, kv, g, hd).astype(jnp.float32) * scale
    kc = k.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nk, bk, kv, hd).transpose(1, 0, 2, 3, 4)

    def k_step(state, ys):
        m_run, l_run, acc = state
        kj, vj, k_idx = ys
        scores = jnp.einsum("bhgd,bkhd->bhgk", qf, kj.astype(jnp.float32))
        k_pos = k_idx * bk + jnp.arange(bk)
        mask = k_pos <= pos
        if window > 0:
            mask &= k_pos > pos - window
        scores = jnp.where(mask[None, None, None], scores, _NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] \
            + jnp.einsum("bhgk,bkhd->bhgd", p, vj.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kv, g), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g), jnp.float32)
    a0 = jnp.zeros((b, kv, g, hd), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def flash_latent_full(q_lat: jax.Array, q_rope: jax.Array, c_kv: jax.Array,
                      k_rope: jax.Array, scale: float, *,
                      bq: int = 1024, bk: int = 1024
                      ) -> jax.Array:
    """Chunked MLA latent attention (causal).

    q_lat: (B,T,H,r) absorbed queries; q_rope: (B,T,H,rd);
    c_kv: (B,S,r); k_rope: (B,S,rd).  Returns latent context (B,T,H,r).
    """
    b, t, h, r = q_lat.shape
    s = c_kv.shape[1]
    bq = min(bq, t)
    bk = min(bk, s)
    assert t % bq == 0 and s % bk == 0
    nq, nk = t // bq, s // bk
    qlc = q_lat.reshape(b, nq, bq, h, r).transpose(1, 0, 2, 3, 4)
    qrc = q_rope.reshape(b, nq, bq, h, -1).transpose(1, 0, 2, 3, 4)
    ckc = c_kv.reshape(b, nk, bk, r).transpose(1, 0, 2, 3)
    krc = k_rope.reshape(b, nk, bk, -1).transpose(1, 0, 2, 3)

    @jax.checkpoint
    def q_step(carry, xs):
        del carry
        ql, qr, q_idx = xs
        qlf = ql.astype(jnp.float32)
        qrf = qr.astype(jnp.float32)

        def k_step(state, ys):
            m_run, l_run, acc = state
            ck, kr, k_idx = ys
            scores = (jnp.einsum("bqhr,bkr->bhqk", qlf,
                                 ck.astype(jnp.float32))
                      + jnp.einsum("bqhd,bkd->bhqk", qrf,
                                   kr.astype(jnp.float32))) * scale
            mask = _tile_mask(q_idx * bq, k_idx * bk, bq, bk, 0)
            scores = jnp.where(mask[None, None], scores, _NEG_INF)
            m_new = jnp.maximum(m_run, scores.max(-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(scores - m_new[..., None])
            l_new = l_run * alpha + p.sum(-1)
            acc = acc * alpha[..., None] \
                + jnp.einsum("bhqk,bkr->bhqr", p, ck.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, bq), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, bq), jnp.float32)
        a0 = jnp.zeros((b, h, bq, r), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                          (ckc, krc, jnp.arange(nk)))
        ctx = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, ctx.astype(q_lat.dtype)          # (B,H,bq,r)

    _, chunks = jax.lax.scan(q_step, None, (qlc, qrc, jnp.arange(nq)))
    return chunks.transpose(1, 0, 3, 2, 4).reshape(b, t, h, r)


def flash_latent_decode(q_lat, q_rope, c_kv, k_rope, pos, scale: float, *,
                        bk: int = 2048) -> jax.Array:
    """One-token MLA decode. q_lat: (B,1,H,r); caches (B,S,*)."""
    b, _, h, r = q_lat.shape
    s = c_kv.shape[1]
    bk = min(bk, s)
    assert s % bk == 0
    nk = s // bk
    qlf = q_lat.reshape(b, h, r).astype(jnp.float32)
    qrf = q_rope.reshape(b, h, -1).astype(jnp.float32)
    ckc = c_kv.reshape(b, nk, bk, r).transpose(1, 0, 2, 3)
    krc = k_rope.reshape(b, nk, bk, -1).transpose(1, 0, 2, 3)

    def k_step(state, ys):
        m_run, l_run, acc = state
        ck, kr, k_idx = ys
        scores = (jnp.einsum("bhr,bkr->bhk", qlf, ck.astype(jnp.float32))
                  + jnp.einsum("bhd,bkd->bhk", qrf,
                               kr.astype(jnp.float32))) * scale
        k_pos = k_idx * bk + jnp.arange(bk)
        scores = jnp.where((k_pos <= pos)[None, None], scores, _NEG_INF)
        m_new = jnp.maximum(m_run, scores.max(-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_run * alpha + p.sum(-1)
        acc = acc * alpha[..., None] \
            + jnp.einsum("bhk,bkr->bhr", p, ck.astype(jnp.float32))
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h), jnp.float32)
    a0 = jnp.zeros((b, h, r), jnp.float32)
    (m_f, l_f, acc), _ = jax.lax.scan(k_step, (m0, l0, a0),
                                      (ckc, krc, jnp.arange(nk)))
    ctx = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return ctx.reshape(b, 1, h, r).astype(q_lat.dtype)
