"""Unified architecture configuration for the assigned model pool.

One dataclass covers all six architecture families (dense / moe / ssm /
hybrid / vlm / audio); family-specific fields default to "off".  Each
src/repro/configs/<id>.py instantiates exactly one of these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // n_heads

    # --- attention ---
    attn_kind: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0          # 0 = full attention
    local_global_ratio: int = 0      # gemma3: N local layers per 1 global

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 64
    qk_nope_head_dim: int = 128
    v_head_dim: int = 128

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert hidden dim
    first_dense_layers: int = 0      # leading dense layers (deepseek)
    moe_interleave: int = 1          # 1 = every layer MoE; 2 = alternate
    # dispatch tokens within each data shard (shard_map partial-manual):
    # scatters stay shard-local instead of being assembled with cross-shard
    # all-reduces — see EXPERIMENTS.md §Perf B2
    moe_local_dispatch: bool = False

    # --- SSM / hybrid ---
    ssm_kind: str = ""               # rwkv6 | mamba2
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    attn_every: int = 0              # zamba2: shared attn block period

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # whisper: 30 s of audio at 50 Hz

    # --- modality frontend (stubbed per assignment) ---
    modality: str = "text"           # text | vision_stub | audio_stub

    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # activation-checkpoint policy for the layer scan:
    #   "full" — save only the inter-layer carry (recompute everything);
    #   "dots" — additionally save matmul outputs (less recompute traffic,
    #            more resident memory) — see EXPERIMENTS.md §Perf (C).
    remat_policy: str = "full"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(1, self.n_kv_heads) == 0

    # ---------------------------------------------------------- accounting
    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def param_count(self) -> int:
        """Total parameters (approximate, embeddings included)."""
        return _count_params(self, active_only=False)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: routed top-k + shared)."""
        return _count_params(self, active_only=True)

    def reduced(self, n_layers: int = 2, d_model: int = 256,
                n_experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests."""
        heads = max(1, min(self.n_heads, 4))
        kv = max(1, min(self.n_kv_heads, heads))
        while heads % kv:
            kv -= 1
        changes = dict(
            n_layers=n_layers, d_model=d_model, n_heads=heads,
            n_kv_heads=kv, head_dim=d_model // heads,
            d_ff=2 * d_model, vocab_size=vocab,
            encoder_layers=min(self.encoder_layers, n_layers),
            first_dense_layers=min(self.first_dense_layers, 1),
        )
        if self.is_moe:
            changes.update(n_experts=min(self.n_experts, n_experts),
                           experts_per_token=min(self.experts_per_token,
                                                 min(self.n_experts,
                                                     n_experts)),
                           moe_d_ff=d_model)
        if self.kv_lora_rank:
            changes.update(kv_lora_rank=64, qk_rope_head_dim=16,
                           qk_nope_head_dim=d_model // heads,
                           v_head_dim=d_model // heads)
        if self.ssm_kind:
            changes.update(ssm_state=16, ssm_head_dim=32)
        if self.attn_every:
            changes.update(attn_every=2)
        if self.sliding_window:
            changes.update(sliding_window=8)
        if self.local_global_ratio:
            # keep the local:global alternation but fit it in n_layers
            changes.update(local_global_ratio=1,
                           n_layers=max(2, n_layers - n_layers % 2))
        if self.moe_interleave > 1:
            changes.update(n_layers=max(2, n_layers
                                        - n_layers % self.moe_interleave))
        return dataclasses.replace(self, **changes)


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd, nh, nkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    total = 2 * v * d                     # embed + unembed

    def attn_params() -> int:
        if cfg.attn_kind == "mla":
            r = cfg.kv_lora_rank
            qd = nh * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
            return (d * qd                              # q
                    + d * (r + cfg.qk_rope_head_dim)    # kv down + k_rope
                    + r * nh * (cfg.qk_nope_head_dim + cfg.v_head_dim)
                    + nh * cfg.v_head_dim * d)          # o
        if cfg.attn_kind == "none":
            return 0
        return d * (nh * hd) + 2 * d * (nkv * hd) + (nh * hd) * d

    def ffn_params(layer: int) -> int:
        dense = 3 * d * ff                # SwiGLU
        if not cfg.is_moe or layer < cfg.first_dense_layers \
                or (layer % cfg.moe_interleave) != 0:
            return dense
        experts = cfg.experts_per_token if active_only else cfg.n_experts
        return (3 * d * cfg.moe_d_ff * (experts + cfg.n_shared_experts)
                + d * cfg.n_experts)      # router

    def ssm_params() -> int:
        d_in = cfg.ssm_expand * d
        if cfg.ssm_kind == "rwkv6":
            return 5 * d * d + d * d + 3 * d * ff // 2
        return 2 * d * d_in + d_in * (2 * cfg.ssm_state) + d_in * d

    for layer in range(cfg.n_layers):
        if cfg.ssm_kind and not cfg.attn_every:
            total += ssm_params()
        elif cfg.attn_every:              # hybrid: mamba blocks + shared attn
            total += ssm_params() + d * ff * 2 // cfg.n_layers
        else:
            total += attn_params() + ffn_params(layer)
    if cfg.is_encoder_decoder:
        # encoder layers + decoder cross-attention
        total += cfg.encoder_layers * (attn_params() + 3 * d * ff)
        total += cfg.n_layers * attn_params()
    return total
