"""Attention-free sequence mixers: RWKV6 ("Finch") and Mamba2 (SSD).

Both are implemented as an O(T) lax.scan over time with an explicit
recurrent state, which gives three modes for free:
  * train / prefill: scan over the whole sequence, return final state;
  * decode: a single recurrence step against the carried state (O(1) per
    token — this is why the ssm/hybrid architectures run the long_500k
    shape that full-attention models skip).

RWKV6 follows arXiv:2404.05892: token-shift interpolation, data-dependent
per-channel decay w_t via a low-rank MLP, per-head WKV state of shape
(head_dim, head_dim), bonus term u.  (Simplification vs. the reference
implementation: one shared token-shift mix per projection instead of the
5-way DDLerp LoRA tower; noted in DESIGN.md.)

Mamba2 follows arXiv:2405.21060 (as used by Zamba2): depthwise causal
conv1d on the xBC stream, scalar-per-head decay A, state (n_heads, head_dim,
d_state), gated output.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.sharding.ctx import batch_axes, constrain

Params = Dict[str, jax.Array]


# =================================================================== RWKV6
def init_rwkv6(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    hd = cfg.ssm_head_dim
    n_heads = d // hd
    lora = 32
    ks = jax.random.split(rng, 10)
    s = float(1.0 / np.sqrt(d))
    return {
        "mix": jax.random.uniform(ks[0], (5, d), dtype),   # r,k,v,g,w shifts
        "wr": jax.random.normal(ks[1], (d, d), dtype) * s,
        "wk": jax.random.normal(ks[2], (d, d), dtype) * s,
        "wv": jax.random.normal(ks[3], (d, d), dtype) * s,
        "wg": jax.random.normal(ks[4], (d, d), dtype) * s,
        "wo": jax.random.normal(ks[5], (d, d), dtype) * s,
        "w0": jnp.zeros((d,), jnp.float32) - 6.0,          # decay bias
        "w_a": jax.random.normal(ks[6], (d, lora), dtype) * s,
        "w_b": jax.random.normal(ks[7], (lora, d), dtype) * (float(1 / np.sqrt(lora))),
        "u": jax.random.normal(ks[8], (n_heads, hd), jnp.float32) * 0.1,
        "ln_x": jnp.ones((d,), dtype),
    }


def _rwkv_projections(p: Params, x: jax.Array, x_prev: jax.Array,
                      cfg: ModelConfig):
    """x: (B,T,D); x_prev: (B,T,D) = x shifted right by one token."""
    xx = x_prev - x
    xr, xk, xv, xg, xw = [x + xx * p["mix"][i] for i in range(5)]
    r = constrain(xr @ p["wr"], batch_axes(), None, "model")
    k = constrain(xk @ p["wk"], batch_axes(), None, "model")
    v = constrain(xv @ p["wv"], batch_axes(), None, "model")
    g = jax.nn.silu(constrain(xg @ p["wg"], batch_axes(), None, "model"))
    # data-dependent decay (per channel, in (0,1))
    ww = p["w0"] + (jnp.tanh(xw @ p["w_a"]) @ p["w_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww))
    return r, k, v, g, w


def _wkv_step(state, inputs, u):
    """state: (B,H,hd,hd); r,k,v: (B,H,hd); w: (B,H,hd)."""
    r, k, v, w = inputs
    kv = k[..., :, None] * v[..., None, :]            # (B,H,hd,hd)
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    state = w[..., :, None] * state + kv
    return state, out


def rwkv6_mix(p: Params, x: jax.Array, cfg: ModelConfig,
              state: jax.Array, x_last: jax.Array
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Time-mixing over a full sequence.

    state: (B, H, hd, hd) WKV state entering this chunk;
    x_last: (B, D) last token of the previous chunk (token shift carry).
    Returns (y, new_state, new_x_last).
    """
    b, t, d = x.shape
    hd = cfg.ssm_head_dim
    h = d // hd
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    r, k, v, g, w = _rwkv_projections(p, x, x_prev, cfg)

    if t >= _WKV_CHUNK and t % _WKV_CHUNK == 0:
        # chunked WKV (see _wkv_chunked): the per-timestep scan streams the
        # (B,H,hd,hd) state through HBM every token — the dominant term of
        # the rwkv6 prefill_32k baseline (EXPERIMENTS.md §Perf D)
        def heads_bt(z):
            return constrain(z.reshape(b, t, h, hd).astype(jnp.float32),
                             batch_axes(), None, "model", None)

        rs, ks, vs, ws = map(heads_bt, (r, k, v, w))
        state_f, y = _wkv_chunked(rs, ks, vs, ws, p["u"],
                                  state.astype(jnp.float32))
        y = y.reshape(b, t, d).astype(x.dtype)
    else:
        def split_heads(z):
            return z.reshape(b, t, h, hd).swapaxes(0, 1).astype(jnp.float32)

        rs, ks, vs, ws = map(split_heads, (r, k, v, w))   # (T,B,H,hd)
        rs, ks, vs, ws = (constrain(z, None, batch_axes(), "model", None)
                          for z in (rs, ks, vs, ws))

        def step(s, inp):
            return _wkv_step(s, inp, p["u"])

        state_f, outs = jax.lax.scan(step, state.astype(jnp.float32),
                                     (rs, ks, vs, ws))
        y = outs.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    # per-head group norm
    y = y.reshape(b, t, h, hd)
    mu = y.mean(-1, keepdims=True)
    var = y.astype(jnp.float32).var(-1, keepdims=True)
    y = ((y - mu) * jax.lax.rsqrt(var + 64e-5)).astype(x.dtype)
    y = y.reshape(b, t, d) * p["ln_x"]
    y = (y * g) @ p["wo"]
    return y, state_f.astype(state.dtype), x[:, -1, :]


def init_rwkv_channel_mix(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(rng, 3)
    s = float(1.0 / np.sqrt(d))
    return {
        "mix_k": jax.random.uniform(k1, (d,), dtype),
        "wk": jax.random.normal(k2, (d, ff), dtype) * s,
        "wv": jax.random.normal(k3, (ff, d), dtype) * (float(1 / np.sqrt(ff))),
    }


def rwkv_channel_mix(p: Params, x: jax.Array, x_last: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    x_prev = jnp.concatenate([x_last[:, None, :], x[:, :-1, :]], axis=1)
    xk = x + (x_prev - x) * p["mix_k"]
    h = jnp.square(jax.nn.relu(constrain(xk @ p["wk"], batch_axes(),
                                         None, "model")))
    return constrain(h @ p["wv"], batch_axes(), None, None), x[:, -1, :]


# ================================================================== Mamba2
_CONV_K = 4


def init_mamba2(rng, cfg: ModelConfig, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    n_heads = d_in // hd
    ks = jax.random.split(rng, 6)
    s = float(1.0 / np.sqrt(d))
    return {
        # projections: z (gate), x, B, C, dt
        "w_in": jax.random.normal(ks[0], (d, 2 * d_in + 2 * n + n_heads),
                                  dtype) * s,
        "conv_w": jax.random.normal(ks[1], (_CONV_K, d_in + 2 * n), dtype)
        * 0.3,
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm_z": jnp.ones((d_in,), dtype),
        "w_out": jax.random.normal(ks[2], (d_in, d), dtype)
        * (float(1 / np.sqrt(d_in))),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array,
                           carry: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B,T,C); w: (K,C); carry: (B,K-1,C) previous inputs."""
    ext = jnp.concatenate([carry, x], axis=1)             # (B, T+K-1, C)
    k = w.shape[0]
    out = sum(ext[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    new_carry = ext[:, -(k - 1):, :] if k > 1 else carry
    return out, new_carry


def mamba2_mix(p: Params, x: jax.Array, cfg: ModelConfig,
               state: jax.Array, conv_carry: jax.Array
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """SSD over a sequence.  state: (B, H, hd, N); conv_carry: (B,K-1,C)."""
    b, t, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    hd = cfg.ssm_head_dim
    h = d_in // hd

    proj = constrain(x @ p["w_in"], batch_axes(), None, "model")
    z, xbc_dt = jnp.split(proj, [d_in], axis=-1)
    xbc, dt_raw = jnp.split(xbc_dt, [d_in + 2 * n], axis=-1)
    xbc, new_carry = _causal_depthwise_conv(xbc, p["conv_w"], conv_carry)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_in, d_in + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                  # (B,T,H)
    a = -jnp.exp(p["A_log"])                              # (H,)
    decay = jnp.exp(dt * a)                               # (B,T,H)

    xs_h = constrain(xs.reshape(b, t, h, hd).astype(jnp.float32),
                     batch_axes(), None, "model", None)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)

    if t >= _SSD_CHUNK and t % _SSD_CHUNK == 0:
        # chunked SSD (arXiv:2405.21060): per-chunk matmul form.  The
        # per-timestep scan streams the (B,H,hd,N) state through HBM every
        # step — the dominant roofline term of the zamba2 train_4k
        # baseline (EXPERIMENTS.md Perf iteration A); chunking exchanges
        # state once per chunk and turns the work into MXU matmuls.
        state_f, y = _ssd_chunked(xs_h, bf, cf, dt, a,
                                  state.astype(jnp.float32))
    else:
        def step(s, inp):
            x_t, b_t, c_t, dec_t, dt_t = inp              # (B,H,hd) (B,N)..
            upd = dt_t[..., None, None] * (x_t[..., :, None]
                                           * b_t[:, None, None, :])
            s = dec_t[..., None, None] * s + upd          # (B,H,hd,N)
            y_t = jnp.einsum("bhdn,bn->bhd", s, c_t)
            return s, y_t

        seq = (xs_h.swapaxes(0, 1), bf.swapaxes(0, 1), cf.swapaxes(0, 1),
               decay.swapaxes(0, 1), dt.swapaxes(0, 1))
        state_f, ys = jax.lax.scan(step, state.astype(jnp.float32), seq)
        y = ys.swapaxes(0, 1)                             # (B,T,H,hd)
    y = y + p["D"][None, None, :, None] * xs_h
    y = y.reshape(b, t, d_in).astype(x.dtype)
    y = y * jax.nn.silu(z) * p["norm_z"]
    return y @ p["w_out"], state_f.astype(state.dtype), new_carry


_SSD_CHUNK = 256


def _ssd_chunked(x, bmat, cmat, dt, a, state0):
    """Chunked SSD recurrence.

    x: (B,T,H,hd) f32; bmat/cmat: (B,T,N); dt: (B,T,H); a: (H,) negative;
    state0: (B,H,hd,N).  Returns (final_state, y (B,T,H,hd)).

    Per chunk of length L (all cumulative sums chunk-local):
        l_t   = cumsum(dt_u * a)                      log-decay, (B,L,H)
        y_t   = exp(l_t) * (C_t . h_0)
              + sum_{j<=t} exp(l_t - l_j) (C_t . B_j) dt_j x_j
        h_L   = exp(l_L) h_0 + sum_j exp(l_L - l_j) dt_j B_j x_j
    """
    b, t, h, hd = x.shape
    L = _SSD_CHUNK
    nc = t // L
    xc = x.reshape(b, nc, L, h, hd).transpose(1, 0, 2, 3, 4)
    bc = bmat.reshape(b, nc, L, -1).transpose(1, 0, 2, 3)
    cc = cmat.reshape(b, nc, L, -1).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, L, h).transpose(1, 0, 2, 3)

    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(h0, inp):
        xk, bk, ck, dtk = inp                   # (B,L,H,hd) (B,L,N) (B,L,H)
        logd = dtk * a                          # (B,L,H), <= 0
        l = jnp.cumsum(logd, axis=1)            # (B,L,H)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bln,bhdn->blhd", ck, h0) \
            * jnp.exp(l)[..., None]
        # intra-chunk: (C_t . B_j) with per-head decay window
        s_cb = jnp.einsum("btn,bjn->btj", ck, bk)          # (B,L,L)
        ldiff = l[:, :, None, :] - l[:, None, :, :]        # (B,L,L,H)
        w = jnp.where(causal[None, :, :, None],
                      jnp.exp(ldiff), 0.0) * s_cb[..., None]
        xdt = xk * dtk[..., None]                          # (B,L,H,hd)
        y_intra = jnp.einsum("btjh,bjhd->bthd", w, xdt)
        # state update
        decay_to_end = jnp.exp(l[:, -1:, :] - l)           # (B,L,H)
        scale = jnp.exp(l[:, -1])                          # (B,H)
        h_new = scale[:, :, None, None] * h0 \
            + jnp.einsum("blh,bln,blhd->bhdn", decay_to_end * dtk, bk, xk)
        return h_new, y_inter + y_intra

    state_f, yc = jax.lax.scan(chunk_step, state0, (xc, bc, cc, dtc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)
    return state_f, y


def mamba2_state_shapes(cfg: ModelConfig, batch: int):
    d_in = cfg.ssm_expand * cfg.d_model
    h = d_in // cfg.ssm_head_dim
    return ((batch, h, cfg.ssm_head_dim, cfg.ssm_state),
            (batch, _CONV_K - 1, d_in + 2 * cfg.ssm_state))


def rwkv6_state_shapes(cfg: ModelConfig, batch: int):
    h = cfg.d_model // cfg.ssm_head_dim
    return ((batch, h, cfg.ssm_head_dim, cfg.ssm_head_dim),
            (batch, cfg.d_model))


# ------------------------------------------------------------ chunked WKV
_WKV_CHUNK = 64
_WKV_SUB = 16


def _wkv_chunked(r, k, v, w, u, state0):
    """Chunked RWKV6 WKV — exact, numerically-safe two-level scheme.

    r/k/v: (B,T,H,hd) f32; w: (B,T,H,hd) per-channel decay in (0,1);
    u: (H,hd); state0: (B,H,hd,hd).  Returns (final_state, out).

    The naive two-factor trick exp(l_{t-1}) * exp(-l_j) overflows/clamps
    under strong decay, so exponents are re-centered per length-16
    sub-chunk: with ref_s = l at sub-chunk s entry,
        A[t, (s,j)] = sum_k r_t exp(l_{t-1}-ref_s) . k_j exp(ref_s-l_j)
    both exponents are bounded (<=0, and <= 16 steps of decay resp.).
    """
    b, t, h, hd = r.shape
    L, c = _WKV_CHUNK, _WKV_SUB
    ns = L // c
    nc = t // L

    def to_chunks(z):
        return z.reshape(b, nc, L, h, hd).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))
    strict = jnp.tril(jnp.ones((L, L), bool), k=-1)
    sub_of = jnp.arange(L) // c                       # (L,)
    valid_ts = sub_of[:, None] >= jnp.arange(ns)[None, :]   # (L, ns)

    def chunk_step(s0, inp):
        rk, kk, vk, wk = inp                          # (B,L,H,hd)
        logw = jnp.log(jnp.maximum(wk, 1e-38))
        l = jnp.cumsum(logw, axis=1)                  # (B,L,H,hd) <= 0
        l_prev = l - logw                             # l_{t-1}, l_0 = 0
        ref = l_prev.reshape(b, ns, c, h, hd)[:, :, 0]        # (B,ns,H,hd)

        # queries re-centered at each sub-chunk reference
        e_r = l_prev[:, :, None] - ref[:, None, :, :, :]      # (B,L,ns,H,hd)
        e_r = jnp.where(valid_ts[None, :, :, None, None], e_r, -jnp.inf)
        rdx = rk[:, :, None] * jnp.exp(e_r)                   # (B,L,ns,H,hd)
        # keys re-centered at their own sub-chunk reference
        e_k = (ref[:, :, None] - l.reshape(b, ns, c, h, hd))  # (B,ns,c,H,hd)
        kdx = kk.reshape(b, ns, c, h, hd) * jnp.exp(e_k)

        a = jnp.einsum("btshk,bsjhk->bhtsj", rdx, kdx)
        a = a.reshape(b, h, L, L)
        a = jnp.where(strict[None, None], a, 0.0)
        out_intra = jnp.einsum("bhtj,bjhv->bthv", a, vk)
        diag = jnp.einsum("blhk,blhk->blh", rk * u[None, None], kk)
        out_inter = jnp.einsum("blhk,bhkv->blhv", rk * jnp.exp(l_prev), s0)
        out = out_inter + out_intra + diag[..., None] * vk

        decay_to_end = jnp.exp(l[:, -1:] - l)         # (B,L,H,hd)
        s_new = jnp.exp(l[:, -1])[:, :, :, None] * s0 \
            + jnp.einsum("bjhk,bjhv->bhkv", kk * decay_to_end, vk)
        return s_new, out

    state_f, outs = jax.lax.scan(chunk_step, state0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, t, h, hd)
    return state_f, out
